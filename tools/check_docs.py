#!/usr/bin/env python
"""Docs lint (CI gate): executable snippets + resolvable links.

Walks README.md and docs/*.md and enforces two rules so the docs tree
cannot rot silently:

1. **Fenced ``python`` blocks run.** Each file's blocks execute in order
   in one shared namespace, seeded with a tiny prelude (a ~200-string
   synthetic corpus as ``strings`` and a saved store directory as
   ``store_dir``) so examples exercise the real API instead of
   pseudo-code. Blocks that genuinely cannot run standalone (remote
   addresses, spawned processes) opt out with an info string of
   ``python no-run``; non-python fences are ignored.

2. **Intra-repo links resolve.** Every relative markdown link target
   (anchors stripped; http/https/mailto skipped) must exist on disk.

Exit status is the number of violations (0 = clean).

  PYTHONPATH=src python tools/check_docs.py            # README.md + docs/
  PYTHONPATH=src python tools/check_docs.py docs/api.md
"""

from __future__ import annotations

import os
import re
import shutil
import sys
import tempfile
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE = re.compile(r"^```(\S*)[ \t]*([^\n]*)$")
#: [text](target) — target captured up to the closing paren
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:")


def doc_files(argv: list[str]) -> list[str]:
    if argv:
        return [os.path.abspath(p) for p in argv]
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(
            os.path.join(docs, n) for n in os.listdir(docs)
            if n.endswith(".md"))
    return files


def extract_blocks(text: str) -> list[tuple[int, str, str]]:
    """(start_line, info_string, code) for every fenced block."""
    blocks: list[tuple[int, str, str]] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and lines[i].startswith("```") and lines[i] != "```":
            lang, extra = m.group(1), m.group(2).strip()
            start = i + 1
            j = start
            while j < len(lines) and lines[j].strip() != "```":
                j += 1
            info = f"{lang} {extra}".strip()
            blocks.append((start + 1, info, "\n".join(lines[start:j])))
            i = j + 1
        else:
            i += 1
    return blocks


def _build_prelude_namespace(workdir: str) -> dict:
    """The shared vocabulary doc snippets may assume: a tiny corpus and a
    saved store directory (built once, copied per doc file so writable
    examples cannot poison each other)."""
    from repro.data.synth import load_dataset
    from repro.store import CompressedStringStore

    strings = load_dataset("book_titles", 1 << 15)[:200]
    store_dir = os.path.join(workdir, "docstore")
    CompressedStringStore.build(
        strings, sample_bytes=1 << 15, strings_per_segment=64,
    ).save(store_dir)
    return {"strings": strings, "store_dir": store_dir}


def check_links(path: str, text: str) -> list[str]:
    errors = []
    base = os.path.dirname(path)
    # ignore link-looking text inside fenced code blocks
    prose = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for m in _LINK.finditer(prose):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(_SKIP_SCHEMES):
            continue
        if not os.path.exists(os.path.join(base, target)):
            rel = os.path.relpath(path, REPO)
            errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def run_blocks(path: str, text: str, prelude: dict, workdir: str) -> list[str]:
    errors = []
    rel = os.path.relpath(path, REPO)
    namespace: dict | None = None
    for lineno, info, code in extract_blocks(text):
        parts = info.split()
        if not parts or parts[0] != "python":
            continue
        if "no-run" in parts[1:]:
            continue
        if namespace is None:
            # fresh per-file copy of the saved store so writes don't leak
            file_dir = tempfile.mkdtemp(dir=workdir)
            store_dir = os.path.join(file_dir, "docstore")
            shutil.copytree(prelude["store_dir"], store_dir)
            namespace = {"strings": list(prelude["strings"]),
                         "store_dir": store_dir}
        try:
            exec(compile(code, f"{rel}:{lineno}", "exec"), namespace)
        except Exception:
            tb = traceback.format_exc(limit=3)
            errors.append(f"{rel}:{lineno}: snippet failed\n{tb}")
    return errors


def main(argv: list[str] | None = None) -> int:
    sys.path.insert(0, os.path.join(REPO, "src"))
    os.environ.setdefault("REPRO_NO_JAX", "1")
    files = doc_files(argv or [])
    workdir = tempfile.mkdtemp(prefix="check_docs_")
    violations: list[str] = []
    try:
        prelude = _build_prelude_namespace(workdir)
        for path in files:
            with open(path, encoding="utf-8") as f:
                text = f.read()
            violations += check_links(path, text)
            violations += run_blocks(path, text, prelude, workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    for v in violations:
        print(v)
    print(f"check_docs: {len(files)} files, {len(violations)} violation(s)")
    return min(len(violations), 125)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
