#!/usr/bin/env python
"""Hot-path observability lint (CI gate).

AST-walks the serving packages (``src/repro/{store,net,client,obs}``) and
fails on two classes of latency bugs that keep sneaking back into serving
code:

1. **``time.time()`` in a hot path** — wall-clock time is not monotonic
   (NTP slew makes latency samples negative or wildly large). Serving code
   must use ``time.perf_counter()``; the tracer and every histogram in
   ``repro.obs`` already do.

2. **Unbounded latency-sample accumulation** — ``somelist.append(dt)`` /
   ``.extend(lats)`` on a name that looks like a latency/sample collector
   grows without bound under sustained load. Latency belongs in the
   fixed-bucket ``repro.obs.Histogram`` (constant memory, mergeable) or a
   bounded ring.

Suppress a deliberate exception with ``# hotpath: ok`` on the offending
line. Exit status is the number of violations (0 = clean).

  PYTHONPATH=src python tools/check_hotpath.py
"""

from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
#: serving packages where the hot-path rules apply
PACKAGES = ("store", "net", "client", "obs", "loadgen")
#: attribute names whose .append/.extend looks like latency-sample hoarding
_SAMPLEY = re.compile(
    r"(^|_)(lat|lats|latency|latencies|sample|samples|duration|durations)($|_)"
)
_SUPPRESS = "# hotpath: ok"


def _target_name(node: ast.expr) -> str | None:
    """The receiver name of a ``<recv>.append(...)`` call, if plain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, source_lines: list[str]):
        self.path = path
        self.lines = source_lines
        self.violations: list[str] = []

    def _flag(self, node: ast.AST, message: str) -> None:
        line = self.lines[node.lineno - 1] if node.lineno <= len(self.lines) else ""
        if _SUPPRESS in line:
            return
        rel = os.path.relpath(self.path, REPO)
        self.violations.append(f"{rel}:{node.lineno}: {message}")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        # any mention of time.time — call or bare reference (aliasing it
        # into a variable is the classic way past a call-only check)
        if (node.attr == "time" and isinstance(node.value, ast.Name)
                and node.value.id == "time"):
            self._flag(node, "time.time is wall-clock (non-monotonic); "
                             "use time.perf_counter()")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in ("append", "extend"):
            recv = _target_name(fn.value)
            if recv is not None and _SAMPLEY.search(recv):
                self._flag(
                    node,
                    f"unbounded sample list: {recv}.{fn.attr}(...) — record "
                    "into repro.obs.Histogram or a bounded ring instead")
        self.generic_visit(node)


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    checker = _Checker(path, source.splitlines())
    checker.visit(ast.parse(source, filename=path))
    return checker.violations


def main(argv: list[str] | None = None) -> int:
    roots = (argv if argv else
             [os.path.join(REPO, "src", "repro", pkg) for pkg in PACKAGES])
    violations: list[str] = []
    n_files = 0
    for root in roots:
        if os.path.isfile(root):
            n_files += 1
            violations += check_file(root)
            continue
        for dirpath, _, filenames in os.walk(root):
            for name in sorted(filenames):
                if name.endswith(".py"):
                    n_files += 1
                    violations += check_file(os.path.join(dirpath, name))
    for v in violations:
        print(v)
    print(f"check_hotpath: {n_files} files, {len(violations)} violation(s)")
    return min(len(violations), 125)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
