"""Example: serving point lookups from the compressed string store.

1. Train OnPair16 and open a CompressedStringStore over the corpus
   (compressed payload + segments + LRU cache + Pallas batch decoder).
2. Batched multiget — note the bounded set of jit-compiled decode shapes.
3. Range scan — one vectorised decode per touched segment.
4. StoreService — concurrent clients coalesced into micro-batches.
5. Persistence + the v3 client layer — store.save(dir), then
   connect("file://<dir>"): the train-once dictionary artifact + corpus
   reopen with no retraining behind the uniform session surface
   (sync + async + streaming scan + one stats schema).

  PYTHONPATH=src python examples/store_serving.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile
import threading
import time

import numpy as np

from repro.data.synth import load_dataset
from repro.store import CompressedStringStore, StoreService

strings = load_dataset("urls", 2 << 20)
store = CompressedStringStore.build(strings, sample_bytes=2 << 20,
                                    strings_per_segment=4096)
print(f"store: {len(store)} strings, {store.segments.n_segments} segments, "
      f"{store.backend} backend, bucket caps {[int(c) for c in store.bucket_caps]}, "
      f"{store.memory_bytes / (1 << 20):.2f} MiB resident")

# --- batched point lookups (the paper's random-access workload, batched) ----
rng = np.random.default_rng(0)
ids = rng.integers(0, len(store), 2000).tolist()
t0 = time.perf_counter()
out = store.multiget(ids)
dt = time.perf_counter() - t0
assert out == [strings[i] for i in ids]
print(f"multiget: {len(ids)} lookups in {dt * 1e3:.1f} ms "
      f"({len(ids) / dt:.0f} lookups/s), "
      f"jit decode shapes: {sorted(store.stats.jit_shapes)}")

# --- range scan -------------------------------------------------------------
t0 = time.perf_counter()
docs = store.scan(1000, 3000)
assert docs == strings[1000:3000]
print(f"scan[1000:3000): {len(docs)} strings in "
      f"{(time.perf_counter() - t0) * 1e3:.1f} ms")

# --- micro-batching service: concurrent clients, coalesced decodes ----------
with StoreService(store, max_batch=256, max_wait_s=0.002) as svc:
    def client(seed: int) -> None:
        r = np.random.default_rng(seed)
        for i in r.integers(0, len(store), 200):
            assert svc.get(int(i)) == strings[int(i)]

    threads = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    st = svc.stats()
    print(f"service: {st['requests']} requests from 4 clients in "
          f"{dt * 1e3:.0f} ms, {st['batches']} batches "
          f"(avg {st['avg_batch']} lookups/batch), "
          f"p99 {st['request_latency']['p99_us']:.0f} us")

snap = store.stats_snapshot()
print(f"totals: {snap['lookups']} lookups, cache hit rate "
      f"{snap['cache']['hit_rate']:.2f}, decode {snap['decode_mib_s']} MiB/s")

# --- persistence + Client API v3: one session over the saved store ----------
from repro.client import connect

with tempfile.TemporaryDirectory() as d:
    t0 = time.perf_counter()
    store.save(d)
    save_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    with connect(f"file://{d}") as client:         # mmap, no retraining
        open_ms = (time.perf_counter() - t0) * 1e3
        assert client.multiget(ids[:200]) == store.multiget(ids[:200])
        # async pipelining: several batched lookups in flight at once, all
        # coalesced through the session's micro-batching service
        futs = [client.multiget_async(ids[k : k + 100])
                for k in range(0, 1000, 100)]
        assert [b for f in futs for b in f.result(30)] == \
            store.multiget(ids[:1000])
        # streamed range decode (never materialises the whole range)
        assert list(client.scan_iter(1000, 3000, chunk=512)) == docs
        snap = client.stats()
        print(f"client: saved in {save_ms:.1f} ms, connect('file://...') in "
              f"{open_ms:.1f} ms ({client.backend.artifact.num_entries} dict "
              f"entries); {snap['ops']} -> p99 "
              f"{snap['latency_summary']['p99_us']:.0f} us, "
              f"{snap['throughput_mib_s']} MiB/s, multiget identical")
