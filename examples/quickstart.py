"""Quickstart: the paper in 60 seconds.

Train OnPair / OnPair16 on a corpus of short strings, compress, random-access
individual strings, and compare against BPE / FSST / block-zstd — the paper's
Table 3 in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np

from repro.core import registry
from repro.data.synth import load_dataset

strings = load_dataset("book_titles", 2 << 20)
raw = sum(len(s) for s in strings)
print(f"corpus: {len(strings)} strings, {raw / (1 << 20):.1f} MiB "
      "(synthetic Book Titles analogue)\n")
print(f"{'compressor':11s} {'ratio':>6s} {'comp MiB/s':>11s} "
      f"{'decomp MiB/s':>13s} {'access ns':>10s} {'train s':>8s}")

for name in ("raw", "zstd-block", "fsst", "onpair", "onpair16"):
    try:
        comp = registry.create(name)
    except Exception as e:  # e.g. zstandard not installed
        print(f"{name:11s} skipped ({e})")
        continue
    stats = comp.train(strings, raw)
    t0 = time.perf_counter()
    corpus = comp.compress(strings)
    comp_s = stats.train_seconds + time.perf_counter() - t0
    t0 = time.perf_counter()
    assert comp.decompress_all(corpus) == b"".join(strings)
    dec_s = time.perf_counter() - t0
    idx = np.random.default_rng(0).integers(0, len(strings), 3000)
    t0 = time.perf_counter()
    for i in idx:
        comp.access(corpus, int(i))
    acc = (time.perf_counter() - t0) / 3000 * 1e9
    print(f"{name:11s} {corpus.ratio:6.3f} {raw / (1 << 20) / comp_s:11.2f} "
          f"{raw / (1 << 20) / dec_s:13.1f} {acc:10.0f} "
          f"{stats.train_seconds:8.2f}")

print("\nexpected shape (paper Table 3): onpair ~ bpe >> fsst > zstd on ratio;"
      "\nfield-level access ~1e3 ns vs block-level ~1e5 ns; onpair16 decode fastest.")
