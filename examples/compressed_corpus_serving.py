"""Example: the paper's random-access workload as an LM data/serving plane.

1. Build an OnPair16-compressed in-memory corpus store (compress once).
2. Random-access point queries (the paper's 1M-query benchmark).
3. Detokenise on device with the Pallas/JAX OnPair decode kernels — the
   serving-side decompression path.

  PYTHONPATH=src python examples/compressed_corpus_serving.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np

from repro.data.corpus import CompressedCorpusStore
from repro.data.synth import load_dataset
from repro.kernels.ops import OnPairDevice

strings = load_dataset("urls", 2 << 20)
store = CompressedCorpusStore.build(strings, sample_bytes=2 << 20)
print(f"store: {store.n_docs} docs, ratio {store.compression_ratio:.2f}x, "
      f"{store.memory_bytes / (1 << 20):.2f} MiB resident "
      f"(dictionary {store.tokenizer.dictionary.total_bytes / (1 << 20):.3f} MiB)")

# --- point queries (paper §4.4: uniform random access) ----------------------
rng = np.random.default_rng(0)
idx = rng.integers(0, store.n_docs, 20000)
t0 = time.perf_counter()
for i in idx:
    store.doc_bytes(int(i))
dt = (time.perf_counter() - t0) / len(idx)
print(f"random access: {dt * 1e9:.0f} ns/string over {len(idx)} queries")
assert store.doc_bytes(17) == strings[17]

# --- device-side detokenisation (kernels) -----------------------------------
# constructed from the serializable artifact — the same object a remote
# serving host would DictArtifact.load() from disk, no trainer state needed
dev = OnPairDevice.from_artifact(store.tokenizer.to_artifact())
batch_ids = [int(i) for i in idx[:64]]
tokens = [store.doc_tokens(i) for i in batch_ids]
T = max(len(t) for t in tokens)
tok_mat = np.zeros((len(tokens), T), np.int32)
ntok = np.zeros(len(tokens), np.int32)
for r, t in enumerate(tokens):
    tok_mat[r, : len(t)] = t
    ntok[r] = len(t)
max_out = max(len(strings[i]) for i in batch_ids)
out = dev.decode_batch(tok_mat, ntok, max_out, use_pallas=True)
assert out == [strings[i] for i in batch_ids]
print(f"Pallas decode_compact: {len(out)} strings decoded on device, "
      "bit-exact vs host decoder")

stream = np.concatenate(tokens)
full = dev.decode_stream(stream, use_pallas=True)
assert full == b"".join(strings[i] for i in batch_ids)
print("Pallas two-phase stream decode (gather + prefix-sum compaction): OK")
