"""Multi-process shard serving, end to end.

1. Train one OnPair dictionary, save the corpus as N shard directories
   sharing that dictionary artifact (repro.distributed.shard_store).
2. Spawn one shard-server PROCESS per shard (python -m repro.net) and
   connect the v3 client to both deployment shapes — connect("tcp://...")
   across the processes and connect("shard://<dir>") in-process — with
   byte-identical results through one session surface.
3. Spawn a read-only REPLICA of the tail shard: read_preference="replica"
   round-robins reads onto it outside compaction windows too, and during
   compact() reads drain to it while appends park in the router's bounded
   retry queue — everything acknowledged and durable once the primary
   publishes its new generation.

Stdlib + numpy only (REPRO_NO_JAX=1 in the children): this is the serving
topology for hosts without accelerators.

  PYTHONPATH=src python examples/multiprocess_serving.py
"""

import os
import re
import shutil
import subprocess
import sys
import tempfile
import threading
import time

from repro.client import connect, format_tcp_url
from repro.data.synth import load_dataset
from repro.distributed import save_sharded
from repro.store import CompressedStringStore

N_SHARDS = 3
SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
ENV = {**os.environ, "PYTHONPATH": SRC, "REPRO_NO_JAX": "1"}


def spawn(shard_dir: str, *flags: str):
    """One shard-server process; returns (proc, (host, port)) once ready."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.net", shard_dir, *flags],
        stdout=subprocess.PIPE,
        text=True,
        env=ENV,
    )
    line = proc.stdout.readline()
    port = int(re.search(r"port=(\d+)", line).group(1))
    return proc, ("127.0.0.1", port)


# --- 1. one dictionary, N shard directories --------------------------------
strings = load_dataset("urls", 2 << 20)
store = CompressedStringStore.build(strings, sample_bytes=2 << 20)
base = tempfile.mkdtemp(prefix="mp_serving_")
bounds = save_sharded(store, base, N_SHARDS)
print(f"sharded {len(strings)} strings into {len(bounds)} shard dirs: {bounds}")

procs = []
try:
    # --- 2. one process per shard + the routing client ---------------------
    addrs = []
    for k in range(N_SHARDS):
        proc, addr = spawn(os.path.join(base, f"shard-{k:04d}"))
        procs.append(proc)
        addrs.append(addr)
    print(f"spawned {N_SHARDS} shard servers: {[p.pid for p in procs]}")

    url = format_tcp_url(addrs)
    dist = connect(url, dir_path=base)
    local = connect(f"shard://{base}")
    ids = list(range(0, len(strings), max(1, len(strings) // 4096)))
    assert dist.multiget(ids) == local.multiget(ids) == [strings[i] for i in ids]
    print(f"connect({url.split(',')[0]}...) multiget({len(ids)} ids spanning "
          f"{N_SHARDS} shards): byte-identical to connect('shard://...')")

    # --- 3. replica-backed compaction hand-off -----------------------------
    tail = N_SHARDS - 1
    pre = dist.extend([b"pre-compact doc %d" % i for i in range(64)])
    dist.save()  # replica must see the saved generation
    replica_proc, replica_addr = spawn(
        os.path.join(base, f"shard-{tail:04d}"), "--read-only"
    )
    procs.append(replica_proc)
    dist.register_replica(tail, replica_addr)

    # replica read load-balancing OUTSIDE the compaction window: with
    # read_preference="replica", reads of ids the replica holds round-robin
    # onto it (ids newer than its generation still come from the primary)
    assert dist.multiget(pre[:8], read_preference="replica") == \
        [b"pre-compact doc %d" % i for i in range(8)]
    print('read_preference="replica": reads served by the replica set')

    done: dict = {}

    def compact():
        done["reports"] = dist.compact(tail)

    worker = threading.Thread(target=compact)
    worker.start()
    time.sleep(0.05)  # land inside the compaction window
    t0 = time.perf_counter()
    read_back = dist.get(pre[7])
    read_ms = (time.perf_counter() - t0) * 1e3
    appended_id = dist.append(b"appended while the primary was compacting")
    worker.join()
    report = done["reports"][0]
    assert read_back == b"pre-compact doc 7"
    print(f"during compact: read served in {read_ms:.1f} ms (replica), "
          f"append parked + acknowledged as id {appended_id}")
    print(f"compact: {report['n_strings']} strings -> {report['version']}, "
          f"ratio {report['ratio_before']} -> {report['ratio_after']}")

    assert dist.get(appended_id) == b"appended while the primary was compacting"
    dist.save()
    with connect(f"shard://{base}") as reopened:
        assert reopened.get(appended_id) == \
            b"appended while the primary was compacting"
        assert reopened.multiget(ids) == [strings[i] for i in ids]
    print("after hand-off: append durable on disk, reopened router agrees — OK")
    local.close()
    dist.close()
finally:
    for p in procs:
        p.terminate()
    shutil.rmtree(base, ignore_errors=True)
