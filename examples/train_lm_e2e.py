"""End-to-end driver: train a ~100M-param LM for a few hundred steps on an
OnPair-compressed corpus, with checkpointing and resume.

Uses the mamba2 family at width 512 (the assigned-architecture code path, at
a CPU-trainable size ~30-100M params depending on flags). The data plane is
the paper's contribution: the corpus lives compressed in memory and the
OnPair dictionary IS the tokenizer vocabulary.

  PYTHONPATH=src python examples/train_lm_e2e.py --steps 200
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.corpus import CompressedCorpusStore
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.data.synth import load_dataset
from repro.models.model import build_params
from repro.optim.adamw import AdamWConfig, init_state
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.train.train_step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
args = ap.parse_args()

# data plane: compressed corpus + OnPair tokenizer
strings = load_dataset("book_reviews", 2 << 20)
store = CompressedCorpusStore.build(strings, sample_bytes=2 << 20)
pipe = TokenPipeline(store, BatchSpec(args.batch, args.seq, seed=0))
print(f"corpus ratio {store.compression_ratio:.2f}x; vocab "
      f"{store.tokenizer.vocab_size}")

cfg = replace(get_arch("mamba2-780m"),
              n_layers=args.layers, d_model=args.d_model,
              ssm_state=64, ssm_head_dim=32,
              vocab_size=store.tokenizer.vocab_size)
print(f"model: {cfg.n_params() / 1e6:.1f}M params "
      f"({cfg.n_layers}L d{cfg.d_model}, SSD)")

params = build_params(cfg, seed=0)
opt = AdamWConfig(lr=3e-3)
state = {"params": params, "opt": init_state(params, opt),
         "step": jnp.zeros((), jnp.int32)}
step_fn = jax.jit(make_train_step(cfg, opt, schedule_total=args.steps))


def batch_fn(step):
    b = pipe.batch(step)
    return {"tokens": jnp.asarray(b["tokens"]),
            "targets": jnp.asarray(b["targets"])}


loop = TrainLoop(step_fn, state, batch_fn,
                 LoopConfig(total_steps=args.steps, ckpt_every=100,
                            ckpt_dir=args.ckpt_dir, log_every=20),
                 abstract_state=jax.eval_shape(lambda: state))
stats = loop.run()
first, last = stats.losses[0], stats.losses[-1]
print(f"\nloss {first:.3f} -> {last:.3f} over {stats.steps_run} steps "
      f"(resumed from {stats.resumed_from})")
assert last < first, "loss should decrease on the compressed-corpus pipeline"
print("OK: end-to-end training on the OnPair data plane works")
