"""Example: the writable-store lifecycle — append, drift, compact.

1. Build a store over an initial URL corpus; its trained dictionary is now
   FROZEN — new strings are parsed against it with no retraining (the
   paper's per-string independence is what makes this safe).
2. Append more URLs: they land in an open tail and seal into immutable
   segments; get/multiget/scan stay consistent across sealed + tail.
3. Inject drift: append book titles (a different distribution). The drift
   monitor watches appended ratio vs the train-time ratio and trips.
4. compact(): re-train on the live data, rewrite every segment, swap a new
   versioned artifact directory atomically. All strings stay byte-identical;
   the ratio recovers.
5. Reopen from disk through the v3 client layer (connect("mut://<dir>")) —
   versioned layout, unsealed tail included, async appends pipelined
   through the session's micro-batching service.

  PYTHONPATH=src python examples/writable_store.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile
import time

from repro.core import registry
from repro.data.synth import load_dataset
from repro.store import MutableStringStore, StoreService

urls = load_dataset("urls", 2 << 20)
half = len(urls) // 2
base, incoming = urls[:half], urls[half:]

# --- 1. train once, freeze the dictionary -----------------------------------
artifact = registry.train("onpair16", base, sample_bytes=2 << 20)
codec = registry.codec_from_artifact(artifact)   # tables built once, shared
store = MutableStringStore((artifact, codec), codec.compress(base),
                           strings_per_segment=4096, drift_threshold=0.25)
print(f"store: {len(store)} strings sealed, ratio at train time "
      f"{store.drift.baseline_ratio:.2f}, backend {store.backend}")

# --- 2. append against the frozen dictionary --------------------------------
t0 = time.perf_counter()
ids = store.extend(incoming)
dt = time.perf_counter() - t0
snap = store.stats_snapshot()
print(f"appended {len(ids)} strings in {dt * 1e3:.0f} ms "
      f"({len(ids) / dt:.0f} strings/s): {snap['n_sealed_strings']} sealed + "
      f"{snap['n_tail_strings']} tail, drift {snap['drift']['drift']:.3f}")
assert store.get(ids[0]) == incoming[0]
assert store.scan(half - 5, half + 5) == urls[half - 5 : half + 5]  # boundary

# appends also flow through the micro-batching service, next to reads
with StoreService(store, max_batch=128) as svc:
    fut = svc.submit_append(b"https://example.com/brand-new-doc")
    new_id = fut.result(10)
    assert svc.get(new_id) == b"https://example.com/brand-new-doc"
print(f"service: append -> id {new_id}, read-back identical")

# --- 3. inject drift: a different distribution arrives ----------------------
titles = load_dataset("book_titles", 1 << 20)
store.extend(titles)
drift = store.drift.snapshot()
print(f"after {len(titles)} book titles: appended-data ratio "
      f"{drift['observed_ratio']:.2f} vs baseline {drift['baseline_ratio']:.2f} "
      f"-> drift {drift['drift']:.3f}, should_compact={drift['should_compact']}")

# --- 4. compact: re-train + rewrite + atomic versioned swap -----------------
with tempfile.TemporaryDirectory() as d:
    store.save(d)
    before = store.scan(0, len(store))
    report = store.compact()
    assert store.scan(0, len(store)) == before     # byte-identical rewrite
    print(f"compact: ratio {report['ratio_before']:.3f} -> "
          f"{report['ratio_after']:.3f} in {report['total_s']:.2f}s "
          f"(train {report['train_s']:.2f}s), now {report['version']} "
          f"in {report['dir']}")

    # --- 5. reopen the versioned directory via the client layer -------------
    from repro.client import connect

    with connect(f"mut://{d}") as client:
        assert len(client) == len(store)
        assert client.multiget([0, new_id, len(store) - 1]) == \
            store.multiget([0, new_id, len(store) - 1])
        # async appends pipeline through the same micro-batching service the
        # sync calls ride; futures resolve to the assigned global ids
        futs = [client.extend_async([b"doc-a-%d" % i, b"doc-b-%d" % i])
                for i in range(8)]
        new_ids = [i for f in futs for i in f.result(30)]
        assert new_ids == list(range(len(store), len(store) + 16))
        snap = client.stats()
        print(f"reopened {report['version']} via connect('mut://...'): "
              f"{snap['n_strings']} strings, multiget identical, "
              f"{snap['ops'].get('extend', 0)} async extends in "
              f"{snap['wakeups']} service wakeups")
