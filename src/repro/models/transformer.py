"""Unified decoder stack for all 10 assigned architectures.

The model is a `lax.scan` over *blocks*; a block is the architecture's layer
period (gemma2: [local, global]; jamba: [7x mamba + 1x attn, MoE every 2nd];
llama-vision: [cross-attn + 4x self]; plain dense/MoE: 1 layer). Scanning
keeps the HLO O(block) instead of O(layers): 100-layer models compile in the
same time as 2-layer ones, and per-layer FSDP all-gathers pipeline inside
the scan (latency hiding).

Three entry points per architecture (built in repro.models.model):
  forward      — full-sequence logits (training)
  prefill      — forward + materialised KV/SSM caches, last-position logits
  decode_step  — one token against the caches
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (attention, batch_axes, constrain,
                                 decode_attention, init_attention, init_mlp,
                                 init_moe, mlp, moe, rms_norm, softcap)
from repro.models.ssm import init_ssm, init_ssm_cache, ssd_apply, ssd_decode

Params = dict[str, Any]


# --------------------------------------------------------------- layer plan
@dataclass(frozen=True)
class SubLayer:
    kind: str            # "attn" | "ssm" | "cross"
    window: int | None   # sliding window for attn
    use_moe: bool
    cap: float | None    # attn logit softcap


def block_plan(cfg: ArchConfig) -> list[SubLayer]:
    """Static layer composition of one block (same for every block)."""
    plan: list[SubLayer] = []
    for i in range(cfg.layers_per_block):
        use_moe = bool(cfg.n_experts) and (i % cfg.moe_every == cfg.moe_every - 1)
        if cfg.family == "encdec":
            # whisper decoder layer: self-attn + cross-attn + one MLP
            plan.append(SubLayer("attn_cross", None, use_moe, None))
        elif cfg.family == "ssm":
            plan.append(SubLayer("ssm", None, False, None))
        elif cfg.family == "hybrid":
            is_attn = i == cfg.layers_per_block - 1
            plan.append(SubLayer("attn" if is_attn else "ssm",
                                 cfg.sliding_window, use_moe, None))
        elif cfg.family == "vlm" and cfg.cross_attn_period and i == 0:
            plan.append(SubLayer("cross", None, use_moe, None))
        elif cfg.local_global_period:
            local = i % cfg.local_global_period == 0
            plan.append(SubLayer("attn",
                                 cfg.sliding_window if local else None,
                                 use_moe, cfg.attn_logit_softcap))
        else:
            plan.append(SubLayer("attn", cfg.sliding_window, use_moe,
                                 cfg.attn_logit_softcap))
    return plan


# -------------------------------------------------------------------- init
def _init_sublayer(key, sub: SubLayer, cfg: ArchConfig, dt) -> Params:
    k1, k2 = jax.random.split(key)
    has_ffn = sub.use_moe or cfg.d_ff > 0
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if has_ffn:
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
    if sub.kind in ("attn", "cross", "attn_cross"):
        p["attn"] = init_attention(k1, cfg, dt)
        if sub.kind == "cross":
            p["xgate"] = jnp.zeros((), jnp.float32)  # gated residual (llama-vision)
        if sub.kind == "attn_cross":
            k1b = jax.random.fold_in(k1, 1)
            p["xattn"] = init_attention(k1b, cfg, dt)
            p["norm1x"] = jnp.zeros((cfg.d_model,), dt)
    else:
        p["ssm"] = init_ssm(k1, cfg, dt)
    if has_ffn:
        p["ffn"] = init_moe(k2, cfg, dt) if sub.use_moe else init_mlp(k2, cfg, dt)
    return p


def init_block(key, cfg: ArchConfig, dt) -> Params:
    plan = block_plan(cfg)
    keys = jax.random.split(key, len(plan))
    return {f"l{i}": _init_sublayer(keys[i], sub, cfg, dt)
            for i, sub in enumerate(plan)}


def _init_encoder_layer(key, cfg: ArchConfig, dt) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": jnp.zeros((cfg.d_model,), dt),
            "norm2": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attention(k1, cfg, dt),
            "ffn": init_mlp(k2, cfg, dt)}


def init_params(key, cfg: ArchConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    kE, kB, kH, kN, kEnc = jax.random.split(key, 5)
    V, D = cfg.vocab_size, cfg.d_model
    params: Params = {
        "embed": jax.random.normal(kE, (V, D), dt) * (D ** -0.5),
        "final_norm": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kH, (D, V), dt) * (D ** -0.5)
    bkeys = jax.random.split(kB, cfg.n_blocks)
    params["blocks"] = jax.vmap(lambda k: init_block(k, cfg, dt))(bkeys)
    if cfg.enc_layers:
        ekeys = jax.random.split(kEnc, cfg.enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_encoder_layer(k, cfg, dt))(ekeys)
        params["enc_norm"] = jnp.zeros((D,), dt)
    return params


def abstract_params(cfg: ArchConfig) -> Params:
    """Shape/dtype-only params (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ------------------------------------------------------------------ encoder
def encoder_forward(params, enc_embed, cfg: ArchConfig):
    """Whisper-style bidirectional encoder over stub frame embeddings."""

    def layer(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attention(lp["attn"], h, h, cfg, causal=False, window=None,
                          cap=None)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp(lp["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(layer), enc_embed, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------------ forward
def _apply_sublayer(x, lp, sub: SubLayer, cfg: ArchConfig, memory, q_offset=0):
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if sub.kind == "ssm":
        x = x + ssd_apply(lp["ssm"], h, cfg)
    elif sub.kind == "cross":
        att = attention(lp["attn"], h, memory, cfg, causal=False, window=None,
                        cap=None)
        x = x + jnp.tanh(lp["xgate"]).astype(x.dtype) * att
    else:
        x = x + attention(lp["attn"], h, h, cfg, causal=True,
                          window=sub.window, cap=sub.cap, q_offset=q_offset)
        if sub.kind == "attn_cross":
            hx = rms_norm(x, lp["norm1x"], cfg.norm_eps)
            x = x + attention(lp["xattn"], hx, memory, cfg, causal=False,
                              window=None, cap=None)
    if "ffn" not in lp:
        return x
    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    ffn = moe(lp["ffn"], h, cfg) if sub.use_moe else mlp(lp["ffn"], h)
    return x + ffn


def forward(params, tokens, cfg: ArchConfig, memory=None, remat: bool = True):
    """Full-sequence logits: tokens (B, S) int32 -> (B, S, V)."""
    plan = block_plan(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, batch_axes()[0], None, None)

    def block(x, bp):
        for i, sub in enumerate(plan):
            x = _apply_sublayer(x, bp[f"l{i}"], sub, cfg, memory)
        x = constrain(x, batch_axes()[0], None, None)
        return x, None

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(blk, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, batch_axes()[0], None, "model")


# ------------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, memory=None) -> Params:
    """Per-block decode caches. Attention sublayers get (B, S_cache, K, hd)
    rings (S_cache = window if SWA else max_seq); SSM sublayers get O(1)
    recurrent state; cross sublayers precompute nothing here (memory K/V are
    recomputed from the stub embeddings at prefill and stored)."""
    dt = jnp.dtype(cfg.dtype)
    plan = block_plan(cfg)
    K, hd = cfg.n_kv_heads, cfg.hd

    def one_block(_):
        cache: Params = {}
        for i, sub in enumerate(plan):
            if sub.kind == "ssm":
                cache[f"l{i}"] = init_ssm_cache(cfg, batch, dt)
            elif sub.kind == "cross":
                S = max(1, cfg.n_vision_tokens)
                cache[f"l{i}"] = {"k": jnp.zeros((batch, S, K, hd), dt),
                                  "v": jnp.zeros((batch, S, K, hd), dt)}
            else:
                S = min(sub.window, max_seq) if sub.window else max_seq
                c = {"k": jnp.zeros((batch, S, K, hd), dt),
                     "v": jnp.zeros((batch, S, K, hd), dt)}
                if sub.kind == "attn_cross":
                    Se = max(1, cfg.enc_seq)
                    c["xk"] = jnp.zeros((batch, Se, K, hd), dt)
                    c["xv"] = jnp.zeros((batch, Se, K, hd), dt)
                cache[f"l{i}"] = c
        return cache

    idx = jnp.arange(cfg.n_blocks)
    return {"blocks": jax.vmap(one_block)(idx), "pos": jnp.zeros((), jnp.int32)}


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


# ------------------------------------------------------------------- decode
def decode_step(params, cache, token, cfg: ArchConfig, memory=None):
    """One decode step: token (B, 1) int32, cache from init_cache/prefill.

    Returns (logits (B, V), new_cache)."""
    plan = block_plan(cfg)
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0)

    def block(x, scans):
        bp, bc = scans
        new_bc = dict(bc)
        for i, sub in enumerate(plan):
            lp, lc = bp[f"l{i}"], bc[f"l{i}"]
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if sub.kind == "ssm":
                out, new_lc = ssd_decode(lp["ssm"], h, lc, cfg)
                x = x + out
            elif sub.kind == "cross":
                att = _cross_decode(lp, h, lc, cfg)
                x = x + jnp.tanh(lp["xgate"]).astype(x.dtype) * att
                new_lc = lc
            else:
                out, nk, nv = decode_attention(lp["attn"], h, lc["k"], lc["v"],
                                               pos, cfg, window=sub.window,
                                               cap=sub.cap)
                x = x + out
                new_lc = dict(lc)
                new_lc.update(k=nk, v=nv)
                if sub.kind == "attn_cross":
                    hx = rms_norm(x, lp["norm1x"], cfg.norm_eps)
                    x = x + _cross_decode(
                        {"attn": lp["xattn"]}, hx,
                        {"k": lc["xk"], "v": lc["xv"]}, cfg)
            if "ffn" in lp:
                h = rms_norm(x, lp["norm2"], cfg.norm_eps)
                ffn = moe(lp["ffn"], h, cfg) if sub.use_moe else mlp(lp["ffn"], h)
                x = x + ffn
            new_bc[f"l{i}"] = new_lc
        return x, new_bc

    x, new_blocks = jax.lax.scan(block, x, (params["blocks"], cache["blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, {"blocks": new_blocks, "pos": pos + 1}


def _cross_decode(lp, h, lc, cfg):
    """Cross-attention against cached memory K/V (decode path)."""
    B = h.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"]).reshape(B, 1, H, hd)
    rep = H // K
    qh = q.reshape(B, K, rep, hd)
    scores = jnp.einsum("bkrh,bskh->bkrs", qh, lc["k"]).astype(jnp.float32)
    probs = jax.nn.softmax(scores * (hd ** -0.5), axis=-1).astype(h.dtype)
    out = jnp.einsum("bkrs,bskh->bkrh", probs, lc["v"]).reshape(B, 1, H * hd)
    return jnp.einsum("bsx,xy->bsy", out, lp["attn"]["wo"])


# ------------------------------------------------------------------ prefill
def prefill(params, tokens, cfg: ArchConfig, memory=None, max_seq=None):
    """Process a prompt, returning (last-position logits, filled caches).

    Caches are built by re-projecting K/V per block (the attention itself is
    the chunked path from `forward`). SSM blocks return their final state.
    """
    B, S = tokens.shape
    max_seq = max_seq or S
    plan = block_plan(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, batch_axes()[0], None, None)
    dt = jnp.dtype(cfg.dtype)
    K, hd = cfg.n_kv_heads, cfg.hd

    def block(x, bp):
        cache: Params = {}
        for i, sub in enumerate(plan):
            lp = bp[f"l{i}"]
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if sub.kind == "ssm":
                out, st = ssd_apply(lp["ssm"], h, cfg, return_state=True)
                x = x + out
                cache[f"l{i}"] = st
            elif sub.kind == "cross":
                att = attention(lp["attn"], h, memory, cfg, causal=False,
                                window=None, cap=None)
                x = x + jnp.tanh(lp["xgate"]).astype(x.dtype) * att
                mk = jnp.einsum("bsd,dh->bsh", memory, lp["attn"]["wk"])
                mv = jnp.einsum("bsd,dh->bsh", memory, lp["attn"]["wv"])
                Sm = memory.shape[1]
                cache[f"l{i}"] = {"k": mk.reshape(B, Sm, K, hd).astype(dt),
                                  "v": mv.reshape(B, Sm, K, hd).astype(dt)}
            else:
                x = x + attention(lp["attn"], h, h, cfg, causal=True,
                                  window=sub.window, cap=sub.cap)
                # re-project K/V into the ring cache layout
                kf = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"])
                vf = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"])
                if cfg.qkv_bias:
                    kf, vf = kf + lp["attn"]["bk"], vf + lp["attn"]["bv"]
                from repro.models.layers import rope as _rope
                kf = _rope(kf.reshape(B, S, K, hd),
                           jnp.arange(S, dtype=jnp.int32), cfg.rope_theta)
                vf = vf.reshape(B, S, K, hd)
                Sc = min(sub.window, max_seq) if sub.window else max_seq
                if Sc >= S:
                    pad = ((0, 0), (0, Sc - S), (0, 0), (0, 0))
                    c = {"k": jnp.pad(kf, pad).astype(dt),
                         "v": jnp.pad(vf, pad).astype(dt)}
                else:  # SWA ring: keep the last window, rotated to slot order
                    tail_k, tail_v = kf[:, -Sc:], vf[:, -Sc:]
                    shift = S % Sc
                    c = {"k": jnp.roll(tail_k, shift, axis=1).astype(dt),
                         "v": jnp.roll(tail_v, shift, axis=1).astype(dt)}
                if sub.kind == "attn_cross":
                    hx = rms_norm(x, lp["norm1x"], cfg.norm_eps)
                    x = x + attention(lp["xattn"], hx, memory, cfg,
                                      causal=False, window=None, cap=None)
                    xk = jnp.einsum("bsd,dh->bsh", memory, lp["xattn"]["wk"])
                    xv = jnp.einsum("bsd,dh->bsh", memory, lp["xattn"]["wv"])
                    Sm = memory.shape[1]
                    c["xk"] = xk.reshape(B, Sm, K, hd).astype(dt)
                    c["xv"] = xv.reshape(B, Sm, K, hd).astype(dt)
                cache[f"l{i}"] = c
            if "ffn" in lp:
                h = rms_norm(x, lp["norm2"], cfg.norm_eps)
                ffn = moe(lp["ffn"], h, cfg) if sub.use_moe else mlp(lp["ffn"], h)
                x = x + ffn
        x = constrain(x, batch_axes()[0], None, None)
        return x, cache

    x, caches = jax.lax.scan(jax.checkpoint(block), x, params["blocks"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, {"blocks": caches, "pos": jnp.full((), S, jnp.int32)}
