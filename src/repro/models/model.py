"""Model entry points: family dispatch, loss, serve paths, input specs.

`input_specs(cfg, shape)` produces ShapeDtypeStruct stand-ins for every model
input of a (architecture x shape) cell — weak-type-correct, shardable, no
device allocation — consumed by both the launcher and the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.models.transformer import (abstract_cache, abstract_params,
                                      decode_step, encoder_forward, forward,
                                      init_cache, init_params, prefill)


def get_memory(params, batch: dict, cfg: ArchConfig):
    """Resolve the cross-attention memory for encdec/vlm families."""
    if cfg.family == "encdec":
        return encoder_forward(params, batch["enc_embed"], cfg)
    if cfg.family == "vlm":
        return batch["vision_embed"]
    return None


def model_forward(params, batch: dict, cfg: ArchConfig, remat: bool = True):
    memory = get_memory(params, batch, cfg)
    return forward(params, batch["tokens"], cfg, memory=memory, remat=remat)


def loss_fn(params, batch: dict, cfg: ArchConfig, remat: bool = True):
    """Token-mean cross entropy in f32 (stable logsumexp)."""
    logits = model_forward(params, batch, cfg, remat=remat).astype(jnp.float32)
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    return nll.mean()


def serve_prefill(params, batch: dict, cfg: ArchConfig, max_seq: int | None = None):
    memory = get_memory(params, batch, cfg)
    return prefill(params, batch["tokens"], cfg, memory=memory,
                   max_seq=max_seq)


def serve_decode(params, cache, batch: dict, cfg: ArchConfig):
    return decode_step(params, cache, batch["token"], cfg)


# --------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig | str) -> dict:
    """ShapeDtypeStruct stand-ins for the cell's model inputs.

    train   -> {"tokens","targets"} (+ modality stubs)
    prefill -> {"tokens"}           (+ modality stubs)
    decode  -> {"token"}            (cache specs come from cache_specs())
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((B, S), jnp.int32)
        specs["targets"] = _sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        specs["token"] = _sds((B, 1), jnp.int32)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["enc_embed"] = _sds((B, cfg.enc_seq, cfg.d_model), dt)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embed"] = _sds((B, cfg.n_vision_tokens, cfg.d_model), dt)
    return specs


def param_specs(cfg: ArchConfig):
    return abstract_params(cfg)


def cache_specs(cfg: ArchConfig, shape: ShapeConfig | str):
    if isinstance(shape, str):
        shape = SHAPES[shape]
    assert shape.kind == "decode"
    return abstract_cache(cfg, shape.global_batch, shape.seq_len)


# ------------------------------------------------------------ concrete build
def build_params(cfg: ArchConfig, seed: int = 0):
    return init_params(jax.random.PRNGKey(seed), cfg)


def build_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return init_cache(cfg, batch, max_seq)


def demo_batch(cfg: ArchConfig, batch: int, seq: int, kind: str = "train",
               seed: int = 0) -> dict:
    """Small concrete batch for smoke tests."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    dt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        out["token"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
    else:
        toks = rng.integers(0, cfg.vocab_size, (batch, seq + 1))
        out["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
        if kind == "train":
            out["targets"] = jnp.asarray(toks[:, 1:], jnp.int32)
    if cfg.family == "encdec" and kind != "decode":
        out["enc_embed"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_seq, cfg.d_model)), dt)
    if cfg.family == "vlm" and kind != "decode":
        out["vision_embed"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_vision_tokens, cfg.d_model)), dt)
    return out
