"""Mamba2 / SSD (state-space duality) blocks — for the `ssm` and `hybrid`
families (mamba2-780m, jamba-1.5-large).

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence via lax.scan over chunks), which is the
TPU-friendly formulation: all heavy compute is batched einsums over
(chunk x chunk) tiles, and the sequential dependency is only O(S / chunk).
Decode keeps an O(1) recurrent state per layer: (B, H, P, N) SSM state plus a
(B, conv-1, channels) convolution tail.

Tensor-parallel layout (head-parallel SSM TP): the input projection is split
into separately-shardable matrices — w_z / w_x (column-parallel over the
inner dim = H*P), w_dt (column-parallel over heads), w_BC (tiny, replicated)
— so z, x, dt, the SSM state and y are all sharded over heads on the 'model'
axis with no mid-layer resharding; w_out is row-parallel (one all-reduce per
layer, same as attention's wo).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import batch_axes, constrain, rms_norm

_G = 1  # B/C projection groups (Mamba2 default n_groups=1)


def ssm_dims(cfg) -> tuple[int, int, int, int]:
    inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = inner // P
    N = cfg.ssm_state
    return inner, H, P, N


def init_ssm(key, cfg, layer_dtype) -> dict:
    D = cfg.d_model
    inner, H, P, N = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (D, inner), layer_dtype) * s,
        "w_x": jax.random.normal(ks[1], (D, inner), layer_dtype) * s,
        "w_BC": jax.random.normal(ks[2], (D, 2 * _G * N), layer_dtype) * s,
        "w_dt": jax.random.normal(ks[3], (D, H), layer_dtype) * s,
        "conv_x": jax.random.normal(ks[4], (cfg.ssm_conv, inner), layer_dtype) * 0.1,
        "conv_bx": jnp.zeros((inner,), layer_dtype),
        "conv_BC": jax.random.normal(ks[5], (cfg.ssm_conv, 2 * _G * N),
                                     layer_dtype) * 0.1,
        "conv_bBC": jnp.zeros((2 * _G * N,), layer_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((inner,), layer_dtype),
        "w_out": jax.random.normal(ks[2], (inner, D), layer_dtype) * (inner ** -0.5),
    }


def _causal_conv(u, conv_w, conv_b):
    """Depthwise causal conv1d over (B, S, C) with kernel (W, C)."""
    W = conv_w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + u.shape[1], :] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b)


def ssd_apply(params, x_in, cfg, chunk: int = 128, return_state: bool = False):
    """Full-sequence SSD. x_in: (B, S, D) -> (B, S, D) [, decode cache]."""
    Bsz, S, Dm = x_in.shape
    inner, H, P, N = ssm_dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x_in, params["w_z"])
    x_raw = jnp.einsum("bsd,di->bsi", x_in, params["w_x"])
    BC_raw = jnp.einsum("bsd,dn->bsn", x_in, params["w_BC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x_in, params["w_dt"])

    xc = _causal_conv(x_raw, params["conv_x"], params["conv_bx"])
    BCc = _causal_conv(BC_raw, params["conv_BC"], params["conv_bBC"])
    x = xc.reshape(Bsz, S, H, P)
    Bm = BCc[..., : _G * N].reshape(Bsz, S, _G, N)
    Cm = BCc[..., _G * N :].reshape(Bsz, S, _G, N)
    x = constrain(x, batch_axes()[0], None, "model", None)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                                         # (H,)
    a = dt * A[None, None, :]                                             # log-decay

    if S % chunk != 0:
        chunk = S  # smoke-test sizes
    nc = S // chunk
    ar = a.reshape(Bsz, nc, chunk, H)
    dtr = dt.reshape(Bsz, nc, chunk, H)
    xr = x.reshape(Bsz, nc, chunk, H, P)
    Br = Bm.reshape(Bsz, nc, chunk, _G, N)
    Cr = Cm.reshape(Bsz, nc, chunk, _G, N)

    cum = jnp.cumsum(ar, axis=2)                    # (B,nc,Q,H)
    total = cum[:, :, -1, :]                        # (B,nc,H)

    # ---- intra-chunk (quadratic within chunk) ----
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    iq = np.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])
    # mask in log-space BEFORE exp: exp of masked (positive) entries would be
    # inf and poison the backward pass through jnp.where.
    li = jnp.where(causal[None, None, :, :, None], li, -1e30)
    L = jnp.exp(li)
    cb = jnp.einsum("bcqgn,bckgn->bcqkg", Cr, Br)        # (B,nc,Q,Q,G)
    att = cb[..., 0]                                     # G == 1: (B,nc,Q,Q)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp",
                         att, L, dtr, xr)

    # ---- chunk states ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)   # (B,nc,Q,H)
    states = jnp.einsum("bckh,bckh,bckn,bckhp->bchpn",
                        decay_to_end, dtr, Br[:, :, :, 0, :], xr)

    # ---- inter-chunk recurrence ----
    def scan_fn(carry, inp):
        st, tot = inp
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.astype(jnp.float32).swapaxes(0, 1), total.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)             # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         Cr[:, :, :, 0, :], prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + params["D"][None, None, :, None] * x
    y = y.reshape(Bsz, S, inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"])
    if return_state:
        W = params["conv_x"].shape[0]
        if S >= W - 1:
            tail_x = x_raw[:, S - (W - 1):, :]
            tail_BC = BC_raw[:, S - (W - 1):, :]
        else:
            tail_x = jnp.pad(x_raw, ((0, 0), (W - 1 - S, 0), (0, 0)))
            tail_BC = jnp.pad(BC_raw, ((0, 0), (W - 1 - S, 0), (0, 0)))
        return out, {"state": final_state,
                     "conv_x": tail_x.astype(x_in.dtype),
                     "conv_BC": tail_BC.astype(x_in.dtype)}
    return out


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    inner, H, P, N = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, inner), dtype),
        "conv_BC": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * _G * N), dtype),
    }


def ssd_decode(params, x_in, cache, cfg):
    """One-token recurrent step. x_in: (B, 1, D) -> (B, 1, D), new cache."""
    Bsz = x_in.shape[0]
    inner, H, P, N = ssm_dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x_in, params["w_z"])[:, 0]
    x_raw = jnp.einsum("bsd,di->bsi", x_in, params["w_x"])[:, 0]
    BC_raw = jnp.einsum("bsd,dn->bsn", x_in, params["w_BC"])[:, 0]
    dt_raw = jnp.einsum("bsd,dh->bsh", x_in, params["w_dt"])[:, 0]

    win_x = jnp.concatenate([cache["conv_x"], x_raw[:, None, :]], axis=1)
    win_BC = jnp.concatenate([cache["conv_BC"], BC_raw[:, None, :]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_x, params["conv_x"])
                     + params["conv_bx"])
    BCc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_BC, params["conv_BC"])
                      + params["conv_bBC"])
    x = xc.reshape(Bsz, H, P)
    Bm = BCc[..., : _G * N].reshape(Bsz, N)
    Cm = BCc[..., _G * N :].reshape(Bsz, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])                                      # (B,H)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bsz, inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, params["w_out"])[:, None, :]
    return out, {"state": state, "conv_x": win_x[:, 1:, :],
                 "conv_BC": win_BC[:, 1:, :]}
