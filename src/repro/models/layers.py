"""Core neural layers: RMSNorm, RoPE, chunked GQA attention (SWA / softcap /
bias / cross / decode), SwiGLU MLP, and capacity-based MoE.

All layers are pure functions over param pytrees (no module framework —
params are nested dicts, init fns mirror apply fns). Sharding is injected via
``constrain`` — a with_sharding_constraint that no-ops outside a mesh context,
so the same code serves CPU smoke tests and the 512-device dry-run.

Attention is *query-chunked*: scores are materialised one (chunk_q, S) slab
at a time via lax.scan over query blocks — O(S·chunk) live memory instead of
O(S²), which is what makes the 32k prefill cells compile inside a v5e HBM
budget. Masks are computed from index arithmetic (never a (S, S) tensor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------- sharding
_MESH_CTX: list = [None]  # set by repro.distributed.sharding.use_mesh


def set_mesh_context(mesh) -> None:
    _MESH_CTX[0] = mesh


def constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """with_sharding_constraint(P(*axes)) iff a mesh context is active."""
    mesh = _MESH_CTX[0]
    if mesh is None:
        return x
    spec = []
    for a in axes:
        if a is None or (isinstance(a, str) and a in mesh.axis_names):
            spec.append(a)
        elif isinstance(a, tuple):
            spec.append(tuple(n for n in a if n in mesh.axis_names) or None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec)))


def batch_axes(mesh=None) -> tuple:
    """The composite data-parallel axis set present in the ambient mesh."""
    mesh = mesh or _MESH_CTX[0]
    if mesh is None:
        return (None,)
    return (tuple(a for a in ("pod", "data") if a in mesh.axis_names),)


def _mesh_axis_size(name: str) -> int:
    mesh = _MESH_CTX[0]
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


# ----------------------------------------------------------------- helpers
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg, layer_dtype) -> dict:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": jax.random.normal(k1, (D, H * hd), layer_dtype) * s,
        "wk": jax.random.normal(k2, (D, K * hd), layer_dtype) * s,
        "wv": jax.random.normal(k3, (D, K * hd), layer_dtype) * s,
        "wo": jax.random.normal(k4, (H * hd, D), layer_dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), layer_dtype)
        p["bk"] = jnp.zeros((K * hd,), layer_dtype)
        p["bv"] = jnp.zeros((K * hd,), layer_dtype)
    return p


def _attend_block(q, k, v, qpos, kpos, causal, window, cap):
    """Scores for one q chunk against full K/V. q: (B,Qc,H,hd),
    k/v: (B,S,K,hd) — GQA repeats kv heads on the fly."""
    B, Qc, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    rep = H // K
    qh = q.reshape(B, Qc, K, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qh, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = softcap(scores, cap)
    mask = jnp.ones((Qc, S), dtype=bool)
    dq = qpos[:, None]
    dk = kpos[None, :]
    if causal:
        mask &= dk <= dq
    if window is not None:
        mask &= dk > dq - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(B, Qc, H, hd)


def attention(params, x, kv_x, cfg, *, causal: bool, window: int | None,
              cap: float | None, q_offset=0, chunk_q: int | None = None,
              positions_k=None) -> jnp.ndarray:
    """Chunked multi-head attention.

    x: (B, Sq, D) queries source; kv_x: (B, Sk, D) keys/values source
    (kv_x is x for self-attention, encoder/vision memory for cross).
    q_offset: absolute position of x[0] (decode/prefill continuation).
    """
    B, Sq, D = x.shape
    Sk = kv_x.shape[1]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", kv_x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", kv_x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, Sk, K, hd)
    v = v.reshape(B, Sk, K, hd)
    qpos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kpos = (positions_k if positions_k is not None
            else jnp.arange(Sk, dtype=jnp.int32))
    if causal:  # RoPE only on self-attention paths
        q = rope(q, qpos, cfg.rope_theta)
        k = rope(k, kpos, cfg.rope_theta)
    # Head sharding must respect divisibility: a partial shard of K forces
    # GSPMD into a K x head_dim 2D tiling, and a sharded *contracting*
    # head_dim turns the scores einsum into a full all-reduce of the
    # (B,H,Qc,S) scores — catastrophic at 32k prefill. Rule: shard Q heads
    # when H divides the axis; shard KV heads only when K divides it,
    # otherwise replicate K/V (standard GQA tensor-parallel layout).
    if chunk_q is None:
        # Single-block attention up to 8k (the scan's per-chunk DUS stacking
        # costs more traffic than the scores it saves — §Perf C.3); scan over
        # 1k q-chunks beyond that to bound live score memory at 32k prefill.
        chunk_q = Sq if Sq <= 8192 else 1024
    msize = _mesh_axis_size("model")
    q_head = "model" if H % max(msize, 1) == 0 else None
    kv_head = "model" if K % max(msize, 1) == 0 else None
    if H == K and q_head is None:
        # MHA with non-divisible heads (qwen1.5 H=K=20): q and k tile
        # identically, so GSPMD's partial K x hd tiling is consistent across
        # the whole layer — replicating instead costs 16x attention compute
        # (measured 11.2 -> 91.8 s memory on qwen1.5 prefill).
        q_head = kv_head = "model"
    # H not divisible (gemma2 H=8, qwen1.5 H=20): fall back to *sequence-
    # parallel attention* — shard the query-sequence dim over 'model'
    # instead of replicating all heads on every device. Only valid on the
    # single-block path: a lax.scan over a seq-sharded axis forces GSPMD to
    # re-gather every iteration (measured 8x regression on qwen1.5 prefill —
    # §Perf follow-up).
    q_seq = ("model" if q_head is None and Sq <= chunk_q
             and Sq % max(msize, 1) == 0 and Sq > msize else None)
    q = constrain(q, batch_axes()[0], q_seq, q_head, None)
    k = constrain(k, batch_axes()[0], None, kv_head, None)
    v = constrain(v, batch_axes()[0], None, kv_head, None)

    if Sq % chunk_q != 0:
        # non-multiple sequence (e.g. whisper's 1500 frames): largest
        # divisor <= chunk_q keeps the scan exact without padding
        chunk_q = next(c for c in range(min(chunk_q, Sq), 0, -1) if Sq % c == 0)
    if Sq <= chunk_q:
        out = _attend_block(q, k, v, qpos, kpos, causal, window, cap)
    else:
        nq = Sq // chunk_q  # noqa: F841  (used below)
        qc = q.reshape(B, nq, chunk_q, H, hd).transpose(1, 0, 2, 3, 4)
        qp = qpos.reshape(nq, chunk_q)

        def step(_, qi):
            qb, qpb = qi
            return None, _attend_block(qb, k, v, qpb, kpos, causal, window, cap)

        _, blocks = jax.lax.scan(step, None, (qc, qp))
        out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    out = constrain(out, batch_axes()[0], None,
                    "model" if H % max(_mesh_axis_size("model"), 1) == 0
                    else None, None)
    return jnp.einsum("bsx,xy->bsy", out.reshape(B, Sq, H * hd), params["wo"])


def decode_attention(params, x, cache_k, cache_v, pos, cfg, *,
                     window: int | None, cap: float | None):
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache_k/v: (B, S_max, K, hd); pos: int32 scalar (current
    write index). Returns (out (B,1,D), new_k, new_v)."""
    B, _, D = x.shape
    S = cache_k.shape[1]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, K, hd)
    v = v.reshape(B, 1, K, hd)
    posv = jnp.full((1,), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    # Match the cache layout (head_dim over 'model') so the cache update and
    # the attention dots never reshard the (B, S_cache, K, hd) tensors; the
    # scores' partial-sum all-reduce over sharded hd is (B,H,S) — tiny next
    # to a per-layer cache copy (§Perf decode follow-up).
    hd_ax = "model" if hd % max(_mesh_axis_size("model"), 1) == 0 else None
    dpn = _mesh_axis_size("data") * _mesh_axis_size("pod")
    bax = batch_axes()[0] if B % max(dpn, 1) == 0 else None
    q = constrain(q, bax, None, None, hd_ax)
    k = constrain(k, bax, None, None, hd_ax)
    v = constrain(v, bax, None, None, hd_ax)
    # SWA: rotate the physical cache slot; full: slot == pos.
    slot = pos % S if window is not None else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    rep = H // K
    qh = q.reshape(B, K, rep, hd)
    scores = jnp.einsum("bkrh,bskh->bkrs", qh, cache_k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = softcap(scores, cap)
    kidx = jnp.arange(S, dtype=jnp.int32)
    if window is not None:
        valid = (kidx < jnp.minimum(pos + 1, S))
    else:
        valid = kidx <= pos
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrs,bskh->bkrh", probs, cache_v).reshape(B, 1, H * hd)
    return jnp.einsum("bsx,xy->bsy", out, params["wo"]), cache_k, cache_v


# ----------------------------------------------------------------- MLP / MoE
def init_mlp(key, cfg, layer_dtype, d_ff=None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = D ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (D, F), layer_dtype) * s,
        "w_up": jax.random.normal(k2, (D, F), layer_dtype) * s,
        "w_down": jax.random.normal(k3, (F, D), layer_dtype) * (F ** -0.5),
    }


def mlp(params, x) -> jnp.ndarray:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = constrain(h, batch_axes()[0], None, "model")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def init_moe(key, cfg, layer_dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    return {
        "router": jax.random.normal(k1, (D, E), jnp.float32) * s,
        "w_gate": jax.random.normal(k2, (E, D, F), layer_dtype) * s,
        "w_up": jax.random.normal(k3, (E, D, F), layer_dtype) * s,
        "w_down": jax.random.normal(k4, (E, F, D), layer_dtype) * (F ** -0.5),
    }


def moe(params, x, cfg) -> jnp.ndarray:
    """Capacity-bucketed top-k MoE (GShard-style, scatter/gather form).

    Tokens pick top_k experts; assignments beyond each expert's capacity are
    dropped (standard capacity-factor semantics). Expert weights are sharded
    over 'model' when E divides the axis (EP); otherwise F is sharded (TP).
    The (E, C, D) expert buffers carry the all-to-all in SPMD partitioning.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(np.ceil(T * K / E * cfg.capacity_factor))
    C = max(8, min(C, T))
    flat_expert = expert_idx.reshape(-1)                      # (T*K,)
    # position of each assignment within its expert's bucket, via sort
    # (O(T log T); the one-hot cumsum alternative materialises a (T, E)
    # tensor and is catastrophically memory-bound at 1M tokens x 128 experts)
    A = flat_expert.shape[0]
    sorted_idx = jnp.argsort(flat_expert)
    sorted_exp = flat_expert[sorted_idx]
    counts = jnp.zeros((E,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.cumsum(counts) - counts                      # (E,)
    pos_sorted = jnp.arange(A, dtype=jnp.int32) - starts[sorted_exp]
    slot = jnp.zeros((A,), jnp.int32).at[sorted_idx].set(pos_sorted)
    keep = slot < C
    msize = _mesh_axis_size("model")
    dsize = _mesh_axis_size("data")
    ep = "model" if E % max(msize, 1) == 0 else None          # EP vs expert-TP
    # When experts can't shard over 'model' (E < axis, e.g. mixtral's 8),
    # shard the *capacity* dim over 'data' so expert FFN compute still
    # divides over the full mesh (C/data x F/model). When EP applies
    # (E % model == 0) tokens are already divided E-ways and an extra
    # capacity shard just adds a 2D dispatch all-to-all (measured 2.5x
    # collective regression on qwen3 prefill — EXPERIMENTS.md §Perf A.2).
    cap_ax = ("data" if ep is None and C % max(dsize, 1) == 0 else None)
    # Dispatch as scatter-of-indices + gather-of-payload: scattering the
    # (T*K, D) payload directly makes GSPMD all-gather the full f32 update
    # tensor to every expert shard (measured 3.3e12 B x48 on qwen3 prefill —
    # §Perf A.3). Scattering only the s32 slot->token map (E x C ints) and
    # gathering rows of xt afterwards moves 2048x fewer bytes through the
    # dispatch collective; dropped assignments land in dump column C.
    # At decode-sized T the indirection costs more than it saves (measured
    # 0.57->0.80 s memory regression on qwen3 decode) — scatter the payload
    # directly there.
    wslot = jnp.where(keep, slot, C)
    if T >= 4096:
        assign_tok = jnp.arange(A, dtype=jnp.int32) // K      # source token
        slot_tok = jnp.full((E, C + 1), T, dtype=jnp.int32)   # T = pad row
        slot_tok = slot_tok.at[flat_expert, wslot].set(assign_tok, mode="drop")
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), x.dtype)], axis=0)
        eb = xt_pad[slot_tok[:, :C]]                          # (E, C, D)
    else:
        src = jnp.repeat(xt, K, axis=0)                       # (T*K, D)
        buf = jnp.zeros((E, C + 1, D), dtype=x.dtype)
        eb = buf.at[flat_expert, wslot].set(src, mode="drop")[:, :C]
    eb = constrain(eb, ep, cap_ax, None)
    idx2 = jnp.stack([flat_expert, jnp.minimum(slot, C - 1)], axis=-1)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, params["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_e = constrain(out_e, ep, cap_ax, None)
    gathered = out_e[idx2[:, 0], idx2[:, 1]]                  # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    combined = weighted.reshape(T, K, D).sum(axis=1)
    return combined.reshape(B, S, D)
