"""Architecture configuration schema covering all 10 assigned families.

One frozen dataclass describes every architecture; family-specific switches
(SWA, local/global alternation, softcaps, MoE, SSD, cross-attention,
encoder-decoder) compose rather than fork the model code. Block periodicity
(`layers_per_block`) drives the scan-over-blocks structure that keeps HLO
size and compile time bounded at 100+ layers.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None     # default d_model // n_heads

    # attention flavour
    qkv_bias: bool = False                      # qwen1.5
    sliding_window: int | None = None           # SWA (danube, mixtral)
    local_global_period: int = 0                # gemma2: 2 -> alternate local/global
    attn_logit_softcap: float | None = None     # gemma2
    final_logit_softcap: float | None = None    # gemma2
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # jamba: MoE on every 2nd layer
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_period: int = 0             # jamba: 1 attention layer per this many

    # encoder-decoder / multimodal
    enc_layers: int = 0              # whisper encoder depth
    enc_seq: int = 0                 # whisper: 1500 frames (stub frontend)
    cross_attn_period: int = 0       # llama-vision: 1 cross-attn block per 5
    n_vision_tokens: int = 0         # vlm stub: precomputed patch embeddings

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # --- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def layers_per_block(self) -> int:
        """Heterogeneous layer period: the scan unit."""
        if self.family == "hybrid" and self.attn_period:
            return self.attn_period
        if self.family == "vlm" and self.cross_attn_period:
            return self.cross_attn_period
        if self.local_global_period:
            return self.local_global_period
        if self.n_experts and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_blocks(self) -> int:
        lpb = self.layers_per_block
        assert self.n_layers % lpb == 0, (self.name, self.n_layers, lpb)
        return self.n_layers // lpb

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k runnability: bounded per-token cost (SSM state or SWA).

        Pure full-attention archs are skipped per spec (DESIGN.md §5).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window is not None and not self.local_global_period:
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive side

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, K = self.hd, self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_params() -> int:
            p = D * (H * hd) + 2 * D * (K * hd) + (H * hd) * D
            if self.qkv_bias:
                p += (H + 2 * K) * hd
            return p + 2 * D                     # norms

        def mlp_params() -> int:
            return 3 * D * F + D                 # swiglu + norm

        def moe_params() -> int:
            return self.n_experts * 3 * D * F + D * self.n_experts + D

        def ssm_params() -> int:
            inner = self.ssm_expand * D
            nh = inner // self.ssm_head_dim
            # in_proj -> (z, x, B, C, dt), out_proj, conv, A/D/dt_bias, norm
            p = D * (2 * inner + 2 * self.ssm_state + nh)
            p += inner * D + self.ssm_conv * (inner + 2 * self.ssm_state)
            p += 3 * nh + 2 * D
            return p

        for layer in range(self.n_layers):
            if self.family == "ssm":
                total += ssm_params()
                continue
            if self.family == "hybrid":
                is_attn = (layer % self.attn_period) == (self.attn_period - 1)
                total += attn_params() if is_attn else ssm_params()
                if self.n_experts and (layer % self.moe_every == self.moe_every - 1):
                    total += moe_params()
                else:
                    total += mlp_params()
                continue
            total += attn_params()
            if self.n_experts and (layer % self.moe_every == self.moe_every - 1):
                total += moe_params()
            else:
                total += mlp_params()
        if self.family == "vlm" and self.cross_attn_period:
            # cross-attn blocks add one attention per block
            total += (self.n_layers // self.cross_attn_period) * attn_params()
        if self.enc_layers:
            total += self.enc_layers * (attn_params() + mlp_params())
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        full = self.n_params()
        moe_layers = sum(1 for k in range(self.n_layers)
                         if k % self.moe_every == self.moe_every - 1)
        per_expert = 3 * self.d_model * self.d_ff
        inactive = moe_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=max(2, self.layers_per_block) if self.layers_per_block > 1
            else 2,
            d_model=64, n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads)),
            d_ff=128, vocab_size=512, head_dim=16,
        )
        if self.n_experts:
            changes["n_experts"] = max(4, self.top_k)
            changes["top_k"] = min(2, self.top_k)
        if self.family in ("ssm", "hybrid"):
            changes["ssm_state"] = 16
            changes["ssm_head_dim"] = 16
        if self.enc_layers:
            changes["enc_layers"] = 2
            changes["enc_seq"] = 16
        if self.n_vision_tokens:
            changes["n_vision_tokens"] = 8
        if self.sliding_window:
            changes["sliding_window"] = 8
        if self.family == "hybrid" and self.attn_period:
            changes["n_layers"] = self.attn_period
        if self.family == "vlm" and self.cross_attn_period:
            changes["n_layers"] = self.cross_attn_period
        if self.local_global_period:
            changes["n_layers"] = 2 * self.local_global_period
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
