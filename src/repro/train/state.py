"""Train state pytree + abstract/sharded construction."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.transformer import abstract_params, init_params
from repro.optim.adamw import AdamWConfig, abstract_state, init_state


def make_state(cfg: ArchConfig, opt: AdamWConfig, seed: int = 0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return {"params": params, "opt": init_state(params, opt),
            "step": jnp.zeros((), jnp.int32)}


def make_abstract_state(cfg: ArchConfig, opt: AdamWConfig):
    params = abstract_params(cfg)
    return {"params": params, "opt": abstract_state(params, opt),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_shardings(abstract, mesh, cfg: ArchConfig, fsdp: bool = False):
    """Param shardings + ZeRO-1 optimizer shardings (data-axis extension)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import (_extend_fsdp, param_specs_tree)

    pspecs = param_specs_tree(abstract["params"], mesh, cfg, fsdp)

    def opt_spec(path, leaf):
        # Quantized moment leaves ('q'/'scale') get simple ZeRO row sharding.
        keys = [str(getattr(p, "key", "")) for p in path]
        if keys and keys[-1] in ("q", "scale"):
            spec = P("data" if leaf.shape[0] % mesh.shape["data"] == 0 else None)
            return NamedSharding(mesh, P(*(list(spec) + [None] * (leaf.ndim - 1))))
        return None  # filled from param spec below

    def build(pspec_leaf, aleaf):
        spec = _extend_fsdp(pspec_leaf, aleaf.shape, mesh, "data")
        return NamedSharding(mesh, spec)

    # moments mirror the param tree structure (possibly with q/scale dicts)
    def moment_shardings(moments):
        def rule(path, leaf):
            s = opt_spec(path, leaf)
            if s is not None:
                return s
            # find matching param spec by path prefix (strip m/v root)
            sub = pspecs
            for p in path:
                k = getattr(p, "key", None)
                if isinstance(sub, dict) and k in sub:
                    sub = sub[k]
            spec = sub if isinstance(sub, P) else P(*([None] * leaf.ndim))
            return build(spec, leaf)

        return jax.tree_util.tree_map_with_path(rule, moments)

    return {
        "params": jax.tree.map(
            lambda s, _leaf: NamedSharding(mesh, s), pspecs, abstract["params"],
            is_leaf=lambda x: isinstance(x, P)),
        "opt": {
            "m": moment_shardings(abstract["opt"]["m"]),
            "v": moment_shardings(abstract["opt"]["v"]),
            "count": NamedSharding(mesh, P()),
        },
        "step": NamedSharding(mesh, P()),
    }
