"""repro subpackage."""
