"""jit-able train / serve step builders.

train_step: microbatched gradient accumulation (lax.scan), remat policy,
AdamW update, cosine schedule. serve_* wrap prefill/decode. All builders
return pure functions ready for jax.jit with explicit in/out shardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.model import loss_fn, serve_decode, serve_prefill
from repro.optim.adamw import AdamWConfig, apply_updates, cosine_schedule


def make_train_step(cfg: ArchConfig, opt: AdamWConfig,
                    microbatches: int = 1, remat: bool = True,
                    schedule_total: int = 10_000):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def single_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch, cfg, remat)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            loss, grads = single_grads(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbatch = jax.tree.map(reshape, batch)

            def acc(carry, mb):
                loss_sum, gacc = carry
                loss_mb, g = single_grads(params, mb)
                return (loss_sum + loss_mb,
                        jax.tree.map(jnp.add, gacc, g)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, gsum), _ = jax.lax.scan(acc, (jnp.float32(0), zero),
                                               mbatch)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)

        lr_scale = cosine_schedule(state["step"], total=schedule_total)
        new_params, new_opt = apply_updates(params, grads, state["opt"], opt,
                                            lr_scale)
        metrics = {"loss": loss.astype(jnp.float32),
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step


def make_prefill_step(cfg: ArchConfig, max_seq: int | None = None):
    def prefill_step(params, batch):
        return serve_prefill(params, batch, cfg, max_seq=max_seq)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def step(params, cache, batch):
        return serve_decode(params, cache, batch, cfg)

    return step
