"""Two-tier Longest Prefix Matching (paper §3.4).

Dynamic structures (used during the training phase, supports insertion):

* short patterns (<= 8 bytes): hash map keyed by ``(packed u64, length)``.
* long patterns  (>  8 bytes): hash map keyed by the packed 8-byte prefix;
  each value is a *bucket* — a list of ``(suffix bytes, token_id)`` kept in
  descending suffix-length order so the scan can stop at the first match
  (Algorithm 1, lines 2-12).

The static (post-training, read-only) flattening into parallel numpy arrays —
the array-hash analogue of the paper's perfect-hash + inline-suffix layout —
lives in :mod:`repro.core.packed` and is consumed by the numpy fast paths and
by the JAX/Pallas kernels.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.packing import pack_u64


@dataclass
class DynamicLPM:
    """Insertable two-tier LPM used by the OnPair training phase."""

    #: (packed u64 value, length) -> token id, for entries of length 1..8.
    short_map: dict[tuple[int, int], int] = field(default_factory=dict)
    #: packed 8-byte prefix -> [(suffix bytes, token id)] sorted by len desc.
    long_buckets: dict[int, list[tuple[bytes, int]]] = field(default_factory=dict)

    def insert(self, entry: bytes, token_id: int) -> None:
        n = len(entry)
        if n <= 8:
            self.short_map[(pack_u64(entry, 0, n), n)] = token_id
            return
        prefix = pack_u64(entry, 0, 8)
        suffix = entry[8:]
        bucket = self.long_buckets.setdefault(prefix, [])
        # Keep descending length order; ties keep insertion order (older first,
        # matching "return the first match found" semantics for equal lengths).
        pos = 0
        slen = len(suffix)
        while pos < len(bucket) and len(bucket[pos][0]) >= slen:
            pos += 1
        bucket.insert(pos, (suffix, token_id))

    def bucket_size(self, entry: bytes) -> int:
        """Current size of the bucket the (long) entry would land in."""
        if len(entry) <= 8:
            return 0
        return len(self.long_buckets.get(pack_u64(entry, 0, 8), ()))

    def search(self, data: bytes, pos: int) -> tuple[int, int]:
        """Algorithm 1: longest dictionary match at ``data[pos:]``.

        Returns ``(token_id, match_length)``. Because the dictionary is seeded
        with all 256 single bytes, a 1-byte match always exists.
        """
        rem = len(data) - pos
        # --- long pattern matching (lines 2-12) ---
        if rem > 8:
            prefix = pack_u64(data, pos, 8)
            bucket = self.long_buckets.get(prefix)
            if bucket is not None:
                after = pos + 8
                for suffix, token_id in bucket:  # sorted by descending length
                    if data.startswith(suffix, after):
                        return token_id, 8 + len(suffix)
        # --- short pattern matching (lines 13-19) ---
        max_len = rem if rem < 8 else 8
        val = pack_u64(data, pos, max_len)
        for length in range(max_len, 0, -1):
            key = (val, length)
            token_id = self.short_map.get(key)
            if token_id is not None:
                return token_id, length
            # Little-endian packing: a length-1 prefix is the *low* bytes, so
            # shorten by masking off the current highest byte.
            val &= (1 << (8 * (length - 1))) - 1
        raise AssertionError("dictionary must contain all single bytes")

    def parse(self, data: bytes) -> list[int]:
        """Greedy longest-prefix tokenisation of one string (paper §3.3)."""
        out: list[int] = []
        pos = 0
        n = len(data)
        while pos < n:
            token_id, length = self.search(data, pos)
            out.append(token_id)
            pos += length
        return out


def lpm_from_entries(entries: list[bytes]) -> DynamicLPM:
    """Build a dynamic LPM over a full entry list (ids = list positions)."""
    lpm = DynamicLPM()
    for tid, entry in enumerate(entries):
        lpm.insert(entry, tid)
    return lpm


# ---------------------------------------------------------------------------
# Vectorised batch parsing over the static PackedDictionary arrays
# ---------------------------------------------------------------------------
# One shared table walk across a whole batch of strings: each outer iteration
# advances every still-active string by one token, with both LPM tiers probed
# as flat numpy gathers over the frozen open-addressing tables (the host
# analogue of the Pallas encode kernel's per-lane loop). Semantics are pinned
# byte-identical to DynamicLPM.parse.

_ARANGE16 = np.arange(16, dtype=np.int64)
_LENS8 = np.arange(8, 0, -1, dtype=np.int32)  # short-tier lengths, longest first


def _len_mask32(n: np.ndarray) -> np.ndarray:
    """Mask selecting the low ``clip(n, 0, 4)`` bytes of a packed u32."""
    nb = np.clip(n, 0, 4).astype(np.uint64)
    return ((np.uint64(1) << (nb * np.uint64(8))) - np.uint64(1)).astype(np.uint32)


_MLO8 = _len_mask32(_LENS8)       # low-word mask for each short length
_MHI8 = _len_mask32(_LENS8 - 4)   # high-word mask for each short length


def _mix32_vec(x: np.ndarray) -> np.ndarray:
    """Vectorised murmur-style finaliser; bit-identical to packed.mix32."""
    x = np.asarray(x, dtype=np.uint32).copy()
    np.multiply(x, np.uint32(0x85EBCA6B), out=x)
    np.bitwise_xor(x, x >> np.uint32(13), out=x)
    np.multiply(x, np.uint32(0xC2B2AE35), out=x)
    np.bitwise_xor(x, x >> np.uint32(16), out=x)
    return x


_MIXL8 = _mix32_vec(_LENS8.astype(np.uint32))  # pre-mixed short lengths
_MIXP = _MIXL8[0]                              # pre-mixed prefix length (8)

_U64_LO32 = np.uint64(0xFFFFFFFF)
_SHIFT32 = np.uint64(32)
# combined u64 masks: low u32 word = packed bytes 0..3, high = bytes 4..7
_M64S8 = _MLO8.astype(np.uint64) | (_MHI8.astype(np.uint64) << _SHIFT32)


def _k64_tables(pd):
    """u64-packed probe tables, built once per dictionary and cached on it:
    each probe round then gathers one u64 key word per 8 key bytes instead
    of two u32 halves. Key comparisons and hashes stay bit-identical — the
    u32 words are recovered by splitting before mixing."""
    t = getattr(pd, "_lpm_k64", None)
    if t is None:
        t = (pd.s_lo.astype(np.uint64) | (pd.s_hi.astype(np.uint64) << _SHIFT32),
             pd.p_lo.astype(np.uint64) | (pd.p_hi.astype(np.uint64) << _SHIFT32),
             pd.l_lo.astype(np.uint64) | (pd.l_hi.astype(np.uint64) << _SHIFT32),
             pd.l_lo2.astype(np.uint64) | (pd.l_hi2.astype(np.uint64) << _SHIFT32))
        pd._lpm_k64 = t
    return t


#: live-lane count below which a probe loop finishes scalar: a vector round
#: costs ~15 fixed-size numpy calls regardless of width, and measured round
#: traces show ~70% of rounds run under this width (collision tails)
_SCALAR_TAIL = 48


def _probe_flat(k, ln, mixlen, t_k, t_len, t_pay, probe_max: int):
    """Vectorised open-addressing lookup of many (key64, len) keys at once.

    Mirrors the scalar probe in packed._build_table: start at
    hash_key(lo, hi, len), walk linearly, stop on an empty slot (len == 0).
    Keys resolve independently; resolved lanes are compacted away each round
    so later probe rounds only touch the colliding tail, and once that tail
    is narrow the walk finishes as a per-lane scalar loop. Returns int32
    payloads, -1 where the key is absent.
    """
    n = k.size
    out = np.full(n, -1, dtype=np.int32)
    if n == 0:
        return out
    mask = np.uint32(t_len.size - 1)
    lo = (k & _U64_LO32).astype(np.uint32)
    hi = (k >> _SHIFT32).astype(np.uint32)
    slot = _mix32_vec(lo ^ _mix32_vec(hi ^ mixlen)) & mask
    idx = None  # None = all key positions still live
    for _ in range(probe_max):
        sl = t_len.take(slot)
        hit = (sl == ln) & (t_k.take(slot) == k)
        out[hit if idx is None else idx[hit]] = t_pay.take(slot[hit])
        keep = ~hit & (sl != 0)
        if not keep.any():
            break
        idx = np.nonzero(keep)[0] if idx is None else idx[keep]
        slot = (slot[keep] + np.uint32(1)) & mask
        k = k[keep]
        if isinstance(ln, np.ndarray) and ln.ndim:
            ln = ln[keep]
        if k.size <= _SCALAR_TAIL:
            ln_v = ln.tolist() if isinstance(ln, np.ndarray) and ln.ndim \
                else [int(ln)] * k.size
            m = int(mask)
            for j, (s, kk, lnj) in enumerate(
                    zip(slot.tolist(), k.tolist(), ln_v)):
                while True:
                    sl_j = int(t_len[s])
                    if sl_j == 0:
                        break
                    if sl_j == lnj and int(t_k[s]) == kk:
                        out[idx[j]] = t_pay[s]
                        break
                    s = (s + 1) & m
            return out
    return out


_LLEN8 = np.arange(16, 8, -1, dtype=np.int32)  # long lengths, longest first
_ML2 = _len_mask32(_LLEN8 - 8)    # window word 2 (bytes 8..11) mask per length
_MH2 = _len_mask32(_LLEN8 - 12)   # window word 3 (bytes 12..15) mask per length
_MIXLL8 = _mix32_vec(_LLEN8.astype(np.uint32))
_M64L2 = _ML2.astype(np.uint64) | (_MH2.astype(np.uint64) << _SHIFT32)


def _probe_flat_long(k1, k2, ln, mixlen, pd, t_k1, t_k2):
    """Open-addressing lookup of full 16-byte packed keys (long entries)."""
    n = k1.size
    out = np.full(n, -1, dtype=np.int32)
    if n == 0:
        return out
    t_len, t_pay = pd.l_len, pd.l_tok
    mask = np.uint32(t_len.size - 1)
    lo = (k1 & _U64_LO32).astype(np.uint32)
    hi = (k1 >> _SHIFT32).astype(np.uint32)
    lo2 = (k2 & _U64_LO32).astype(np.uint32)
    hi2 = (k2 >> _SHIFT32).astype(np.uint32)
    slot = _mix32_vec(
        lo ^ _mix32_vec(hi ^ _mix32_vec(lo2 ^ _mix32_vec(hi2 ^ mixlen)))) & mask
    idx = None
    for _ in range(pd.l_probe_max):
        sl = t_len.take(slot)
        hit = ((sl == ln) & (t_k1.take(slot) == k1) & (t_k2.take(slot) == k2))
        out[hit if idx is None else idx[hit]] = t_pay.take(slot[hit])
        keep = ~hit & (sl != 0)
        if not keep.any():
            break
        idx = np.nonzero(keep)[0] if idx is None else idx[keep]
        slot = (slot[keep] + np.uint32(1)) & mask
        k1 = k1[keep]
        k2 = k2[keep]
        ln = ln[keep]
        if k1.size <= _SCALAR_TAIL:
            m = int(mask)
            for j, (s, ka, kb, lnj) in enumerate(
                    zip(slot.tolist(), k1.tolist(), k2.tolist(), ln.tolist())):
                while True:
                    sl_j = int(t_len[s])
                    if sl_j == 0:
                        break
                    if sl_j == lnj and int(t_k1[s]) == ka \
                            and int(t_k2[s]) == kb:
                        out[idx[j]] = t_pay[s]
                        break
                    s = (s + 1) & m
            return out
    return out


def _long_exact(k1, k2, rem, pd, t_k1, t_k2):
    """Longest 9..16-byte match per row via 8 exact probes (variant16 only).

    Equivalent to the bucket scan: equal-length suffixes in a bucket are
    distinct byte strings, so at most one entry matches a given window at
    each length, and the longest valid length is the greedy answer."""
    A = k1.size
    k1_c = np.repeat(k1, 8)
    k2_c = (k2[:, None] & _M64L2[None, :]).ravel()
    ln = np.broadcast_to(_LLEN8, (A, 8)).ravel()
    mix = np.broadcast_to(_MIXLL8, (A, 8)).ravel()
    found = _probe_flat_long(k1_c, k2_c, ln, mix, pd, t_k1,
                             t_k2).reshape(A, 8)
    valid = (found >= 0) & (_LLEN8[None, :] <= rem[:, None])
    pick = np.argmax(valid, axis=1)
    ar = np.arange(A)
    ok = valid[ar, pick]
    tok = np.where(ok, found[ar, pick], np.int32(-1))
    ml = np.where(ok, _LLEN8[pick], 0).astype(np.int64)
    return tok, ml


def _short_tier(k1, rem, pd, t_s):
    """Longest short-tier match per row: all 8 candidate lengths probed as
    one flat key batch, then the longest valid one picked per row."""
    A = k1.size
    k_c = (k1[:, None] & _M64S8[None, :]).ravel()
    ln = np.broadcast_to(_LENS8, (A, 8)).ravel()
    mix = np.broadcast_to(_MIXL8, (A, 8)).ravel()
    found = _probe_flat(k_c, ln, mix, t_s, pd.s_len,
                        pd.s_tok, pd.s_probe_max).reshape(A, 8)
    valid = (found >= 0) & (_LENS8[None, :] <= rem[:, None])
    pick = np.argmax(valid, axis=1)  # first True along descending lengths
    ar = np.arange(A)
    if not valid[ar, pick].all():
        raise AssertionError("dictionary must contain all single bytes")
    return found[ar, pick], _LENS8[pick].astype(np.int64)


def _bucket_scan(pd, data, rows, pos, rem, lo2, hi2, bkt):
    """Find each row's first fitting suffix in its long-tier bucket.

    Every (row, bucket-slot) candidate pair is compared at once with masked
    packed equality; buckets store suffixes in descending length (ties in
    insertion order), so the first hit per row IS the DynamicLPM answer.
    Returns (token, match_len) with token == -1 where no suffix fits.
    """
    A = bkt.size
    start = pd.bucket_start[bkt].astype(np.int64)
    size = pd.bucket_size[bkt].astype(np.int64)
    tok = np.full(A, -1, dtype=np.int32)
    ml = np.zeros(A, dtype=np.int64)
    total = int(size.sum())
    if total == 0:
        return tok, ml
    prow = np.repeat(np.arange(A, dtype=np.int64), size)
    boff = np.zeros(A, dtype=np.int64)
    np.cumsum(size[:-1], out=boff[1:])
    psi = np.arange(total, dtype=np.int64) - boff[prow] + start[prow]
    sl = pd.suf_len[psi]
    eq = (((lo2[prow] ^ pd.suf_lo[psi]) & pd.suf_mlo[psi]) == 0) \
        & (((hi2[prow] ^ pd.suf_hi[psi]) & pd.suf_mhi[psi]) == 0) \
        & (sl <= rem[prow] - 8)
    if not pd.variant16:
        # unbounded OnPair: suffixes longer than the packed 8 bytes must
        # verify their tails against the raw entry bytes (rare)
        for j in np.nonzero(eq & (sl > 8))[0].tolist():
            t = int(pd.suf_tok[psi[j]])
            o = int(pd.offsets[t])
            ln_e = int(pd.lens[t])
            r = int(prow[j])
            q = int(pos[r])
            if not np.array_equal(data[rows[r], q + 16 : q + ln_e],
                                  pd.blob[o + 16 : o + ln_e]):
                eq[j] = False
    hits = np.nonzero(eq)[0]
    if hits.size:
        # hits ascend and pairs are grouped by row, so unique() yields each
        # row's first (= longest, tie-correct) hit
        got, firsti = np.unique(prow[hits], return_index=True)
        w = hits[firsti]
        tok[got] = pd.suf_tok[psi[w]]
        ml[got] = 8 + sl[w]
    return tok, ml


def _parse_chunk(pd, strings: list[bytes], lens: np.ndarray):
    """Parse one (length-homogeneous) chunk; returns the chunk's token stream
    flattened in chunk order ('<u2') plus per-string token counts."""
    B = len(strings)
    Lmax = int(lens.max())
    counts = np.zeros(B, dtype=np.int64)
    if Lmax == 0:
        return np.zeros(0, dtype="<u2"), counts
    # one blob -> (B, Lmax + 16) matrix; the +16 columns stay zero so every
    # 16-byte window gather is in bounds
    data = np.zeros((B, Lmax + 16), dtype=np.uint8)
    blob = np.frombuffer(b"".join(strings), dtype=np.uint8)
    fill = np.arange(Lmax, dtype=np.int64)[None, :] < lens[:, None]
    data[:, :Lmax][fill] = blob
    toks = np.zeros((B, Lmax), dtype=np.int32)  # <= 1 token per input byte
    tflat = toks.reshape(-1)
    dflat = data.reshape(-1)
    W = data.shape[1]
    has_long = pd.max_bucket_size > 0
    t_s, t_p, t_l1, t_l2 = _k64_tables(pd)
    # live rows carried as compacted parallel arrays: finished rows drop out
    # wholesale each round, so no per-round fancy gather/scatter on (B,)
    # state — only the (shrinking) live set is touched
    row = np.nonzero(lens > 0)[0]
    p = np.zeros(row.size, dtype=np.int64)
    rlen = lens[row]
    cnt = np.zeros(row.size, dtype=np.int64)
    dbase = row * np.int64(W)
    tbase = row * np.int64(Lmax)
    while row.size:
        rem = rlen - p
        win = dflat.take((dbase + p)[:, None] + _ARANGE16)
        w64 = win.view("<u8")  # (A, 2): the 16-byte window as 2 LE u64 words
        k1 = w64[:, 0]
        k2 = w64[:, 1]
        tok = np.full(row.size, -1, dtype=np.int32)
        mlen = np.zeros(row.size, dtype=np.int64)
        if has_long:
            cand = np.nonzero(rem > 8)[0]
            if cand.size:
                bkt = _probe_flat(k1[cand], np.int32(8), _MIXP, t_p,
                                  pd.p_len, pd.p_bucket, pd.p_probe_max)
                hitb = np.nonzero(bkt >= 0)[0]
                if hitb.size:
                    li = cand[hitb]
                    if pd.variant16:
                        t, m = _long_exact(k1[li], k2[li], rem[li], pd,
                                           t_l1, t_l2)
                    else:
                        w32 = win.view("<u4")
                        t, m = _bucket_scan(pd, data, row[li], p[li],
                                            rem[li], w32[li, 2], w32[li, 3],
                                            bkt[hitb])
                    tok[li] = t
                    mlen[li] = m
        # short tier only where the long tier found nothing (Algorithm 1:
        # a long match, being >= 9 bytes, always beats the short tier)
        short = np.nonzero(tok < 0)[0]
        if short.size:
            stok, sml = _short_tier(k1[short], rem[short], pd, t_s)
            tok[short] = stok
            mlen[short] = sml
        tflat[tbase + cnt] = tok
        cnt += 1
        p += mlen
        keep = p < rlen
        if not keep.all():
            done = ~keep
            counts[row[done]] = cnt[done]
            row = row[keep]
            p = p[keep]
            rlen = rlen[keep]
            cnt = cnt[keep]
            dbase = dbase[keep]
            tbase = tbase[keep]
    keep = np.arange(Lmax, dtype=np.int64)[None, :] < counts[:, None]
    return toks[keep].astype("<u2"), counts


def parse_batch(dictionary, strings: list[bytes],
                chunk: int = 4096) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised greedy LPM parse of a whole batch (paper §3.3).

    One shared static-table walk across all strings instead of a per-string
    Python loop. Returns ``(payload, counts)``: the concatenated '<u2' token
    stream in input order and per-string token counts. Byte-identical to
    ``DynamicLPM.parse`` on every string (pinned by tests).
    """
    n = len(strings)
    counts = np.zeros(n, dtype=np.int64)
    if n == 0:
        return np.zeros(0, dtype="<u2"), counts
    lens = np.fromiter(map(len, strings), dtype=np.int64, count=n)
    # Length-sorted chunks keep each chunk's token loop rectangular: the
    # active set drains together instead of idling on one long straggler.
    order = np.argsort(lens, kind="stable")
    parts: list[np.ndarray] = []
    sorted_counts = np.zeros(n, dtype=np.int64)
    for c0 in range(0, n, chunk):
        sel = order[c0 : c0 + chunk]
        flat, cnt = _parse_chunk(dictionary, [strings[i] for i in sel],
                                 lens[sel])
        parts.append(flat)
        sorted_counts[c0 : c0 + sel.size] = cnt
    flat_sorted = parts[0] if len(parts) == 1 else np.concatenate(parts)
    counts[order] = sorted_counts
    total = int(flat_sorted.size)
    if total == 0:
        return flat_sorted, counts
    # gather sorted-order tokens back into input order
    src_off = np.zeros(n, dtype=np.int64)
    np.cumsum(sorted_counts[:-1], out=src_off[1:])
    starts = np.empty(n, dtype=np.int64)
    starts[order] = src_off  # per input string: its span start in flat_sorted
    out_off = np.zeros(n, dtype=np.int64)
    np.cumsum(counts[:-1], out=out_off[1:])
    gather = np.repeat(starts - out_off, counts) + np.arange(total,
                                                             dtype=np.int64)
    return flat_sorted[gather], counts
