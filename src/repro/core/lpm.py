"""Two-tier Longest Prefix Matching (paper §3.4).

Dynamic structures (used during the training phase, supports insertion):

* short patterns (<= 8 bytes): hash map keyed by ``(packed u64, length)``.
* long patterns  (>  8 bytes): hash map keyed by the packed 8-byte prefix;
  each value is a *bucket* — a list of ``(suffix bytes, token_id)`` kept in
  descending suffix-length order so the scan can stop at the first match
  (Algorithm 1, lines 2-12).

The static (post-training, read-only) flattening into parallel numpy arrays —
the array-hash analogue of the paper's perfect-hash + inline-suffix layout —
lives in :mod:`repro.core.packed` and is consumed by the numpy fast paths and
by the JAX/Pallas kernels.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.packing import pack_u64


@dataclass
class DynamicLPM:
    """Insertable two-tier LPM used by the OnPair training phase."""

    #: (packed u64 value, length) -> token id, for entries of length 1..8.
    short_map: dict[tuple[int, int], int] = field(default_factory=dict)
    #: packed 8-byte prefix -> [(suffix bytes, token id)] sorted by len desc.
    long_buckets: dict[int, list[tuple[bytes, int]]] = field(default_factory=dict)

    def insert(self, entry: bytes, token_id: int) -> None:
        n = len(entry)
        if n <= 8:
            self.short_map[(pack_u64(entry, 0, n), n)] = token_id
            return
        prefix = pack_u64(entry, 0, 8)
        suffix = entry[8:]
        bucket = self.long_buckets.setdefault(prefix, [])
        # Keep descending length order; ties keep insertion order (older first,
        # matching "return the first match found" semantics for equal lengths).
        pos = 0
        slen = len(suffix)
        while pos < len(bucket) and len(bucket[pos][0]) >= slen:
            pos += 1
        bucket.insert(pos, (suffix, token_id))

    def bucket_size(self, entry: bytes) -> int:
        """Current size of the bucket the (long) entry would land in."""
        if len(entry) <= 8:
            return 0
        return len(self.long_buckets.get(pack_u64(entry, 0, 8), ()))

    def search(self, data: bytes, pos: int) -> tuple[int, int]:
        """Algorithm 1: longest dictionary match at ``data[pos:]``.

        Returns ``(token_id, match_length)``. Because the dictionary is seeded
        with all 256 single bytes, a 1-byte match always exists.
        """
        rem = len(data) - pos
        # --- long pattern matching (lines 2-12) ---
        if rem > 8:
            prefix = pack_u64(data, pos, 8)
            bucket = self.long_buckets.get(prefix)
            if bucket is not None:
                after = pos + 8
                for suffix, token_id in bucket:  # sorted by descending length
                    if data.startswith(suffix, after):
                        return token_id, 8 + len(suffix)
        # --- short pattern matching (lines 13-19) ---
        max_len = rem if rem < 8 else 8
        val = pack_u64(data, pos, max_len)
        for length in range(max_len, 0, -1):
            key = (val, length)
            token_id = self.short_map.get(key)
            if token_id is not None:
                return token_id, length
            # Little-endian packing: a length-1 prefix is the *low* bytes, so
            # shorten by masking off the current highest byte.
            val &= (1 << (8 * (length - 1))) - 1
        raise AssertionError("dictionary must contain all single bytes")

    def parse(self, data: bytes) -> list[int]:
        """Greedy longest-prefix tokenisation of one string (paper §3.3)."""
        out: list[int] = []
        pos = 0
        n = len(data)
        while pos < n:
            token_id, length = self.search(data, pos)
            out.append(token_id)
            pos += length
        return out


def lpm_from_entries(entries: list[bytes]) -> DynamicLPM:
    """Build a dynamic LPM over a full entry list (ids = list positions)."""
    lpm = DynamicLPM()
    for tid, entry in enumerate(entries):
        lpm.insert(entry, tid)
    return lpm
