"""The paper's primary contribution: OnPair / OnPair16 string compression
with fast random access, plus the baselines it is evaluated against
(BPE, FSST-like, block-based zstd/zlib, RAW).

Layered as: packing (u64 tricks) -> lpm (two-tier longest prefix matching)
-> onpair (training + parsing phases) -> packed (frozen dictionary + static
LPM arrays consumed by the JAX/Pallas kernels).

API v2 splits the codec into three first-class pieces:

  artifact  — DictArtifact: immutable, serializable trained dictionary
              (token table + config + format version; save/load, mmap-able)
  codec     — Encoder / Decoder: stateless per-string encode/decode built
              from an artifact with an explicit backend= (numpy | pallas)
  registry  — codecs constructible by name with capability flags
              (token_stream / bounded_entries / device_decodable / trainable)

``StringCompressor`` and ``ALL_COMPRESSORS`` remain as a **deprecated**
back-compat shim over those pieces: accessing either through this package
emits :class:`DeprecationWarning` (see ``__getattr__`` below) and they are
scheduled for removal two PRs after Client API v3 (see README "Deprecations"
for the horizon). Use ``registry.create(name)`` / ``registry.names()`` to
construct codecs, and subclass ``repro.core.api.StringCompressor`` directly
when implementing one.
"""

import warnings

from repro.core import registry
from repro.core.api import (CompressedCorpus, RawCompressor, TrainStats,
                            pack_corpus)
from repro.core.artifact import DictArtifact
from repro.core.blockcomp import ZlibBlockCompressor, ZstdBlockCompressor
from repro.core.bpe import BPECompressor
from repro.core.codec import Decoder, Encoder
from repro.core.fsst import FSSTCompressor
from repro.core.onpair import (MAX_TOKENS, OnPairCompressor, OnPairConfig,
                               auto_threshold, make_onpair, make_onpair16,
                               train_dictionary)
from repro.core.packed import PackedDictionary
from repro.core.registry import CodecCaps, CodecSpec

def _all_compressors() -> dict:
    """The pre-v2 name->factory view of the registry."""
    return {
        "raw": registry.get_spec("raw").factory,
        "zlib-block": registry.get_spec("zlib-block").factory,
        "zstd-block": registry.get_spec("zstd-block").factory,
        "lz-block": registry.get_spec("lz-block").factory,
        "bpe": registry.get_spec("bpe").factory,
        "fsst": registry.get_spec("fsst").factory,
        "onpair": registry.get_spec("onpair").factory,
        "onpair16": registry.get_spec("onpair16").factory,
    }


def __getattr__(name: str):
    """Deprecated back-compat shim: ``ALL_COMPRESSORS`` indexing predates
    the registry, and ``StringCompressor`` is an implementation base class,
    not a public constructor surface. Both warn here and will be removed
    from this namespace on the horizon documented in the README."""
    if name == "ALL_COMPRESSORS":
        warnings.warn(
            "repro.core.ALL_COMPRESSORS is deprecated; use "
            "repro.core.registry.create(name) (and registry.names() for "
            "the listing). Removal horizon: two PRs after Client API v3 — "
            "see README 'Deprecations'.",
            DeprecationWarning, stacklevel=2)
        return _all_compressors()
    if name == "StringCompressor":
        warnings.warn(
            "importing StringCompressor from repro.core is deprecated; "
            "construct codecs via repro.core.registry and subclass "
            "repro.core.api.StringCompressor when implementing one. "
            "Removal horizon: two PRs after Client API v3 — see README "
            "'Deprecations'.",
            DeprecationWarning, stacklevel=2)
        from repro.core.api import StringCompressor
        return StringCompressor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "CompressedCorpus", "RawCompressor", "TrainStats",
    "pack_corpus", "ZlibBlockCompressor", "ZstdBlockCompressor",
    "BPECompressor", "FSSTCompressor", "OnPairCompressor", "OnPairConfig",
    "MAX_TOKENS", "auto_threshold", "make_onpair", "make_onpair16",
    "train_dictionary", "PackedDictionary",
    "DictArtifact", "Encoder", "Decoder", "registry", "CodecCaps", "CodecSpec",
]
