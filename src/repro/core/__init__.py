"""The paper's primary contribution: OnPair / OnPair16 string compression
with fast random access, plus the baselines it is evaluated against
(BPE, FSST-like, block-based zstd/zlib, RAW).

Layered as: packing (u64 tricks) -> lpm (two-tier longest prefix matching)
-> onpair (training + parsing phases) -> packed (frozen dictionary artifact
+ static LPM arrays consumed by the JAX/Pallas kernels).
"""

from repro.core.api import (CompressedCorpus, RawCompressor, StringCompressor,
                            TrainStats, pack_corpus)
from repro.core.blockcomp import ZlibBlockCompressor, ZstdBlockCompressor
from repro.core.bpe import BPECompressor
from repro.core.fsst import FSSTCompressor
from repro.core.onpair import (MAX_TOKENS, OnPairCompressor, OnPairConfig,
                               auto_threshold, make_onpair, make_onpair16,
                               train_dictionary)
from repro.core.packed import PackedDictionary

ALL_COMPRESSORS = {
    "raw": RawCompressor,
    "zlib-block": ZlibBlockCompressor,
    "zstd-block": ZstdBlockCompressor,
    "bpe": BPECompressor,
    "fsst": FSSTCompressor,
    "onpair": make_onpair,
    "onpair16": make_onpair16,
}

__all__ = [
    "CompressedCorpus", "RawCompressor", "StringCompressor", "TrainStats",
    "pack_corpus", "ZlibBlockCompressor", "ZstdBlockCompressor",
    "BPECompressor", "FSSTCompressor", "OnPairCompressor", "OnPairConfig",
    "MAX_TOKENS", "auto_threshold", "make_onpair", "make_onpair16",
    "train_dictionary", "PackedDictionary", "ALL_COMPRESSORS",
]
