"""The paper's primary contribution: OnPair / OnPair16 string compression
with fast random access, plus the baselines it is evaluated against
(BPE, FSST-like, block-based zstd/zlib, RAW).

Layered as: packing (u64 tricks) -> lpm (two-tier longest prefix matching)
-> onpair (training + parsing phases) -> packed (frozen dictionary + static
LPM arrays consumed by the JAX/Pallas kernels).

API v2 splits the codec into three first-class pieces:

  artifact  — DictArtifact: immutable, serializable trained dictionary
              (token table + config + format version; save/load, mmap-able)
  codec     — Encoder / Decoder: stateless per-string encode/decode built
              from an artifact with an explicit backend= (numpy | pallas)
  registry  — codecs constructible by name with capability flags
              (token_stream / bounded_entries / device_decodable / trainable)

``StringCompressor`` and ``ALL_COMPRESSORS`` remain as the back-compat shim
over those pieces.
"""

from repro.core import registry
from repro.core.api import (CompressedCorpus, RawCompressor, StringCompressor,
                            TrainStats, pack_corpus)
from repro.core.artifact import DictArtifact
from repro.core.blockcomp import ZlibBlockCompressor, ZstdBlockCompressor
from repro.core.bpe import BPECompressor
from repro.core.codec import Decoder, Encoder
from repro.core.fsst import FSSTCompressor
from repro.core.onpair import (MAX_TOKENS, OnPairCompressor, OnPairConfig,
                               auto_threshold, make_onpair, make_onpair16,
                               train_dictionary)
from repro.core.packed import PackedDictionary
from repro.core.registry import CodecCaps, CodecSpec

#: Back-compat name->factory view of the registry (pre-v2 callers indexed
#: this dict directly). Prefer ``registry.create(name)`` going forward.
ALL_COMPRESSORS = {
    "raw": registry.get_spec("raw").factory,
    "zlib-block": registry.get_spec("zlib-block").factory,
    "zstd-block": registry.get_spec("zstd-block").factory,
    "lz-block": registry.get_spec("lz-block").factory,
    "bpe": registry.get_spec("bpe").factory,
    "fsst": registry.get_spec("fsst").factory,
    "onpair": registry.get_spec("onpair").factory,
    "onpair16": registry.get_spec("onpair16").factory,
}

__all__ = [
    "CompressedCorpus", "RawCompressor", "StringCompressor", "TrainStats",
    "pack_corpus", "ZlibBlockCompressor", "ZstdBlockCompressor",
    "BPECompressor", "FSSTCompressor", "OnPairCompressor", "OnPairConfig",
    "MAX_TOKENS", "auto_threshold", "make_onpair", "make_onpair16",
    "train_dictionary", "PackedDictionary", "ALL_COMPRESSORS",
    "DictArtifact", "Encoder", "Decoder", "registry", "CodecCaps", "CodecSpec",
]
