"""Byte-Pair Encoding baseline (paper §2.2) — the compression-quality anchor.

Classic corpus-level BPE: iteratively merge the globally most frequent
adjacent token pair until the dictionary holds 65,536 tokens (2-byte IDs, the
same budget as OnPair) or no pair occurs twice. This implementation is the
*efficient* classical algorithm — linked-list token stream, incremental pair
counts, a lazy max-heap, and a full pair→positions index — i.e. exactly the
"substantial computational effort … maintaining a complete record of pair
positions also demands considerable memory" cost structure the paper
contrasts OnPair against. We keep it honest: the positions index and global
statistics are real, so measured training time/memory exhibit BPE's true
profile rather than a strawman.

Encoding uses the same greedy longest-prefix-match parser as OnPair (shared
harness; the paper's field-level compressors all parse against a static
dictionary), and decoding uses the same packed-dictionary decoder.
"""

from __future__ import annotations

import heapq
import time

import numpy as np

from repro.core.api import CompressedCorpus, StringCompressor, TrainStats, pack_corpus
from repro.core.artifact import DictArtifact
from repro.core.lpm import lpm_from_entries
from repro.core.packed import PackedDictionary

_SEP = -1  # string separator: pairs never span strings


def _initial_positions(keys: np.ndarray) -> dict[int, list]:
    """Group positions by pair key with one argsort (no Python-loop build)."""
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    boundaries = np.nonzero(np.diff(sorted_keys))[0] + 1
    starts = np.concatenate([[0], boundaries])
    ends = np.concatenate([boundaries, [len(sorted_keys)]])
    out: dict[int, list] = {}
    for s, e in zip(starts, ends):
        out[int(sorted_keys[s])] = [order[s:e]]
    return out


def train_bpe(strings: list[bytes], max_tokens: int = 65536,
              sample_bytes: int = 4 << 20, seed: int = 0,
              min_count: int = 2) -> list[bytes]:
    """Train a BPE vocabulary; returns the entry list (ids = positions)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(strings))

    # Build the token stream (sample) with separators.
    chunks: list[np.ndarray] = []
    budget = 0
    sep = np.array([_SEP], dtype=np.int32)
    for idx in order:
        s = strings[int(idx)]
        if not s:
            continue
        chunks.append(np.frombuffer(s, dtype=np.uint8).astype(np.int32))
        chunks.append(sep)
        budget += len(s)
        if budget >= sample_bytes:
            break
    if not chunks:
        return [bytes([b]) for b in range(256)]
    seq = np.concatenate(chunks)
    n = len(seq)
    nxt = np.arange(1, n + 1, dtype=np.int64)
    prv = np.arange(-1, n - 1, dtype=np.int64)

    entries: list[bytes] = [bytes([b]) for b in range(256)]

    def key_of(a: int, b: int) -> int:
        return (a << 32) | b

    # Global pair statistics + full positions index (BPE's memory cost).
    a_ids = seq[:-1]
    b_ids = seq[1:]
    valid = (a_ids >= 0) & (b_ids >= 0)
    keys = (a_ids.astype(np.int64) << 32) | b_ids.astype(np.int64)
    keys = np.where(valid, keys, -1)
    uniq, cnt = np.unique(keys[valid], return_counts=True)
    counts: dict[int, int] = {int(k): int(c) for k, c in zip(uniq, cnt)}
    positions = _initial_positions(np.where(valid, keys, np.int64(-(1 << 62))))
    positions.pop(-(1 << 62), None)

    heap: list[tuple[int, int]] = [(-c, int(k)) for k, c in counts.items() if c >= min_count]
    heapq.heapify(heap)

    def dec(a: int, b: int) -> None:
        if a < 0 or b < 0:
            return
        k = key_of(a, b)
        c = counts.get(k)
        if c:
            counts[k] = c - 1

    def inc(a: int, b: int, pos: int) -> None:
        if a < 0 or b < 0:
            return
        k = key_of(a, b)
        c = counts.get(k, 0) + 1
        counts[k] = c
        plist = positions.get(k)
        if plist is None:
            positions[k] = plist = []
        plist.append(pos)
        if c >= min_count:
            heapq.heappush(heap, (-c, k))

    while len(entries) < max_tokens and heap:
        negc, k = heapq.heappop(heap)
        c = counts.get(k, 0)
        if c < min_count:
            continue
        if -negc != c:           # stale heap entry: reinsert with true count
            heapq.heappush(heap, (-c, k))
            continue
        a, b = k >> 32, k & 0xFFFFFFFF
        new_id = len(entries)
        entries.append(entries[a] + entries[b])
        plists = positions.pop(k, [])
        counts.pop(k, None)
        for pl in plists:
            # elements are either a numpy chunk (initial index) or single ints
            it = pl.tolist() if isinstance(pl, np.ndarray) else (pl,)
            for p in it:
                if seq[p] != a:
                    continue
                q = nxt[p]
                if q >= n or seq[q] != b:
                    continue
                # merge [p]=a,[q]=b -> [p]=new_id
                left = int(prv[p])
                r = int(nxt[q])
                la = int(seq[left]) if left >= 0 else _SEP
                rb = int(seq[r]) if r < n else _SEP
                dec(la, a)
                dec(b, rb)
                seq[p] = new_id
                seq[q] = _SEP  # tombstone
                nxt[p] = r
                if r < n:
                    prv[r] = p
                inc(la, new_id, int(left))
                inc(new_id, rb, int(p))
    return entries


class BPECompressor(StringCompressor):
    name = "bpe"

    def __init__(self, max_tokens: int = 65536, sample_bytes: int = 4 << 20,
                 seed: int = 0):
        self.max_tokens = max_tokens
        self.sample_bytes = sample_bytes
        self.seed = seed
        self.dictionary: PackedDictionary | None = None
        self._lpm = None

    def to_artifact(self) -> DictArtifact:
        assert self.dictionary is not None, "train() first"
        cfg = {"max_tokens": self.max_tokens, "sample_bytes": self.sample_bytes,
               "seed": self.seed}
        return DictArtifact.from_entries("bpe", self.dictionary.entries,
                                         config=cfg)

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "BPECompressor":
        comp = cls(**artifact.config) if artifact.config else cls()
        comp.dictionary = PackedDictionary.build(artifact.entries)
        return comp

    def _parser(self):
        if self._lpm is None:
            assert self.dictionary is not None, "train() first"
            self._lpm = lpm_from_entries(self.dictionary.entries)
        return self._lpm

    def train(self, strings, dataset_bytes=None) -> TrainStats:
        t0 = time.perf_counter()
        entries = train_bpe(strings, self.max_tokens, self.sample_bytes, self.seed)
        self._lpm = lpm_from_entries(entries)
        self.dictionary = PackedDictionary.build(entries)
        return TrainStats(
            train_seconds=time.perf_counter() - t0,
            sample_bytes=min(self.sample_bytes, dataset_bytes or self.sample_bytes),
            dict_entries=len(entries),
            dict_data_bytes=self.dictionary.data_bytes,
            dict_total_bytes=self.dictionary.total_bytes,
        )

    def compress(self, strings) -> CompressedCorpus:
        parse = self._parser().parse
        parts, raw = [], 0
        for s in strings:
            raw += len(s)
            parts.append(np.asarray(parse(s), dtype="<u2").tobytes())
        return pack_corpus(parts, raw, compressor=self.name)

    def decompress_all(self, corpus) -> bytes:
        assert self.dictionary is not None
        return self.dictionary.decode_tokens(np.asarray(corpus.payload.view("<u2")))

    def access(self, corpus, i) -> bytes:
        assert self.dictionary is not None
        o0, o1 = int(corpus.offsets[i]), int(corpus.offsets[i + 1])
        tokens = corpus.payload[o0:o1].view("<u2")
        entries = self.dictionary.entries
        return b"".join(entries[t] for t in tokens)
