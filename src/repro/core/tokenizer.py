"""OnPair as a byte-level subword tokenizer for LM training/serving.

The paper (§2.2) notes BPE's dual life as a compressor and an NLP subword
tokenizer; OnPair's dictionary has exactly the same shape (65,536 substrings,
2-byte IDs) but trains orders of magnitude faster. This module turns a
trained OnPair16 dictionary into the framework's tokenizer: the LM vocabulary
IS the compression dictionary, so the data pipeline's compressed corpus can
be fed to the model *without ever materialising raw text* — token IDs come
straight out of the stored compressed payload.

Special IDs live in a small reserved band appended after the dictionary
(65536..65536+n_special), so vocab_size = 65536 + n_special (still << typical
LM vocab sizes; configs may also round up for shardability).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.onpair import OnPairCompressor, OnPairConfig
from repro.core.packed import PackedDictionary

PAD_ID = 65536
BOS_ID = 65537
EOS_ID = 65538
N_SPECIAL = 3
VOCAB_SIZE = 65536 + N_SPECIAL


@dataclass
class OnPairTokenizer:
    compressor: OnPairCompressor

    @property
    def dictionary(self) -> PackedDictionary:
        assert self.compressor.dictionary is not None
        return self.compressor.dictionary

    @property
    def vocab_size(self) -> int:
        return VOCAB_SIZE

    @classmethod
    def train(cls, strings: list[bytes], sample_bytes: int = 8 << 20,
              seed: int = 0, threshold: int | None = None) -> "OnPairTokenizer":
        comp = OnPairCompressor(OnPairConfig.onpair16(
            sample_bytes=sample_bytes, seed=seed, threshold=threshold))
        comp.train(strings)
        return cls(comp)

    @classmethod
    def from_dictionary(cls, dictionary: PackedDictionary) -> "OnPairTokenizer":
        comp = OnPairCompressor(OnPairConfig.onpair16())
        comp.dictionary = dictionary
        return cls(comp)

    @classmethod
    def from_artifact(cls, artifact) -> "OnPairTokenizer":
        return cls(OnPairCompressor.from_artifact(artifact))

    def to_artifact(self):
        return self.compressor.to_artifact()

    # ----------------------------------------------------------------- encode
    def encode(self, text: bytes, bos: bool = False, eos: bool = False) -> np.ndarray:
        ids = self.compressor._parser().parse(text)
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return np.asarray(ids, dtype=np.int32)

    def encode_batch(self, texts: list[bytes], **kw) -> list[np.ndarray]:
        return [self.encode(t, **kw) for t in texts]

    # ----------------------------------------------------------------- decode
    def decode(self, ids: np.ndarray) -> bytes:
        entries = self.dictionary.entries
        out = []
        for t in np.asarray(ids).reshape(-1):
            t = int(t)
            if t < 65536 and t < len(entries):
                out.append(entries[t])
        return b"".join(out)

    def decode_fast(self, ids: np.ndarray) -> bytes:
        """Vectorised decode (Algorithm 3 path) for non-special streams."""
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        ids = ids[ids < len(self.dictionary.entries)]
        return self.dictionary.decode_tokens(ids)

    def save(self, path: str) -> None:
        self.dictionary.save(path)

    @classmethod
    def load(cls, path: str) -> "OnPairTokenizer":
        return cls.from_dictionary(PackedDictionary.load(path))
