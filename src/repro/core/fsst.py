"""FSST-like baseline (paper §2.4, Boncz et al. VLDB'20).

Fast Static Symbol Table: up to 255 substrings of <= 8 bytes mapped to 1-byte
codes; code 255 is an escape followed by one literal byte. The table is built
bottom-up over a sample in a few generations: (1) parse the sample with the
current table selecting longest matches, (2) re-select the 255 symbols with
the highest apparent gain (frequency x length) among current symbols and
concatenations of adjacent matches.

This mirrors FSST's published construction closely enough to reproduce its
trade-off (very fast, table fits L1, but <= 8-byte symbols cap the ratio);
AVX-512 encode and lossy perfect hashing are CPU-specific mechanics we do not
emulate (see DESIGN.md §3) — the decode fast path here is the vectorised
analogue (grouped fixed-size row copies out of a (256, 8) table).
"""

from __future__ import annotations

import time
from collections import Counter

import numpy as np

from repro.core.api import CompressedCorpus, StringCompressor, TrainStats, pack_corpus
from repro.core.artifact import DictArtifact

ESCAPE = 255
_ARANGE8 = np.arange(8, dtype=np.int64)


class _Matcher:
    """Greedy longest-match over <= 8-byte symbols, with escape fallback."""

    def __init__(self, table: list[bytes]):
        # (packed u64 LE value, length) -> code
        self.map: dict[tuple[int, int], int] = {}
        for code, sym in enumerate(table):
            self.map[(int.from_bytes(sym, "little"), len(sym))] = code

    def parse(self, s: bytes) -> bytearray:
        out = bytearray()
        get = self.map.get
        pos, n = 0, len(s)
        while pos < n:
            max_len = n - pos
            if max_len > 8:
                max_len = 8
            val = int.from_bytes(s[pos : pos + max_len], "little")
            length = max_len
            while length > 0:
                code = get((val, length))
                if code is not None:
                    out.append(code)
                    pos += length
                    break
                length -= 1
                val &= (1 << (8 * length)) - 1
            else:
                out.append(ESCAPE)
                out.append(s[pos])
                pos += 1
        return out

    def parse_symbols(self, s: bytes) -> list[bytes]:
        """Like parse but yields the matched substrings (training use)."""
        syms: list[bytes] = []
        pos, n = 0, len(s)
        while pos < n:
            max_len = min(8, n - pos)
            val = int.from_bytes(s[pos : pos + max_len], "little")
            length = max_len
            while length > 0:
                if (val, length) in self.map:
                    syms.append(s[pos : pos + length])
                    pos += length
                    break
                length -= 1
                val &= (1 << (8 * length)) - 1
            else:
                syms.append(s[pos : pos + 1])
                pos += 1
        return syms


def train_fsst(strings: list[bytes], sample_bytes: int = 1 << 20,
               generations: int = 5, seed: int = 0) -> list[bytes]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(strings))
    sample: list[bytes] = []
    budget = 0
    for idx in order:
        s = strings[int(idx)]
        if not s:
            continue
        sample.append(s)
        budget += len(s)
        if budget >= sample_bytes:
            break

    table: list[bytes] = []
    for _ in range(generations):
        matcher = _Matcher(table)
        freq: Counter[bytes] = Counter()
        pair_freq: Counter[bytes] = Counter()
        for s in sample:
            syms = matcher.parse_symbols(s)
            freq.update(syms)
            for a, b in zip(syms, syms[1:]):
                if len(a) + len(b) <= 8:
                    pair_freq[a + b] += 1
        gains: Counter[bytes] = Counter()
        for sym, f in freq.items():
            gains[sym] = f * len(sym)
        for sym, f in pair_freq.items():
            gains[sym] += f * len(sym)
        table = [sym for sym, _ in gains.most_common(255)]
    return table


def _build_decode_tables(table: list[bytes]) -> tuple[np.ndarray, np.ndarray]:
    mat8 = np.zeros((256, 8), dtype=np.uint8)
    lens = np.ones(256, dtype=np.int64)
    for code, sym in enumerate(table):
        mat8[code, : len(sym)] = np.frombuffer(sym, dtype=np.uint8)
        lens[code] = len(sym)
    return mat8, lens


def _unit_starts(codes: np.ndarray) -> np.ndarray:
    """Boolean mask of unit starts (symbol codes or escape codes).

    A maximal run of ESCAPE bytes always begins at a unit boundary (an
    encoded string never ends with a dangling escape, so end-of-string runs
    have even length and concatenation preserves parity); within a run, even
    offsets are escapes and odd offsets are escaped literal 255 bytes. A
    non-255 byte is a unit start iff it is not the literal of an odd-offset
    terminating escape.
    """
    n = codes.size
    is_esc_byte = codes == ESCAPE
    starts = np.ones(n, dtype=bool)
    if not is_esc_byte.any():
        return starts
    idx = np.nonzero(is_esc_byte)[0]
    run_break = np.empty(idx.size, dtype=bool)
    run_break[0] = True
    run_break[1:] = np.diff(idx) != 1
    run_id = np.cumsum(run_break) - 1
    run_start = idx[run_break][run_id]
    offset = idx - run_start
    literal_255 = idx[offset % 2 == 1]          # escaped literal 255 bytes
    starts[literal_255] = False
    # escapes consume their next byte: mark pos+1 of every escape as non-start
    escapes = idx[offset % 2 == 0]
    consumed = escapes + 1
    consumed = consumed[consumed < n]
    starts[consumed] = False
    return starts


class FSSTCompressor(StringCompressor):
    name = "fsst"

    def __init__(self, sample_bytes: int = 1 << 20, generations: int = 5, seed: int = 0):
        self.sample_bytes = sample_bytes
        self.generations = generations
        self.seed = seed
        self.table: list[bytes] | None = None
        self._matcher: _Matcher | None = None
        self._mat8: np.ndarray | None = None
        self._lens: np.ndarray | None = None

    def to_artifact(self) -> DictArtifact:
        assert self.table is not None, "train() first"
        cfg = {"sample_bytes": self.sample_bytes,
               "generations": self.generations, "seed": self.seed}
        return DictArtifact.from_entries("fsst", self.table, config=cfg)

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "FSSTCompressor":
        comp = cls(**artifact.config) if artifact.config else cls()
        comp.table = artifact.entries
        comp._matcher = _Matcher(comp.table)
        comp._mat8, comp._lens = _build_decode_tables(comp.table)
        return comp

    def train(self, strings, dataset_bytes=None) -> TrainStats:
        t0 = time.perf_counter()
        self.table = train_fsst(strings, self.sample_bytes, self.generations, self.seed)
        self._matcher = _Matcher(self.table)
        self._mat8, self._lens = _build_decode_tables(self.table)
        data = sum(len(s) for s in self.table)
        return TrainStats(
            train_seconds=time.perf_counter() - t0,
            sample_bytes=min(self.sample_bytes, dataset_bytes or self.sample_bytes),
            dict_entries=len(self.table),
            dict_data_bytes=data,
            dict_total_bytes=data + 4 * (len(self.table) + 1),
        )

    def compress(self, strings) -> CompressedCorpus:
        assert self._matcher is not None
        parse = self._matcher.parse
        parts, raw = [], 0
        for s in strings:
            raw += len(s)
            parts.append(bytes(parse(s)))
        return pack_corpus(parts, raw, compressor=self.name)

    def decompress_all(self, corpus) -> bytes:
        """Vectorised decode: resolve escape structure, then grouped
        fixed-size row copies (the SIMD-store analogue)."""
        assert self._mat8 is not None and self._lens is not None
        codes = corpus.payload
        if codes.size == 0:
            return b""
        starts_mask = _unit_starts(codes)
        unit_pos = np.nonzero(starts_mask)[0]
        toks = codes[unit_pos].astype(np.int64)
        is_esc = toks == ESCAPE
        lens = np.where(is_esc, 1, self._lens[toks])
        rows = self._mat8[toks]
        if is_esc.any():
            lit_pos = unit_pos[is_esc] + 1
            rows[is_esc, 0] = codes[lit_pos]
        ends = np.cumsum(lens)
        outpos = ends - lens
        out = np.zeros(int(ends[-1]) + 8, dtype=np.uint8)
        for length in np.unique(lens):
            L = int(length)
            sel = np.nonzero(lens == L)[0]
            idx = outpos[sel, None] + _ARANGE8[None, :L]
            out[idx.reshape(-1)] = rows[sel, :L].reshape(-1)
        return out[: int(ends[-1])].tobytes()

    def decode_string(self, payload: bytes) -> bytes:
        """Scalar reference decoder (oracle for the vectorised path)."""
        assert self.table is not None
        out = bytearray()
        i, n = 0, len(payload)
        while i < n:
            c = payload[i]
            if c == ESCAPE:
                out.append(payload[i + 1])
                i += 2
            else:
                out += self.table[c]
                i += 1
        return bytes(out)

    def access(self, corpus, i) -> bytes:
        return self.decode_string(corpus.string_payload(i))
