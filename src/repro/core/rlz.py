"""Relative Lempel-Ziv factorization against a shared reference.

The cold-tier codec behind :mod:`repro.store.tier`: a sealed segment's
decoded strings are factorized against the trained dictionary's entry blob
(the Hoobin/Puglisi/Zobel RLZ construction with the OnPair dictionary as the
reference — the dictionary was trained on exactly this data, so it is a
dense source of long matches). Every string records its own factor range,
so random access stays O(factors-per-string): decoding string ``i`` gathers
only the copy/literal runs in ``starts[i]:starts[i+1]``, never a block.

Factor layout — four parallel arrays, container- and mmap-friendly::

    starts    i64[n + 1]   per-string factor boundaries
    offs      u32[F]       source offset; top bit set = literals-blob offset
    lens      u32[F]       run length in bytes
    literals  u8[L]        byte runs no reference window covered

Factor search is a vectorised numpy scan: the reference's 4-byte grams are
key-sorted once at codec construction, each string's grams are looked up in
bulk with two ``searchsorted`` passes, and the greedy left-to-right walk
only pays per *factor* (match extension compares 64-byte windows), not per
byte — literal gaps jump straight to the next gram hit.
"""

from __future__ import annotations

import numpy as np

#: top bit of ``offs``: the run copies from ``literals``, not the reference
LIT_FLAG = np.uint32(1 << 31)
OFF_MASK = np.uint32((1 << 31) - 1)

#: gram width the reference index is built over (also the match floor)
_GRAM = 4
#: match extension compares windows of this many bytes at a time
_EXTEND_CHUNK = 64


def _as_u8(buf) -> np.ndarray:
    """Coerce a reference (ndarray / memmap / bytes-like) to a u8 array."""
    if isinstance(buf, np.ndarray):
        return buf if buf.dtype == np.uint8 else buf.astype(np.uint8)
    return np.frombuffer(bytes(buf), dtype=np.uint8)


def _grams(a: np.ndarray) -> np.ndarray:
    """u32 big-endian packing of every 4-byte window of ``a``."""
    a32 = a.astype(np.uint32)
    return (a32[:-3] << 24) | (a32[1:-2] << 16) | (a32[2:-1] << 8) | a32[3:]


class RLZCodec:
    """Greedy RLZ factorizer over a fixed ``reference`` byte array.

    ``min_match`` is the shortest copy factor worth emitting (shorter runs
    become literals — a copy factor costs 8 bytes of ``offs``+``lens``, so
    sub-8-byte matches rarely pay). ``max_candidates`` bounds how many
    reference positions sharing a query's gram are extended per factor.
    """

    def __init__(self, reference, *, min_match: int = 8,
                 max_candidates: int = 4):
        if min_match < _GRAM:
            raise ValueError(f"min_match must be >= {_GRAM}, got {min_match}")
        self.reference = np.ascontiguousarray(_as_u8(reference))
        self.min_match = int(min_match)
        self.max_candidates = int(max_candidates)
        if self.reference.size >= _GRAM:
            keys = _grams(self.reference)
            order = np.argsort(keys, kind="stable").astype(np.int64)
            self._keys = keys[order]
            self._order = order
        else:
            self._keys = np.zeros(0, dtype=np.uint32)
            self._order = np.zeros(0, dtype=np.int64)

    # ---------------------------------------------------------------- encode
    def _best_match(self, s: np.ndarray, pos: int,
                    lo: int, hi: int) -> tuple[int, int]:
        """Longest extension among the candidate reference positions whose
        gram equals ``s[pos:pos+4]`` (guaranteed by the key-sorted lookup
        that produced ``[lo, hi)``)."""
        ref = self.reference
        limit_s = s.size - pos
        best_len, best_off = 0, 0
        for c in self._order[lo:min(hi, lo + self.max_candidates)]:
            c = int(c)
            limit = min(ref.size - c, limit_s)
            m = _GRAM
            while m < limit:
                step = min(_EXTEND_CHUNK, limit - m)
                neq = np.flatnonzero(
                    ref[c + m:c + m + step] != s[pos + m:pos + m + step])
                if neq.size:
                    m += int(neq[0])
                    break
                m += step
            if m > best_len:
                best_len, best_off = m, c
        return best_len, best_off

    def factorize(self, strings) -> dict[str, np.ndarray]:
        """Factor arrays (``starts``/``offs``/``lens``/``literals``) for
        ``strings``, decodable per string by :func:`decode_ids`."""
        starts = np.zeros(len(strings) + 1, dtype=np.int64)
        offs: list[int] = []
        lens: list[int] = []
        lit_parts: list[bytes] = []
        lit_total = 0
        lit_flag = int(LIT_FLAG)
        for k, s in enumerate(strings):
            a = np.frombuffer(bytes(s), dtype=np.uint8)
            n = a.size
            if n >= _GRAM and self._keys.size:
                grams = _grams(a)
                ls = np.searchsorted(self._keys, grams, side="left")
                rs = np.searchsorted(self._keys, grams, side="right")
                has = rs > ls
                # next position at/after p holding a candidate (n = none)
                hidx = np.where(has, np.arange(has.size, dtype=np.int64), n)
                next_hit = np.minimum.accumulate(hidx[::-1])[::-1]
            else:
                has = np.zeros(0, dtype=bool)
                ls = rs = next_hit = np.zeros(0, dtype=np.int64)
            pos, lit0 = 0, -1
            while pos < n:
                blen = 0
                if pos < has.size and has[pos]:
                    blen, boff = self._best_match(
                        a, pos, int(ls[pos]), int(rs[pos]))
                if blen >= self.min_match:
                    if lit0 >= 0:
                        offs.append(lit_flag | lit_total)
                        lens.append(pos - lit0)
                        lit_parts.append(a[lit0:pos].tobytes())
                        lit_total += pos - lit0
                        lit0 = -1
                    offs.append(boff)
                    lens.append(blen)
                    pos += blen
                else:
                    if lit0 < 0:
                        lit0 = pos
                    nxt = pos + 1
                    if nxt >= has.size:
                        nxt = n            # no grams left: rest is literal
                    elif not has[nxt]:
                        nxt = int(next_hit[nxt])
                    pos = max(nxt, pos + 1)
            if lit0 >= 0:
                offs.append(lit_flag | lit_total)
                lens.append(n - lit0)
                lit_parts.append(a[lit0:n].tobytes())
                lit_total += n - lit0
            starts[k + 1] = len(offs)
        return {
            "starts": starts,
            "offs": np.asarray(offs, dtype=np.uint32),
            "lens": np.asarray(lens, dtype=np.uint32),
            "literals": (np.frombuffer(b"".join(lit_parts), dtype=np.uint8)
                         if lit_parts else np.zeros(0, dtype=np.uint8)),
        }


# -------------------------------------------------------------------- decode
def decode_ids(reference, arrays: dict[str, np.ndarray], ids) -> list[bytes]:
    """Decode the strings named by ``ids`` (local to the factorized batch).

    One vectorised gather per call, independent of batch composition: the
    requested factor ranges concatenate (repeat/cumsum trick), every output
    byte resolves its source position in bulk, and copy vs literal runs are
    split by the ``offs`` top bit. Work is O(factors + decoded bytes) for
    exactly the requested strings — the random-access contract.
    """
    ids = np.asarray(ids, dtype=np.int64)
    if ids.size == 0:
        return []
    starts = np.asarray(arrays["starts"], dtype=np.int64)
    f0 = starts[ids]
    fcnt = starts[ids + 1] - f0
    total_f = int(fcnt.sum())
    if total_f == 0:
        return [b""] * len(ids)
    fbase = np.cumsum(fcnt) - fcnt
    fidx = (np.repeat(f0, fcnt)
            + np.arange(total_f, dtype=np.int64) - np.repeat(fbase, fcnt))
    o = np.asarray(arrays["offs"])[fidx]
    fl = np.asarray(arrays["lens"])[fidx].astype(np.int64)
    nbytes = int(fl.sum())
    bstart = np.cumsum(fl) - fl
    src = ((o & OFF_MASK).astype(np.int64).repeat(fl)
           + np.arange(nbytes, dtype=np.int64) - np.repeat(bstart, fl))
    is_lit = np.repeat((o & LIT_FLAG) != 0, fl)
    out = np.empty(nbytes, dtype=np.uint8)
    if is_lit.any():
        out[is_lit] = np.asarray(arrays["literals"])[src[is_lit]]
        hot = ~is_lit
        out[hot] = _as_u8(reference)[src[hot]]
    else:
        out = _as_u8(reference)[src]
    # per-string byte bounds via the factor-boundary positions of the
    # gathered length cumsum (reduceat would trip on empty strings)
    cs = np.concatenate((np.zeros(1, dtype=np.int64), np.cumsum(fl)))
    fend = np.cumsum(fcnt)
    b1 = cs[fend]
    b0 = cs[fend - fcnt]
    buf = out.tobytes()
    return [buf[int(b0[k]):int(b1[k])] for k in range(len(ids))]


def decode_range(reference, arrays: dict[str, np.ndarray],
                 lo: int, hi: int) -> list[bytes]:
    """Decode the contiguous local id range ``[lo, hi)``."""
    return decode_ids(reference, arrays, np.arange(lo, hi, dtype=np.int64))


def rlz_nbytes(arrays: dict[str, np.ndarray]) -> int:
    """Total encoded size of a factorization (all four arrays)."""
    return int(sum(np.asarray(a).nbytes for a in arrays.values()))
