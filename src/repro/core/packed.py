"""Packed dictionary artifact + static LPM arrays (paper §3.4.3, §3.5, Fig. 5/7).

After training, the dictionary is frozen into:

* the decode layout of Figure 7 — a contiguous byte blob + a u32 offset
  array (entry ``i`` is ``blob[offsets[i]:offsets[i+1]]``), plus the
  OnPair16 fast-decode matrix: a ``(N, 16)`` u8 table so every token decodes
  with one fixed-size row copy (Algorithm 3's unconditional 16-byte copy);

* the static LPM layout of Figure 5, adapted for TPU (DESIGN.md §3): instead
  of PtrHash + cache-line bucket-info records, both tiers become flat
  parallel arrays with open-addressing hash tables, so lookups are plain
  gathers and probing is a bounded loop. Packed u64 values are stored as
  (lo, hi) u32 pairs because TPUs (and default JAX) have no native u64.

All hashes are 32-bit multiplicative mixes computed identically here (numpy)
and in the JAX kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.artifact import DictArtifact

_ARANGE16 = np.arange(16, dtype=np.int64)

U32 = np.uint32
_M32 = 0xFFFFFFFF


def mix32(x: int) -> int:
    """32-bit finaliser (murmur3-style); scalar version used at build time."""
    x &= _M32
    x = (x * 0x85EBCA6B) & _M32
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & _M32
    x ^= x >> 16
    return x


def hash_key(lo: int, hi: int, length: int) -> int:
    """Hash of a packed (lo, hi, len) key; must match kernels/ref exactly."""
    return mix32(lo ^ mix32(hi ^ mix32(length)))


def hash_key_long(lo: int, hi: int, lo2: int, hi2: int, length: int) -> int:
    """Hash of a full 16-byte packed key (bounded long entries); must match
    the vectorised probe in core.lpm exactly."""
    return mix32(lo ^ mix32(hi ^ mix32(lo2 ^ mix32(hi2 ^ mix32(length)))))


def split_u64(value: int) -> tuple[int, int]:
    return value & _M32, (value >> 32) & _M32


def _pack_lo_hi(entry: bytes) -> tuple[int, int]:
    v = int.from_bytes(entry[:8], "little")
    return split_u64(v)


def _build_table(keys: list[tuple[int, int, int]], payloads: list[int],
                 empty_payload: int) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                              np.ndarray, int]:
    """Open-addressing (linear probe) table over (lo, hi, len) keys.

    Returns (tbl_lo, tbl_hi, tbl_len, tbl_payload, max_probes). Empty slots
    have len == 0 (real entries always have len >= 1).
    """
    n = len(keys)
    size = 16
    while size < 2 * max(n, 1):
        size *= 2
    tbl_lo = np.zeros(size, dtype=U32)
    tbl_hi = np.zeros(size, dtype=U32)
    tbl_len = np.zeros(size, dtype=np.int32)
    tbl_payload = np.full(size, empty_payload, dtype=np.int32)
    mask = size - 1
    max_probes = 1
    for (lo, hi, length), payload in zip(keys, payloads):
        slot = hash_key(lo, hi, length) & mask
        probes = 1
        while tbl_len[slot] != 0:
            slot = (slot + 1) & mask
            probes += 1
        tbl_lo[slot] = lo
        tbl_hi[slot] = hi
        tbl_len[slot] = length
        tbl_payload[slot] = payload
        max_probes = max(max_probes, probes)
    return tbl_lo, tbl_hi, tbl_len, tbl_payload, max_probes


def _build_table_long(keys: list[tuple[int, int, int, int, int]],
                      payloads: list[int]):
    """Open-addressing table over full 16-byte packed keys (long entries)."""
    n = len(keys)
    size = 16
    while size < 2 * max(n, 1):
        size *= 2
    tbl = [np.zeros(size, dtype=U32) for _ in range(4)]
    tbl_len = np.zeros(size, dtype=np.int32)
    tbl_payload = np.full(size, -1, dtype=np.int32)
    mask = size - 1
    max_probes = 1
    for (lo, hi, lo2, hi2, length), payload in zip(keys, payloads):
        slot = hash_key_long(lo, hi, lo2, hi2, length) & mask
        probes = 1
        while tbl_len[slot] != 0:
            slot = (slot + 1) & mask
            probes += 1
        tbl[0][slot], tbl[1][slot], tbl[2][slot], tbl[3][slot] = lo, hi, lo2, hi2
        tbl_len[slot] = length
        tbl_payload[slot] = payload
        max_probes = max(max_probes, probes)
    return tbl[0], tbl[1], tbl[2], tbl[3], tbl_len, tbl_payload, max_probes


@dataclass
class PackedDictionary:
    """Frozen OnPair/OnPair16 dictionary with decode + static-LPM layouts."""

    entries: list[bytes]
    variant16: bool

    # --- decode layout (Figure 7 + Algorithm 3) ---
    blob: np.ndarray          # u8[total_data_bytes]
    offsets: np.ndarray       # u32[n+1]
    lens: np.ndarray          # i32[n]
    mat16: np.ndarray         # u8[n, 16]  (first 16 bytes, zero padded)

    # --- static LPM: short tier (<= 8 bytes) ---
    s_lo: np.ndarray
    s_hi: np.ndarray
    s_len: np.ndarray         # 0 = empty slot
    s_tok: np.ndarray
    s_probe_max: int

    # --- static LPM: long tier (> 8 bytes), bucketed by 8-byte prefix ---
    p_lo: np.ndarray
    p_hi: np.ndarray
    p_len: np.ndarray         # 0 = empty, 8 = occupied (prefix keys are 8 B)
    p_bucket: np.ndarray      # index into bucket arrays, -1 on empty slots
    p_probe_max: int
    bucket_start: np.ndarray  # i32[num_buckets]
    bucket_size: np.ndarray   # i32[num_buckets]
    max_bucket_size: int
    suf_lo: np.ndarray        # u32[M]  first 8 suffix bytes, packed LE
    suf_hi: np.ndarray
    suf_len: np.ndarray       # i32[M]  full suffix length (may exceed 8 for OnPair)
    suf_tok: np.ndarray       # i32[M]
    # byte masks selecting each suffix's live bytes of (suf_lo, suf_hi) —
    # precomputed so the batched parser compares without per-call mask math
    suf_mlo: np.ndarray       # u32[M]
    suf_mhi: np.ndarray       # u32[M]

    # --- static LPM: exact long-entry table (9..16-byte entries) ---
    # Bounded (variant16) dictionaries admit a second long-tier layout: every
    # long entry fits one 16-byte window, so the batched parser can replace
    # the bucket *scan* with 8 exact hash probes (lengths 16 down to 9) —
    # rectangular work per string, like the short tier. Only consulted when
    # ``variant16`` (unbounded entries still need the bucket scan).
    l_lo: np.ndarray          # u32  entry bytes 0..3, packed LE
    l_hi: np.ndarray          # u32  entry bytes 4..7
    l_lo2: np.ndarray         # u32  entry bytes 8..11 (zero padded)
    l_hi2: np.ndarray         # u32  entry bytes 12..15 (zero padded)
    l_len: np.ndarray         # i32  0 = empty slot
    l_tok: np.ndarray         # i32
    l_probe_max: int

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, entries: list[bytes]) -> "PackedDictionary":
        n = len(entries)
        lens = np.array([len(e) for e in entries], dtype=np.int32)
        offsets = np.zeros(n + 1, dtype=np.uint32)
        np.cumsum(lens, out=offsets[1:])
        blob = np.frombuffer(b"".join(entries), dtype=np.uint8).copy()
        mat16 = np.zeros((n, 16), dtype=np.uint8)
        for i, e in enumerate(entries):
            head = e[:16]
            mat16[i, : len(head)] = np.frombuffer(head, dtype=np.uint8)
        variant16 = bool((lens <= 16).all())

        # short tier
        short_keys, short_payloads = [], []
        for tid, e in enumerate(entries):
            if len(e) <= 8:
                lo, hi = _pack_lo_hi(e)
                short_keys.append((lo, hi, len(e)))
                short_payloads.append(tid)
        s_lo, s_hi, s_len, s_tok, s_probe_max = _build_table(
            short_keys, short_payloads, empty_payload=-1)

        # long tier: group by 8-byte prefix, suffixes sorted descending length
        buckets: dict[tuple[int, int], list[tuple[bytes, int]]] = {}
        for tid, e in enumerate(entries):
            if len(e) > 8:
                buckets.setdefault(_pack_lo_hi(e[:8]), []).append((e[8:], tid))
        prefix_keys, bucket_ids = [], []
        bucket_start_l, bucket_size_l = [], []
        suf_lo_l, suf_hi_l, suf_len_l, suf_tok_l = [], [], [], []
        for (lo, hi), items in buckets.items():
            items.sort(key=lambda it: -len(it[0]))  # stable: ties keep id order
            prefix_keys.append((lo, hi, 8))
            bucket_ids.append(len(bucket_start_l))
            bucket_start_l.append(len(suf_lo_l))
            bucket_size_l.append(len(items))
            for suffix, tid in items:
                sl, sh = _pack_lo_hi(suffix)
                suf_lo_l.append(sl)
                suf_hi_l.append(sh)
                suf_len_l.append(len(suffix))
                suf_tok_l.append(tid)
        p_lo, p_hi, p_len, p_bucket, p_probe_max = _build_table(
            prefix_keys, bucket_ids, empty_payload=-1)

        suf_len_arr = np.array(suf_len_l or [0], dtype=np.int32)
        mlo_n = np.clip(suf_len_arr, 0, 4).astype(np.uint64)
        mhi_n = np.clip(suf_len_arr - 4, 0, 4).astype(np.uint64)
        one = np.uint64(1)
        eight = np.uint64(8)

        # exact long-entry table: every 9..16-byte entry keyed by its full
        # packed bytes (>16-byte entries can't use it and are left out; the
        # table is only consulted for variant16 dictionaries)
        long_keys, long_payloads = [], []
        for tid, e in enumerate(entries):
            if 8 < len(e) <= 16:
                lo, hi = _pack_lo_hi(e)
                lo2, hi2 = _pack_lo_hi(e[8:])
                long_keys.append((lo, hi, lo2, hi2, len(e)))
                long_payloads.append(tid)
        l_lo, l_hi, l_lo2, l_hi2, l_len, l_tok, l_probe_max = \
            _build_table_long(long_keys, long_payloads)

        return cls(
            entries=entries, variant16=variant16,
            blob=blob, offsets=offsets, lens=lens, mat16=mat16,
            s_lo=s_lo, s_hi=s_hi, s_len=s_len, s_tok=s_tok,
            s_probe_max=s_probe_max,
            p_lo=p_lo, p_hi=p_hi, p_len=p_len, p_bucket=p_bucket,
            p_probe_max=p_probe_max,
            bucket_start=np.array(bucket_start_l or [0], dtype=np.int32),
            bucket_size=np.array(bucket_size_l or [0], dtype=np.int32),
            max_bucket_size=int(max(bucket_size_l, default=0)),
            suf_lo=np.array(suf_lo_l or [0], dtype=U32),
            suf_hi=np.array(suf_hi_l or [0], dtype=U32),
            suf_len=suf_len_arr,
            suf_tok=np.array(suf_tok_l or [0], dtype=np.int32),
            suf_mlo=((one << (mlo_n * eight)) - one).astype(U32),
            suf_mhi=((one << (mhi_n * eight)) - one).astype(U32),
            l_lo=l_lo, l_hi=l_hi, l_lo2=l_lo2, l_hi2=l_hi2, l_len=l_len,
            l_tok=l_tok, l_probe_max=l_probe_max,
        )

    # ------------------------------------------------------------- accounting
    @property
    def num_entries(self) -> int:
        return len(self.entries)

    @property
    def data_bytes(self) -> int:
        """Paper Table 4 'Data' column: raw bytes of all entries."""
        return int(self.blob.size)

    @property
    def total_bytes(self) -> int:
        """Paper Table 4 'Total': data region + 4-byte offset array."""
        return self.data_bytes + 4 * (len(self.offsets))

    @property
    def resident_bytes(self) -> int:
        """True in-memory footprint: paper accounting plus the decode matrix
        and the static-LPM hash/bucket/suffix arrays (which Table 4 excludes).
        This is what capacity planning against a serving store should use."""
        arrays = (self.lens, self.mat16, self.s_lo, self.s_hi, self.s_len,
                  self.s_tok, self.p_lo, self.p_hi, self.p_len, self.p_bucket,
                  self.bucket_start, self.bucket_size, self.suf_lo,
                  self.suf_hi, self.suf_len, self.suf_tok)
        return self.total_bytes + sum(a.nbytes for a in arrays)

    # ----------------------------------------------------------------- decode
    def decode_tokens(self, tokens: np.ndarray) -> bytes:
        """Vectorised Algorithm 3 over a full token stream.

        Fast path: every token writes its (zero-padded) first 16 bytes via a
        masked scatter (the numpy analogue of the unconditional 16-byte SIMD
        copy). Slow path: the rare >16-byte entries (unbounded OnPair only)
        append their tails.
        """
        tokens = np.asarray(tokens, dtype=np.int64)
        if tokens.size == 0:
            return b""
        if tokens.size <= 64:
            # Single-string / random-access regime: the vectorised machinery
            # has ~10us of fixed numpy overhead, so short streams are faster
            # through a plain list join (~0.2us/token).
            return b"".join(map(self.entries.__getitem__, tokens.tolist()))
        if self.variant16:
            # Every entry fits one mat16 row, so a row-major boolean select
            # of each row's first len(t) bytes IS the concatenated output —
            # one gather + one select, no per-length passes.
            rows = self.mat16[tokens]
            mask = _ARANGE16[None, :] < self.lens[tokens, None]
            return rows[mask].tobytes()
        lens = self.lens[tokens].astype(np.int64)
        ends = np.cumsum(lens)
        starts = ends - lens
        total = int(ends[-1])
        out = np.zeros(total + 16, dtype=np.uint8)  # +16: fast-path overhang
        rows = self.mat16[tokens]                   # (T, 16)
        clamped = np.minimum(lens, 16)
        # Scatter grouped by token length: one exact vectorised write per
        # distinct length (<= 16 passes), total work ~ output bytes.
        for length in np.unique(clamped):
            L = int(length)
            sel = np.nonzero(clamped == L)[0]
            idx = starts[sel, None] + _ARANGE16[None, :L]
            out[idx.reshape(-1)] = rows[sel, :L].reshape(-1)
        # only non-variant16 dictionaries reach here (variant16 returned
        # above), so >16-byte tails may exist and are appended individually
        long_pos = np.nonzero(lens > 16)[0]
        for t in long_pos:
            tid = tokens[t]
            o = int(self.offsets[tid])
            tail = self.blob[o + 16 : o + int(self.lens[tid])]
            s = int(starts[t]) + 16
            out[s : s + tail.size] = tail
        return out[:total].tobytes()

    def decode_string(self, compressed: bytes) -> bytes:
        """Random-access decode of one independently-compressed string."""
        tokens = np.frombuffer(compressed, dtype="<u2")
        parts = self.entries
        return b"".join(parts[t] for t in tokens)

    # -------------------------------------------------------------- serialise
    # The persistent form of a dictionary is a DictArtifact (table + codec
    # name + format version); the static-LPM/hash arrays are derived
    # deterministically from the entries at build() time, so only the table
    # ships. These helpers exist for callers holding a bare dictionary.
    def to_artifact(self, codec: str | None = None) -> "DictArtifact":
        from repro.core.artifact import DictArtifact
        return DictArtifact.from_entries(
            codec or ("onpair16" if self.variant16 else "onpair"), self.entries)

    @classmethod
    def from_artifact(cls, artifact) -> "PackedDictionary":
        return cls.build(artifact.entries)

    def save(self, path: str) -> None:
        self.to_artifact().save(path)

    @classmethod
    def load(cls, path: str) -> "PackedDictionary":
        from repro.core.artifact import DictArtifact
        return cls.from_artifact(DictArtifact.load(path))

    def to_bytes(self) -> bytes:
        return self.to_artifact().to_bytes()

    @classmethod
    def from_bytes(cls, data: bytes) -> "PackedDictionary":
        from repro.core.artifact import DictArtifact
        return cls.from_artifact(DictArtifact.from_bytes(data))
