"""DictArtifact — the train-once dictionary as a first-class storage object.

The paper's economics (§3.2–3.3) are train-once / use-many: one sequential
training pass produces a dictionary that then serves millions of independent
per-string encodes and decodes. Compressed string dictionaries in the
literature are likewise *storage artifacts* opened independently of training
(LZ-compressed string dictionaries, RLZ web-collection dictionaries), so the
dictionary here is an immutable, serializable value — not hidden mutable
state inside a compressor object.

On-disk container (shared by :class:`DictArtifact` and the corpus/store
persistence in :mod:`repro.core.api` / :mod:`repro.store.store`):

    magic  b"RPROART1"            (8 bytes)
    u32    container version
    u32    header length H
    bytes  header JSON            (codec name, config, stats, array table)
    pad    to 64-byte alignment
    data   arrays, each 64-byte aligned, raw little-endian

Array offsets in the header are *relative to the data region*, so the header
bytes are independent of their own length, and every array can be mapped
read-only straight off disk (``mmap=True`` load path) — opening a multi-MiB
dictionary costs page mapping, not parsing.
"""

from __future__ import annotations

import io
import json
import os
from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

MAGIC = b"RPROART1"
CONTAINER_VERSION = 1
FORMAT_VERSION = 1  # DictArtifact schema version (header["format_version"])
_ALIGN = 64


def _aligned(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


# --------------------------------------------------------------- container IO
def write_container(path: str, header: dict, arrays: dict[str, np.ndarray]) -> None:
    """Write one header + named-array container (atomic via temp rename)."""
    data = dump_container(header, arrays)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def dump_container(header: dict, arrays: dict[str, np.ndarray]) -> bytes:
    contig = {name: np.ascontiguousarray(a) for name, a in arrays.items()}
    table: dict[str, dict] = {}
    rel = 0
    for name, a in contig.items():
        table[name] = {"dtype": a.dtype.str, "shape": list(a.shape),
                       "offset": rel, "nbytes": int(a.nbytes)}
        rel = _aligned(rel + a.nbytes)
    full_header = dict(header)
    full_header["arrays"] = table
    hjson = json.dumps(full_header, sort_keys=True).encode()
    data_start = _aligned(len(MAGIC) + 8 + len(hjson))
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(np.uint32(CONTAINER_VERSION).tobytes())
    buf.write(np.uint32(len(hjson)).tobytes())
    buf.write(hjson)
    buf.write(b"\0" * (data_start - buf.tell()))
    for name, a in contig.items():
        buf.write(b"\0" * (data_start + table[name]["offset"] - buf.tell()))
        buf.write(a.tobytes())
    out = buf.getvalue()
    return out + b"\0" * (_aligned(len(out)) - len(out))


def read_container(path: str, mmap: bool = True) -> tuple[dict, dict[str, np.ndarray]]:
    """Read a container; with ``mmap=True`` arrays are read-only disk maps."""
    if not mmap:
        with open(path, "rb") as f:
            return load_container(f.read())
    with open(path, "rb") as f:
        head = f.read(len(MAGIC) + 8)
        if head[: len(MAGIC)] != MAGIC:
            raise ValueError(f"{path}: not a repro artifact container")
        hlen = int(np.frombuffer(head[len(MAGIC) + 4 :], dtype="<u4")[0])
        header = json.loads(f.read(hlen).decode())
    data_start = _aligned(len(MAGIC) + 8 + hlen)
    arrays: dict[str, np.ndarray] = {}
    for name, at in header.pop("arrays").items():
        if at["nbytes"] == 0:  # mmap cannot map zero bytes
            arrays[name] = np.zeros(at["shape"], dtype=np.dtype(at["dtype"]))
            continue
        arrays[name] = np.memmap(path, dtype=np.dtype(at["dtype"]), mode="r",
                                 offset=data_start + at["offset"],
                                 shape=tuple(at["shape"]))
    return header, arrays


def load_container(data: bytes) -> tuple[dict, dict[str, np.ndarray]]:
    if data[: len(MAGIC)] != MAGIC:
        raise ValueError("not a repro artifact container")
    hlen = int(np.frombuffer(data[len(MAGIC) + 4 : len(MAGIC) + 8], dtype="<u4")[0])
    header = json.loads(data[len(MAGIC) + 8 : len(MAGIC) + 8 + hlen].decode())
    data_start = _aligned(len(MAGIC) + 8 + hlen)
    arrays: dict[str, np.ndarray] = {}
    for name, at in header.pop("arrays").items():
        a = np.frombuffer(data, dtype=np.dtype(at["dtype"]),
                          count=at["nbytes"] // np.dtype(at["dtype"]).itemsize,
                          offset=data_start + at["offset"])
        arrays[name] = a.reshape(at["shape"])
    return header, arrays


# ----------------------------------------------------------------- DictArtifact
@dataclass(frozen=True)
class DictArtifact:
    """Immutable, serializable dictionary: token table + config + version.

    ``train()`` produces one; :class:`~repro.core.codec.Encoder` /
    :class:`~repro.core.codec.Decoder` (or ``registry.codec_from_artifact``)
    consume one — on any host, without retraining. Codecs without a trained
    table (raw, block codecs) carry config only.
    """

    codec: str                                  # registry codec name
    config: dict = field(default_factory=dict)  # codec construction config
    arrays: dict = field(default_factory=dict)  # "blob" u8 + "offsets" u32
    stats: dict = field(default_factory=dict)   # train-time stats (informational)
    version: int = FORMAT_VERSION

    # ------------------------------------------------------------ constructors
    @classmethod
    def from_entries(cls, codec: str, entries: list[bytes],
                     config: dict | None = None,
                     stats: dict | None = None) -> "DictArtifact":
        arrays: dict[str, np.ndarray] = {}
        if entries:
            lens = np.fromiter((len(e) for e in entries), dtype=np.int64,
                               count=len(entries))
            offsets = np.zeros(len(entries) + 1, dtype=np.uint32)
            np.cumsum(lens, out=offsets[1:])
            arrays["blob"] = np.frombuffer(b"".join(entries), dtype=np.uint8)
            arrays["offsets"] = offsets
        return cls(codec=codec, config=dict(config or {}), arrays=arrays,
                   stats=dict(stats or {}))

    @classmethod
    def from_config(cls, codec: str, config: dict | None = None) -> "DictArtifact":
        return cls(codec=codec, config=dict(config or {}))

    # --------------------------------------------------------------- accessors
    @cached_property
    def entries(self) -> list[bytes]:
        """The token table as a list of byte strings (ids = positions)."""
        if "blob" not in self.arrays:
            return []
        raw = np.asarray(self.arrays["blob"]).tobytes()
        off = self.arrays["offsets"]
        return [raw[int(off[i]) : int(off[i + 1])] for i in range(len(off) - 1)]

    @property
    def num_entries(self) -> int:
        return max(0, len(self.arrays.get("offsets", ())) - 1)

    @property
    def data_bytes(self) -> int:
        """Raw bytes of all table entries (paper Table 4 'Data')."""
        blob = self.arrays.get("blob")
        return int(blob.size) if blob is not None else 0

    # ------------------------------------------------------------- persistence
    def _header(self) -> dict:
        return {"kind": "dict_artifact", "format_version": self.version,
                "codec": self.codec, "config": self.config, "stats": self.stats}

    def save(self, path: str) -> None:
        """Write the artifact to ``path`` (compact aligned binary container)."""
        write_container(path, self._header(), self.arrays)

    def to_bytes(self) -> bytes:
        return dump_container(self._header(), self.arrays)

    @classmethod
    def _from_parsed(cls, header: dict, arrays: dict) -> "DictArtifact":
        if header.get("kind") != "dict_artifact":
            raise ValueError(f"container holds {header.get('kind')!r}, "
                             "not a dict_artifact")
        return cls(codec=header["codec"], config=header.get("config", {}),
                   arrays=arrays, stats=header.get("stats", {}),
                   version=header.get("format_version", FORMAT_VERSION))

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "DictArtifact":
        header, arrays = read_container(path, mmap=mmap)
        return cls._from_parsed(header, arrays)

    @classmethod
    def from_bytes(cls, data: bytes) -> "DictArtifact":
        header, arrays = load_container(data)
        return cls._from_parsed(header, arrays)
