"""Byte <-> u64 packing utilities shared by the LPM implementations.

The paper packs up to 8 bytes little-endian into a 64-bit integer so that
prefix comparison reduces to ``count_trailing_zeros(a ^ b) / 8`` (Algorithm 2).
Strings shorter than 8 bytes are zero-padded at the most-significant end, so
the *low-order* bytes always hold the actual prefix.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1


def pack_u64(data: bytes, start: int = 0, length: int | None = None) -> int:
    """Pack ``data[start:start+length]`` (length <= 8) little-endian into an int."""
    if length is None:
        length = min(8, len(data) - start)
    chunk = data[start : start + length]
    return int.from_bytes(chunk, "little")


def unpack_u64(value: int, length: int) -> bytes:
    """Inverse of :func:`pack_u64`."""
    return value.to_bytes(8, "little")[:length]


def ctz64(x: int) -> int:
    """Count trailing zeros of a non-zero 64-bit value (64 for x == 0)."""
    if x == 0:
        return 64
    return ((x & -x).bit_length()) - 1


def shared_prefix_size(s1: int, s2: int) -> int:
    """Algorithm 2: number of matching low-order *bytes* of two packed u64s."""
    diff = (s1 ^ s2) & MASK64
    return ctz64(diff) // 8


def is_prefix_packed(input_val: int, input_len: int, prefix_val: int, prefix_len: int) -> bool:
    """Algorithm 2 ``IsPrefix`` on packed u64 values.

    Zero-padding at the most significant end means ``shared_prefix_size`` can
    over-report when both values run out of real bytes; the ``prefix_len``
    bound (line 6 of Algorithm 2) rules out artificial padding matches.
    """
    if prefix_len > input_len:
        return False
    return shared_prefix_size(input_val, prefix_val) >= prefix_len


def pack_rows_u64(entries: list[bytes]) -> np.ndarray:
    """Vectorised little-endian packing of many <=8-byte strings."""
    out = np.zeros(len(entries), dtype=np.uint64)
    for i, e in enumerate(entries):
        out[i] = np.uint64(int.from_bytes(e[:8], "little"))
    return out


# A multiplicative hash over (packed value, length); the constant is the
# 64-bit golden-ratio multiplier (used instead of PtrHash: see DESIGN.md §3 —
# perfect hashing is replaced by bounded open-addressing probes over flat
# arrays, the TPU/VMEM-friendly analogue).
_GOLDEN = 0x9E3779B97F4A7C15


def hash_u64(value: int, salt: int = 0) -> int:
    x = (value + salt) & MASK64
    x = (x * _GOLDEN) & MASK64
    x ^= x >> 29
    x = (x * 0xBF58476D1CE4E5B9) & MASK64
    x ^= x >> 32
    return x
