"""Stateless Encoder / Decoder objects over a :class:`DictArtifact`.

The v2 split of ``StringCompressor``: training produces an immutable
artifact; per-string encode/decode are stateless operations *constructed
from* that artifact with an explicit backend selector:

    artifact = registry.train("onpair16", strings)
    artifact.save("dict.rpa")
    ...
    art = DictArtifact.load("dict.rpa")             # any host, no retraining
    corpus = Encoder(art).encode(strings)
    Decoder(art, backend="pallas").access(corpus, 17)

Backends:

* ``numpy``  — host path: greedy LPM parse / vectorised Algorithm-3 decode.
  Works for every registered codec; the only backend when JAX is absent.
* ``pallas`` — device path through :class:`repro.kernels.ops.OnPairDevice`
  (encode kernel + per-string decode kernel). Requires JAX and a codec whose
  registry capabilities say ``device_decodable`` (onpair16's bounded-entry
  token-stream layout).

Both backends produce byte-identical results; tests pin that equivalence.
"""

from __future__ import annotations

import numpy as np

import repro.core.registry as registry
from repro.core.api import CompressedCorpus
from repro.core.artifact import DictArtifact

BACKENDS = ("numpy", "pallas")


def _check_backend(artifact: DictArtifact, backend: str):
    """Resolve + validate; returns an OnPairDevice for the pallas backend."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r} (one of {BACKENDS})")
    if backend == "numpy":
        return None
    caps = registry.capabilities(artifact.codec)
    if not caps.device_decodable:
        raise ValueError(f"codec {artifact.codec!r} is not device-decodable "
                         "(registry capability); use backend='numpy'")
    try:
        from repro.kernels.ops import OnPairDevice
    except Exception as e:  # jax missing on this host
        raise ValueError(f"backend='pallas' unavailable: {e}") from None
    return OnPairDevice.from_artifact(artifact)


class Encoder:
    """Stateless per-string encoder constructed from an artifact.

    ``codec`` optionally supplies an already-built host codec for the same
    artifact (e.g. a store's compressor) so its dictionary tables are
    shared instead of rebuilt.
    """

    def __init__(self, artifact: DictArtifact, backend: str = "numpy",
                 codec=None, device=None):
        self.artifact = artifact
        self.backend = backend
        # ``device`` optionally supplies an already-built OnPairDevice for the
        # same artifact (e.g. a store's decode device) so its packed tables
        # and compiled kernels are shared instead of rebuilt.
        if device is not None and backend == "pallas":
            self._device = device
        else:
            self._device = _check_backend(artifact, backend)
        # the host codec (and its PackedDictionary rebuild) is only needed on
        # the numpy path; the pallas path decodes through the device tables
        self._codec = None
        if self._device is None:
            self._codec = (codec if codec is not None
                           else registry.codec_from_artifact(artifact))

    def warm(self) -> None:
        """AOT-compile the device encode buckets (no-op on the numpy path)."""
        if self._device is not None:
            self._device.warm_encode()

    def encode(self, strings: list[bytes]) -> CompressedCorpus:
        """Compress every string independently into one corpus."""
        if self._device is None:
            return self._codec.compress(strings)
        toks = self._device.encode_bucketed(strings)
        counts = np.fromiter((t.size for t in toks), dtype=np.int64,
                             count=len(toks))
        offsets = np.zeros(len(toks) + 1, dtype=np.int64)
        np.cumsum(counts * 2, out=offsets[1:])
        payload = (np.concatenate(toks).astype("<u2").view(np.uint8)
                   if len(toks) else np.zeros(0, dtype=np.uint8))
        return CompressedCorpus(payload=payload, offsets=offsets,
                                raw_bytes=sum(len(s) for s in strings),
                                meta={"compressor":
                                      registry.resolve(self.artifact.codec)})

    def encode_one(self, s: bytes) -> bytes:
        """Compressed payload of a single string."""
        if self._device is None:
            corpus = self._codec.compress([s])
            return corpus.string_payload(0)
        return self._device.encode_to_bytes([s])[0]


class Decoder:
    """Stateless decoder constructed from an artifact."""

    def __init__(self, artifact: DictArtifact, backend: str = "numpy"):
        self.artifact = artifact
        self.backend = backend
        self._device = _check_backend(artifact, backend)
        self._codec = (registry.codec_from_artifact(artifact)
                       if self._device is None else None)
        self._caps = registry.capabilities(artifact.codec)

    @property
    def dictionary(self):
        """The frozen PackedDictionary (token-stream codecs only)."""
        if self._device is not None:
            return self._device.dictionary
        return getattr(self._codec, "dictionary", None)

    def decode_all(self, corpus: CompressedCorpus) -> bytes:
        """Sequential full-corpus decode (concatenated strings)."""
        if self._device is not None:
            tokens = np.asarray(corpus.payload.view("<u2"), dtype=np.int32)
            return self._device.decode_stream(tokens)
        return self._codec.decompress_all(corpus)

    def access(self, corpus: CompressedCorpus, i: int) -> bytes:
        """Random access: string ``i`` alone."""
        if self._device is not None:
            return self.multiget(corpus, [i])[0]
        return self._codec.access(corpus, i)

    def multiget(self, corpus: CompressedCorpus, ids) -> list[bytes]:
        """Batched random access; one kernel launch on the pallas backend."""
        if self._device is not None:
            lists = [np.asarray(corpus.string_tokens(int(i)), dtype=np.int32)
                     for i in ids]
            return self._device.multiget_decode(lists)
        return [self._codec.access(corpus, int(i)) for i in ids]
