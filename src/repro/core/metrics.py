"""Paper metrics: token gain (§3.2.2), length/frequency distributions,
cumulative coverage (Fig. 3/6/8/9/10 inputs)."""

from __future__ import annotations

import numpy as np

from repro.core.packed import PackedDictionary


def token_gain(length: int, freq: int) -> int:
    """token_gain(t) = (l(t) - 2) * f(t) - l(t)   (paper §3.2.2).

    First term: bytes saved replacing the raw substring with a 2-byte ID;
    second term: dictionary space holding the token's content.
    """
    return (length - 2) * freq - length


def token_frequencies(tokens: np.ndarray, num_entries: int) -> np.ndarray:
    """Occurrence count per token id over a compressed stream."""
    return np.bincount(np.asarray(tokens, dtype=np.int64), minlength=num_entries)


def gain_by_token(dictionary: PackedDictionary, tokens: np.ndarray) -> np.ndarray:
    freq = token_frequencies(tokens, dictionary.num_entries)
    lens = dictionary.lens.astype(np.int64)
    return (lens - 2) * freq - lens


def gain_by_length(dictionary: PackedDictionary, tokens: np.ndarray,
                   max_len: int | None = None) -> dict[int, dict[str, int]]:
    """Cumulative gain and frequency by token length (paper Fig. 3)."""
    gains = gain_by_token(dictionary, tokens)
    freq = token_frequencies(tokens, dictionary.num_entries)
    lens = dictionary.lens.astype(np.int64)
    if max_len is None:
        max_len = int(lens.max())
    out: dict[int, dict[str, int]] = {}
    for L in range(1, max_len + 1):
        sel = lens == L
        out[L] = {"gain": int(gains[sel].sum()), "freq": int(freq[sel].sum())}
    return out


def bucket_size_histogram(dictionary: PackedDictionary) -> dict[int, int]:
    """Distribution of long-pattern bucket sizes (paper Fig. 6)."""
    sizes = dictionary.bucket_size
    if dictionary.p_len.max(initial=0) == 0:
        return {}
    uniq, cnt = np.unique(sizes, return_counts=True)
    return {int(u): int(c) for u, c in zip(uniq, cnt)}


def avg_token_length(dictionary: PackedDictionary, tokens: np.ndarray) -> float:
    """Average decoded length per token in a compressed stream (Table 1)."""
    if len(tokens) == 0:
        return 0.0
    return float(dictionary.lens[np.asarray(tokens, dtype=np.int64)].mean())


# --------------------------------------------------------- serving metrics
def latency_summary(samples_s, percentiles=(50.0, 99.0)) -> dict[str, float]:
    """Summarise a latency sample set (seconds) into mean/percentile stats.

    Shared by the store/serving layer (repro.store.stats) and the benchmark
    harness so every surface reports the same p50/p99 definition
    (linear-interpolated percentiles over the observed samples).
    """
    arr = np.asarray(list(samples_s), dtype=np.float64)
    if arr.size == 0:
        out = {f"p{p:g}_us": 0.0 for p in percentiles}
        out.update(count=0, mean_us=0.0)
        return out
    out = {f"p{p:g}_us": float(np.percentile(arr, p)) * 1e6
           for p in percentiles}
    out.update(count=int(arr.size), mean_us=float(arr.mean()) * 1e6)
    return out


def throughput_mib_s(nbytes: int, seconds: float) -> float:
    return nbytes / float(1 << 20) / max(seconds, 1e-12)


class LatencyReservoir:
    """Bounded latency sample store: append until full, then overwrite the
    oldest (ring). One policy shared by every serving-layer recorder so the
    bound and summary definition cannot drift between surfaces."""

    def __init__(self, max_samples: int = 65536):
        self.max_samples = int(max_samples)
        self._samples: list[float] = []
        self._pos = 0

    def record(self, seconds: float) -> None:
        if len(self._samples) < self.max_samples:
            self._samples.append(seconds)
        else:
            self._samples[self._pos % self.max_samples] = seconds
            self._pos += 1

    def summary(self, percentiles=(50.0, 99.0)) -> dict[str, float]:
        return latency_summary(self._samples, percentiles)


def cumulative_coverage(dictionary: PackedDictionary, tokens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(dictionary bytes, cumulative token coverage) sorted by frequency desc
    (paper Fig. 10): how much of the compressed stream is served by the top-k
    most frequent tokens, vs the dictionary bytes needed to hold them."""
    freq = token_frequencies(tokens, dictionary.num_entries)
    order = np.argsort(-freq, kind="stable")
    mem = np.cumsum(dictionary.lens.astype(np.int64)[order])
    cov = np.cumsum(freq[order]) / max(1, len(tokens))
    return mem, cov
