"""Paper metrics: token gain (§3.2.2), length/frequency distributions,
cumulative coverage (Fig. 3/6/8/9/10 inputs)."""

from __future__ import annotations

import numpy as np

from repro.core.packed import PackedDictionary


def token_gain(length: int, freq: int) -> int:
    """token_gain(t) = (l(t) - 2) * f(t) - l(t)   (paper §3.2.2).

    First term: bytes saved replacing the raw substring with a 2-byte ID;
    second term: dictionary space holding the token's content.
    """
    return (length - 2) * freq - length


def token_frequencies(tokens: np.ndarray, num_entries: int) -> np.ndarray:
    """Occurrence count per token id over a compressed stream."""
    return np.bincount(np.asarray(tokens, dtype=np.int64), minlength=num_entries)


def gain_by_token(dictionary: PackedDictionary, tokens: np.ndarray) -> np.ndarray:
    freq = token_frequencies(tokens, dictionary.num_entries)
    lens = dictionary.lens.astype(np.int64)
    return (lens - 2) * freq - lens


def gain_by_length(dictionary: PackedDictionary, tokens: np.ndarray,
                   max_len: int | None = None) -> dict[int, dict[str, int]]:
    """Cumulative gain and frequency by token length (paper Fig. 3)."""
    gains = gain_by_token(dictionary, tokens)
    freq = token_frequencies(tokens, dictionary.num_entries)
    lens = dictionary.lens.astype(np.int64)
    if max_len is None:
        max_len = int(lens.max())
    out: dict[int, dict[str, int]] = {}
    for L in range(1, max_len + 1):
        sel = lens == L
        out[L] = {"gain": int(gains[sel].sum()), "freq": int(freq[sel].sum())}
    return out


def bucket_size_histogram(dictionary: PackedDictionary) -> dict[int, int]:
    """Distribution of long-pattern bucket sizes (paper Fig. 6)."""
    sizes = dictionary.bucket_size
    if dictionary.p_len.max(initial=0) == 0:
        return {}
    uniq, cnt = np.unique(sizes, return_counts=True)
    return {int(u): int(c) for u, c in zip(uniq, cnt)}


def avg_token_length(dictionary: PackedDictionary, tokens: np.ndarray) -> float:
    """Average decoded length per token in a compressed stream (Table 1)."""
    if len(tokens) == 0:
        return 0.0
    return float(dictionary.lens[np.asarray(tokens, dtype=np.int64)].mean())


def cumulative_coverage(dictionary: PackedDictionary, tokens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(dictionary bytes, cumulative token coverage) sorted by frequency desc
    (paper Fig. 10): how much of the compressed stream is served by the top-k
    most frequent tokens, vs the dictionary bytes needed to hold them."""
    freq = token_frequencies(tokens, dictionary.num_entries)
    order = np.argsort(-freq, kind="stable")
    mem = np.cumsum(dictionary.lens.astype(np.int64)[order])
    cov = np.cumsum(freq[order]) / max(1, len(tokens))
    return mem, cov
