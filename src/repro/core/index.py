"""Reverse-lookup index over *compressed* string forms (queryable dictionary).

OnPair compresses every string independently against a shared frozen
dictionary, so the greedy LPM parse is deterministic: for a given dictionary
generation each raw string has exactly one encoded byte form.  That makes the
inverse direction — ``locate(string) -> id`` — cheap: encode the query once
and compare *compressed* bytes, no decompression anywhere (Arz/Fischer's
``locate`` operation from LZ-compressed string dictionaries).

Two per-segment structures, both built at seal/compact time:

* an open-addressing hash table over u64 fingerprints of the encoded
  payload bytes (the flat-array idiom of :mod:`repro.core.packed`):
  ``table_fp`` holds fingerprints, ``table_loc`` the segment-local string
  id, ``-1`` marking empty slots.  Collisions are resolved by linear
  probing; candidate hits are verified against the actual payload bytes, so
  fingerprint quality affects speed only, never correctness.  Local ids are
  inserted in ascending order, which means probe-chain order equals
  insertion order and the first byte-verified hit is the *lowest* local id
  for duplicate strings.
* a sorted sidecar: ``perm`` is the permutation of local ids ordered by
  *raw* string bytes (stable, so ties keep ascending-id order).  Binary
  search over ``perm`` plus independent per-hit decode gives
  ``scan_prefix(prefix, limit)`` without materialising the segment.

Both persist into a single ``index.npz`` sidecar per store version; loaders
validate per-segment string counts and fall back to lazy rebuild on any
mismatch rather than serve stale ids.
"""

from __future__ import annotations

import io
from dataclasses import dataclass

import numpy as np

#: FNV-1a 64-bit prime, used as the polynomial base for payload hashing.
_POLY_BASE = np.uint64(0x100000001B3)
#: Golden-ratio odd constant mixed with the length so equal-content
#: prefixes of different lengths fingerprint apart.
_LEN_SALT = np.uint64(0x9E3779B97F4A7C15)

_U64 = np.uint64


def _fmix64(h: np.ndarray) -> np.ndarray:
    """Murmur3 64-bit finaliser: avalanche a u64 array in place-ish."""
    h = h.copy()
    h ^= h >> _U64(33)
    h *= _U64(0xFF51AFD7ED558CCD)
    h ^= h >> _U64(33)
    h *= _U64(0xC4CEB9FE1A85EC53)
    h ^= h >> _U64(33)
    return h


def fingerprints(payload: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """u64 fingerprint per string of a concatenated byte payload.

    ``payload`` is a flat u8 array, ``offsets`` the i64 ``[n+1]`` prefix
    starts (the segment layout).  Computes a polynomial hash of each
    string's bytes — vectorised with a single ``np.add.reduceat`` over
    per-byte terms — then avalanches with the length mixed in.  All u64
    arithmetic wraps mod 2**64 (C semantics), which is exactly what we
    want for a polynomial rolling hash.
    """
    offsets = np.asarray(offsets, dtype=np.int64)
    n = len(offsets) - 1
    lens = (offsets[1:] - offsets[:-1]).astype(np.int64)
    total = int(offsets[-1] - offsets[0])
    base = int(offsets[0])
    sums = np.zeros(n, dtype=np.uint64)
    if total > 0:
        data = np.asarray(payload[base : base + total], dtype=np.uint64)
        # exponent of each byte position, counted from the *end* of its
        # string: exp[i] = (string end - 1) - i
        ends = np.repeat(offsets[1:] - base, lens)
        exp = ends - np.int64(1) - np.arange(total, dtype=np.int64)
        # power table up to the longest string
        max_len = int(lens.max())
        pw = np.ones(max_len, dtype=np.uint64)
        if max_len > 1:
            np.cumprod(np.full(max_len - 1, _POLY_BASE, dtype=np.uint64), out=pw[1:])
        terms = data * pw[exp]
        # reduceat misreads zero-length strings (repeated indices yield the
        # single element, not 0) — only reduce over nonempty starts.
        nz = lens > 0
        if nz.any():
            sums[nz] = np.add.reduceat(terms, (offsets[:-1] - base)[nz])
    with np.errstate(over="ignore"):
        mixed = sums ^ (lens.astype(np.uint64) * _LEN_SALT)
    return _fmix64(mixed)


def fingerprint_one(encoded: bytes) -> int:
    """Fingerprint of a single encoded byte string (query-side helper)."""
    payload = np.frombuffer(encoded, dtype=np.uint8)
    offsets = np.array([0, len(encoded)], dtype=np.int64)
    return int(fingerprints(payload, offsets)[0])


def _table_size(n: int) -> int:
    """Power-of-two table size with load factor <= 0.5 (min 8 slots)."""
    size = 8
    while size < 2 * n:
        size *= 2
    return size


@dataclass
class SegmentIndex:
    """Exact-match + prefix index for one sealed segment.

    ``table_fp``/``table_loc`` form the open-addressing fingerprint table
    over *encoded* payload bytes; ``perm`` is the raw-string sort
    permutation of local ids.  ``n`` is the number of strings indexed —
    callers validate it against the live segment before trusting the index
    (segment indexes can be rebuilt, re-segmented, or loaded from an older
    layout).
    """

    n: int
    table_fp: np.ndarray  # u64[size]
    table_loc: np.ndarray  # i32[size], -1 == empty
    perm: np.ndarray  # i32[n], local ids in raw-string order

    @classmethod
    def build(
        cls,
        payload: np.ndarray,
        offsets: np.ndarray,
        raw_strings: list[bytes],
    ) -> "SegmentIndex":
        """Build from a segment's encoded layout plus its decoded strings."""
        n = len(offsets) - 1
        fps = fingerprints(payload, offsets)
        size = _table_size(n)
        mask = size - 1
        table_fp = np.zeros(size, dtype=np.uint64)
        table_loc = np.full(size, -1, dtype=np.int32)
        for loc in range(n):
            slot = int(fps[loc]) & mask
            while table_loc[slot] != -1:
                slot = (slot + 1) & mask
            table_fp[slot] = fps[loc]
            table_loc[slot] = loc
        perm = np.asarray(
            sorted(range(n), key=raw_strings.__getitem__), dtype=np.int32
        )
        return cls(n=n, table_fp=table_fp, table_loc=table_loc, perm=perm)

    def locate(
        self,
        encoded: bytes,
        payload: np.ndarray,
        offsets: np.ndarray,
    ) -> int | None:
        """Segment-local id of the string whose encoded form is ``encoded``.

        Probes the fingerprint table linearly; every fingerprint hit is
        verified by comparing actual payload bytes, so a false positive
        costs one memcmp and can never return a wrong id.  Duplicate
        strings resolve to the lowest local id (insertion order == probe
        order).  Returns ``None`` on miss.
        """
        size = len(self.table_loc)
        mask = size - 1
        fp = _U64(fingerprint_one(encoded))
        slot = int(fp) & mask
        for _ in range(size):
            loc = int(self.table_loc[slot])
            if loc == -1:
                return None
            if self.table_fp[slot] == fp:
                o0 = int(offsets[loc])
                o1 = int(offsets[loc + 1])
                if o1 - o0 == len(encoded) and (
                    bytes(payload[o0:o1]) == encoded
                ):
                    return loc
            slot = (slot + 1) & mask
        return None

    def scan_prefix(
        self,
        prefix: bytes,
        limit: int | None,
        fetch,
        after: tuple[bytes, int] | None = None,
    ) -> list[tuple[int, bytes]]:
        """Segment-local prefix scan: ``[(local_id, string), ...]``.

        Results come back in ``(string, local_id)`` order — the global
        merge relies on this.  ``fetch(local_id) -> bytes`` decodes one
        string on demand (the index stores no raw text).  ``after`` is an
        exclusive ``(string, local_id)`` resume cursor for pagination.
        ``limit=None`` means unbounded.
        """
        n = self.n
        if n == 0:
            return []
        perm = self.perm
        # lower bound: first perm position whose (string, local) key is
        # > after (when resuming) or whose string is >= prefix.
        lo, hi = 0, n
        while lo < hi:
            mid = (lo + hi) // 2
            loc = int(perm[mid])
            s = fetch(loc)
            if after is not None:
                before = (s, loc) <= after
            else:
                before = s < prefix
            if before:
                lo = mid + 1
            else:
                hi = mid
        out: list[tuple[int, bytes]] = []
        pos = lo
        while pos < n and (limit is None or len(out) < limit):
            loc = int(perm[pos])
            s = fetch(loc)
            if not s.startswith(prefix):
                break
            out.append((loc, s))
            pos += 1
        return out


def dump_indexes(indexes: dict[int, tuple[int, SegmentIndex]]) -> bytes:
    """Serialise per-segment indexes to ``.npz`` bytes.

    ``indexes`` maps segment position (``Segment.index``) to
    ``(base_id, SegmentIndex)``.  Arrays are stored flat under
    ``<pos>_fp`` / ``<pos>_loc`` / ``<pos>_perm`` names with a parallel
    ``layout`` table ``[[pos, base_id, n], ...]`` for load-time
    validation: a reopened corpus may re-segment on different boundaries
    (force-sealed short segments shift every later base), so count alone
    is not enough to prove an index describes the same strings.
    """
    arrays: dict[str, np.ndarray] = {}
    layout = []
    for pos in sorted(indexes):
        base, idx = indexes[pos]
        arrays[f"{pos}_fp"] = idx.table_fp
        arrays[f"{pos}_loc"] = idx.table_loc
        arrays[f"{pos}_perm"] = idx.perm
        layout.append((pos, base, idx.n))
    arrays["layout"] = np.asarray(layout, dtype=np.int64).reshape(-1, 3)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def load_indexes(
    data: bytes, segment_layout: dict[int, tuple[int, int]]
) -> dict[int, SegmentIndex]:
    """Deserialise ``dump_indexes`` output, validating against live segments.

    ``segment_layout`` maps segment position -> ``(base_id, n_strings)``
    of the *live* segmentation.  Any persisted segment whose position,
    base id, or count disagrees (or that no longer exists) is dropped —
    the store lazily rebuilds it — so a stale or re-segmented sidecar can
    never serve wrong ids.  Returns ``{}`` for unreadable payloads.
    """
    try:
        with np.load(io.BytesIO(data)) as zf:
            out: dict[int, SegmentIndex] = {}
            for pos, base, n in zf["layout"]:
                pos, base, n = int(pos), int(base), int(n)
                if segment_layout.get(pos) != (base, n):
                    continue
                out[pos] = SegmentIndex(
                    n=n,
                    table_fp=zf[f"{pos}_fp"],
                    table_loc=zf[f"{pos}_loc"],
                    perm=zf[f"{pos}_perm"],
                )
            return out
    except Exception:
        return {}
