"""Common interface all field-level / block-level compressors implement.

A *corpus* is a list of independent byte strings (paper: rows of a string
column). Compressors turn it into a :class:`CompressedCorpus` — one payload
blob plus per-string byte offsets — so the benchmark harness can measure the
paper's four axes (ratio, compression speed, decompression speed, random
access latency) uniformly across OnPair/OnPair16/BPE/FSST/LZ-block/RAW.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field

import numpy as np


@dataclass
class CompressedCorpus:
    """Concatenated compressed strings + offsets (random-access layout)."""

    payload: np.ndarray            # u8[total_compressed_bytes]
    offsets: np.ndarray            # i64[n+1], byte offsets into payload
    raw_bytes: int                 # original corpus size (payload only)
    meta: dict = field(default_factory=dict)

    @property
    def n_strings(self) -> int:
        return len(self.offsets) - 1

    @property
    def compressed_bytes(self) -> int:
        return int(self.payload.size)

    @property
    def ratio(self) -> float:
        """Compression ratio (raw payload / compressed payload), as in the
        paper's tables: both RAW and compressed layouts need an offset array,
        so offsets cancel and dictionaries are reported separately (Table 4)."""
        return self.raw_bytes / max(1, self.compressed_bytes)

    def string_payload(self, i: int) -> bytes:
        return self.payload[int(self.offsets[i]) : int(self.offsets[i + 1])].tobytes()

    # Token-stream accessors: valid for compressors whose payload is a stream
    # of 2-byte token IDs (onpair / onpair16 / bpe), where every per-string
    # compressed slice has even length.
    def string_tokens(self, i: int) -> np.ndarray:
        """u16 token IDs of string ``i`` — a zero-copy view of the payload."""
        o0, o1 = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.payload[o0:o1].view("<u2")

    def token_counts(self) -> np.ndarray:
        """Tokens per string, i64[n_strings] (2 bytes per token ID)."""
        return ((self.offsets[1:] - self.offsets[:-1]) // 2).astype(np.int64)


@dataclass
class TrainStats:
    train_seconds: float = 0.0
    sample_bytes: int = 0
    dict_entries: int = 0
    dict_data_bytes: int = 0
    dict_total_bytes: int = 0


class StringCompressor(abc.ABC):
    """Train-once, compress/decompress-many string compressor."""

    name: str = "base"

    @abc.abstractmethod
    def train(self, strings: list[bytes], dataset_bytes: int | None = None) -> TrainStats:
        """Build the dictionary/model from (a sample of) the corpus."""

    @abc.abstractmethod
    def compress(self, strings: list[bytes]) -> CompressedCorpus:
        """Compress every string independently (field-level) or in blocks."""

    @abc.abstractmethod
    def decompress_all(self, corpus: CompressedCorpus) -> bytes:
        """Sequentially decode the full corpus; returns concatenated strings."""

    @abc.abstractmethod
    def access(self, corpus: CompressedCorpus, i: int) -> bytes:
        """Random access: materialise string ``i`` alone."""


def pack_corpus(parts: list[bytes], raw_bytes: int, **meta) -> CompressedCorpus:
    offsets = np.zeros(len(parts) + 1, dtype=np.int64)
    np.cumsum([len(p) for p in parts], out=offsets[1:])
    payload = np.frombuffer(b"".join(parts), dtype=np.uint8).copy()
    return CompressedCorpus(payload=payload, offsets=offsets,
                            raw_bytes=raw_bytes, meta=dict(meta))


class RawCompressor(StringCompressor):
    """Uncompressed baseline (paper's RAW row)."""

    name = "raw"

    def train(self, strings, dataset_bytes=None) -> TrainStats:
        return TrainStats()

    def compress(self, strings):
        return pack_corpus(strings, sum(len(s) for s in strings))

    def decompress_all(self, corpus):
        return corpus.payload.tobytes()

    def access(self, corpus, i):
        return corpus.string_payload(i)
