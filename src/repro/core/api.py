"""Common interface all field-level / block-level compressors implement.

A *corpus* is a list of independent byte strings (paper: rows of a string
column). Compressors turn it into a :class:`CompressedCorpus` — one payload
blob plus per-string byte offsets — so the benchmark harness can measure the
paper's four axes (ratio, compression speed, decompression speed, random
access latency) uniformly across OnPair/OnPair16/BPE/FSST/LZ-block/RAW.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field

import numpy as np

from repro.core.artifact import (DictArtifact, dump_container, load_container,
                                 read_container, write_container)


@dataclass
class CompressedCorpus:
    """Concatenated compressed strings + offsets (random-access layout)."""

    payload: np.ndarray            # u8[total_compressed_bytes]
    offsets: np.ndarray            # i64[n+1], byte offsets into payload
    raw_bytes: int                 # original corpus size (payload only)
    meta: dict = field(default_factory=dict)

    @property
    def n_strings(self) -> int:
        return len(self.offsets) - 1

    @property
    def compressed_bytes(self) -> int:
        return int(self.payload.size)

    @property
    def ratio(self) -> float:
        """Compression ratio (raw payload / compressed payload), as in the
        paper's tables: both RAW and compressed layouts need an offset array,
        so offsets cancel and dictionaries are reported separately (Table 4)."""
        return self.raw_bytes / max(1, self.compressed_bytes)

    def string_payload(self, i: int) -> bytes:
        return self.payload[int(self.offsets[i]) : int(self.offsets[i + 1])].tobytes()

    # Token-stream accessors: valid for compressors whose payload is a stream
    # of 2-byte token IDs (onpair / onpair16 / bpe), where every per-string
    # compressed slice has even length.
    def string_tokens(self, i: int) -> np.ndarray:
        """u16 token IDs of string ``i`` — a zero-copy view of the payload."""
        o0, o1 = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.payload[o0:o1].view("<u2")

    def token_counts(self) -> np.ndarray:
        """Tokens per string, i64[n_strings] (2 bytes per token ID)."""
        return ((self.offsets[1:] - self.offsets[:-1]) // 2).astype(np.int64)

    def slice_strings(self, lo: int, hi: int) -> "CompressedCorpus":
        """Sub-corpus covering string ids [lo, hi) with rebased offsets.

        Valid only for field-level layouts where ``offsets`` are per-string
        (token-stream codecs, raw) — block codecs index blocks, not strings.
        raw_bytes is pro-rated by payload share (exact per-string raw sizes
        are not stored)."""
        meta = dict(self.meta)
        if "str_block" in meta:
            raise ValueError("slice_strings: block-layout corpora cannot be "
                             "sliced on string boundaries")
        b0, b1 = int(self.offsets[lo]), int(self.offsets[hi])
        share = ((b1 - b0) / self.payload.size if self.payload.size
                 else (hi - lo) / max(1, self.n_strings))
        return CompressedCorpus(
            payload=self.payload[b0:b1],
            offsets=(self.offsets[lo : hi + 1] - b0).astype(np.int64),
            raw_bytes=int(round(self.raw_bytes * share)), meta=meta)

    # ------------------------------------------------------------- persistence
    def _split_meta(self) -> tuple[dict, dict]:
        """meta -> (json-able scalars, ndarray sections); drops caches."""
        scalars, arrays = {}, {}
        for k, v in self.meta.items():
            if k.startswith("_"):
                continue  # transient (e.g. block decode cache)
            if isinstance(v, np.ndarray):
                arrays[f"meta.{k}"] = v
            else:
                scalars[k] = v
        return scalars, arrays

    def save(self, path: str) -> None:
        """Persist payload + offsets + meta in the shared artifact container."""
        scalars, meta_arrays = self._split_meta()
        header = {"kind": "compressed_corpus", "format_version": 1,
                  "raw_bytes": int(self.raw_bytes), "meta": scalars}
        write_container(path, header,
                        {"payload": self.payload, "offsets": self.offsets,
                         **meta_arrays})

    def to_bytes(self) -> bytes:
        scalars, meta_arrays = self._split_meta()
        header = {"kind": "compressed_corpus", "format_version": 1,
                  "raw_bytes": int(self.raw_bytes), "meta": scalars}
        return dump_container(header, {"payload": self.payload,
                                       "offsets": self.offsets, **meta_arrays})

    @classmethod
    def _from_parsed(cls, header: dict, arrays: dict) -> "CompressedCorpus":
        if header.get("kind") != "compressed_corpus":
            raise ValueError(f"container holds {header.get('kind')!r}, "
                             "not a compressed_corpus")
        meta = dict(header.get("meta", {}))
        for k, v in arrays.items():
            if k.startswith("meta."):
                meta[k[len("meta."):]] = v
        return cls(payload=np.asarray(arrays["payload"], dtype=np.uint8),
                   offsets=np.asarray(arrays["offsets"], dtype=np.int64),
                   raw_bytes=int(header["raw_bytes"]), meta=meta)

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "CompressedCorpus":
        header, arrays = read_container(path, mmap=mmap)
        return cls._from_parsed(header, arrays)

    @classmethod
    def from_bytes(cls, data: bytes) -> "CompressedCorpus":
        return cls._from_parsed(*load_container(data))


@dataclass
class TrainStats:
    train_seconds: float = 0.0
    sample_bytes: int = 0
    dict_entries: int = 0
    dict_data_bytes: int = 0
    dict_total_bytes: int = 0


class StringCompressor(abc.ABC):
    """Train-once, compress/decompress-many string compressor.

    Since API v2 this is the back-compat shim over the three first-class
    pieces: the trained state is an immutable :class:`DictArtifact`
    (``to_artifact`` / ``from_artifact``), and stateless per-string
    encode/decode lives in :class:`repro.core.codec.Encoder` /
    :class:`~repro.core.codec.Decoder`. Subclasses implement the artifact
    hooks so a trained dictionary can be persisted and reopened on another
    host without retraining.
    """

    name: str = "base"

    @abc.abstractmethod
    def train(self, strings: list[bytes], dataset_bytes: int | None = None) -> TrainStats:
        """Build the dictionary/model from (a sample of) the corpus."""

    @abc.abstractmethod
    def compress(self, strings: list[bytes]) -> CompressedCorpus:
        """Compress every string independently (field-level) or in blocks."""

    @abc.abstractmethod
    def decompress_all(self, corpus: CompressedCorpus) -> bytes:
        """Sequentially decode the full corpus; returns concatenated strings."""

    @abc.abstractmethod
    def access(self, corpus: CompressedCorpus, i: int) -> bytes:
        """Random access: materialise string ``i`` alone."""

    # ---------------------------------------------------------- artifact hooks
    def to_artifact(self) -> DictArtifact:
        """Freeze the trained state into a serializable artifact."""
        raise NotImplementedError(f"{self.name}: to_artifact not implemented")

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "StringCompressor":
        """Reconstruct a ready codec from an artifact (no retraining)."""
        raise NotImplementedError(f"{cls.__name__}: from_artifact not implemented")


def pack_corpus(parts: list[bytes], raw_bytes: int, **meta) -> CompressedCorpus:
    offsets = np.zeros(len(parts) + 1, dtype=np.int64)
    np.cumsum([len(p) for p in parts], out=offsets[1:])
    # Single allocation: parts are memcpy'd straight into the payload array
    # (no intermediate b"".join blob + frombuffer copy).
    payload = np.empty(int(offsets[-1]), dtype=np.uint8)
    view = memoryview(payload.data)
    pos = 0
    for p in parts:
        view[pos : pos + len(p)] = p
        pos += len(p)
    return CompressedCorpus(payload=payload, offsets=offsets,
                            raw_bytes=raw_bytes, meta=dict(meta))


class RawCompressor(StringCompressor):
    """Uncompressed baseline (paper's RAW row)."""

    name = "raw"

    def train(self, strings, dataset_bytes=None) -> TrainStats:
        return TrainStats()

    def compress(self, strings):
        return pack_corpus(strings, sum(len(s) for s in strings),
                           compressor=self.name)

    def decompress_all(self, corpus):
        return corpus.payload.tobytes()

    def access(self, corpus, i):
        return corpus.string_payload(i)

    def to_artifact(self) -> DictArtifact:
        return DictArtifact.from_config("raw")

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "RawCompressor":
        return cls()
