"""Block-based baselines (paper §2.1, §4.4): zstd / zlib over 64 KiB blocks.

Strings are grouped into fixed-size blocks before compression so the LZ77
window can exploit cross-string redundancy; random access to string ``i``
requires decompressing its whole block. A one-block cache mirrors the paper's
setup ("when a string is requested, the entire 64 KiB block containing it is
decompressed and stored in memory") — under uniformly random queries the hit
rate is low, which is exactly the trade-off the paper measures.
"""

from __future__ import annotations

import zlib

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - zstandard is installed in this env
    _zstd = None

from repro.core.api import CompressedCorpus, StringCompressor, TrainStats
from repro.core.artifact import DictArtifact


class BlockCompressor(StringCompressor):
    """Shared block machinery; subclasses provide codec_compress/decompress."""

    block_bytes = 64 * 1024

    def __init__(self, block_bytes: int = 64 * 1024):
        self.block_bytes = block_bytes

    # codec hooks -----------------------------------------------------------
    def codec_compress(self, data: bytes) -> bytes:
        raise NotImplementedError

    def codec_decompress(self, data: bytes) -> bytes:
        raise NotImplementedError

    # API -------------------------------------------------------------------
    def train(self, strings, dataset_bytes=None) -> TrainStats:
        return TrainStats()  # block codecs are trained per-block implicitly

    def to_artifact(self) -> DictArtifact:
        """Config-only artifact: block codecs carry no trained table."""
        return DictArtifact.from_config(self.name,
                                        {"block_bytes": self.block_bytes})

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "BlockCompressor":
        return cls(**artifact.config) if artifact.config else cls()

    def compress(self, strings) -> CompressedCorpus:
        blocks: list[bytes] = []
        # per-string: block id + offset inside the (uncompressed) block
        str_block = np.zeros(len(strings), dtype=np.int32)
        str_off = np.zeros(len(strings) + 1, dtype=np.int64)
        cur: list[bytes] = []
        cur_len = 0
        raw = 0
        block_payloads: list[bytes] = []
        for i, s in enumerate(strings):
            raw += len(s)
            if cur_len + len(s) > self.block_bytes and cur:
                block_payloads.append(self.codec_compress(b"".join(cur)))
                cur, cur_len = [], 0
            str_block[i] = len(block_payloads)
            str_off[i] = cur_len
            cur.append(s)
            cur_len += len(s)
        if cur:
            block_payloads.append(self.codec_compress(b"".join(cur)))
        # string end offsets: next string's start or block end; store lengths
        lens = np.array([len(s) for s in strings], dtype=np.int64)
        payload = np.frombuffer(b"".join(block_payloads), dtype=np.uint8).copy()
        boff = np.zeros(len(block_payloads) + 1, dtype=np.int64)
        np.cumsum([len(b) for b in block_payloads], out=boff[1:])
        return CompressedCorpus(
            payload=payload,
            offsets=boff,  # block offsets (field-level offsets don't apply)
            raw_bytes=raw,
            meta=dict(compressor=self.name, str_block=str_block,
                      str_off=str_off[: len(strings)], str_len=lens),
        )

    def decompress_all(self, corpus) -> bytes:
        raw = corpus.payload.tobytes()
        parts = []
        for b in range(len(corpus.offsets) - 1):
            o0, o1 = int(corpus.offsets[b]), int(corpus.offsets[b + 1])
            parts.append(self.codec_decompress(raw[o0:o1]))
        return b"".join(parts)

    def access(self, corpus, i) -> bytes:
        blk = int(corpus.meta["str_block"][i])
        cache = corpus.meta.get("_cache")
        if cache is None or cache[0] != blk:
            o0, o1 = int(corpus.offsets[blk]), int(corpus.offsets[blk + 1])
            data = self.codec_decompress(corpus.payload[o0:o1].tobytes())
            corpus.meta["_cache"] = cache = (blk, data)
        off = int(corpus.meta["str_off"][i])
        return cache[1][off : off + int(corpus.meta["str_len"][i])]


class ZstdBlockCompressor(BlockCompressor):
    name = "zstd-block"

    def __init__(self, level: int = 3, block_bytes: int = 64 * 1024):
        super().__init__(block_bytes)
        assert _zstd is not None, "zstandard not available"
        self.level = level
        self._c = _zstd.ZstdCompressor(level=level)
        self._d = _zstd.ZstdDecompressor()

    def to_artifact(self) -> DictArtifact:
        return DictArtifact.from_config(
            self.name, {"level": self.level, "block_bytes": self.block_bytes})

    def codec_compress(self, data: bytes) -> bytes:
        return self._c.compress(data)

    def codec_decompress(self, data: bytes) -> bytes:
        return self._d.decompress(data)


class ZlibBlockCompressor(BlockCompressor):
    """Stands in for the paper's LZ4 row (stdlib DEFLATE at low level)."""

    name = "zlib-block"

    def __init__(self, level: int = 1, block_bytes: int = 64 * 1024):
        super().__init__(block_bytes)
        self.level = level

    def to_artifact(self) -> DictArtifact:
        return DictArtifact.from_config(
            self.name, {"level": self.level, "block_bytes": self.block_bytes})

    def codec_compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)

    def codec_decompress(self, data: bytes) -> bytes:
        return zlib.decompress(data)
