"""OnPair / OnPair16 — the paper's core contribution (§3).

Training phase (§3.2): a *single sequential pass* over a shuffled random
sample. The sample is tokenised with the current dictionary via longest
prefix matching; adjacent token-pair frequencies are counted in a local hash
map (NOT global statistics — this is the cache-friendly departure from BPE),
and when a pair's count reaches the threshold the pair is merged into a new
token. The new token immediately replaces the last parsed token so that
subsequent pair counting continues with it (Figure 1), and it becomes
matchable for the rest of the pass. Training halts when the dictionary
reaches 65,536 tokens or the sample is exhausted.

Parsing phase (§3.3): every string is independently greedily tokenised into
2-byte token IDs — this per-string independence is what gives O(1) random
access with no block overhead.

OnPair16 (§3.2.2, §3.4.4): entries bounded to 16 bytes and long-pattern
buckets bounded to 128 suffixes, enabling the fixed-size-copy decoder and the
packed-u64 suffix comparisons.
"""

from __future__ import annotations

import math
import time
from dataclasses import asdict, dataclass

import numpy as np

from repro.core.api import CompressedCorpus, StringCompressor, TrainStats, pack_corpus
from repro.core.artifact import DictArtifact
from repro.core.lpm import DynamicLPM, lpm_from_entries, parse_batch
from repro.core.packed import PackedDictionary

MAX_TOKENS = 65536  # 2-byte token IDs (paper §3.1)


@dataclass
class OnPairConfig:
    max_tokens: int = MAX_TOKENS
    #: maximum dictionary entry length; None = unbounded (OnPair),
    #: 16 = OnPair16 (§3.2.2).
    max_entry_len: int | None = None
    #: maximum suffixes per long-pattern bucket; None = unbounded (OnPair),
    #: 128 = OnPair16 (§3.4.4).
    max_bucket: int | None = None
    #: pair-frequency threshold; None = auto max(2, floor(log2(S_MiB))) (§3.2.1).
    threshold: int | None = None
    #: training-sample budget in bytes; the paper trains on a small random
    #: sample and stops early once the dictionary is full.
    sample_bytes: int = 8 << 20
    seed: int = 0

    @staticmethod
    def onpair(**kw) -> "OnPairConfig":
        return OnPairConfig(**kw)

    @staticmethod
    def onpair16(**kw) -> "OnPairConfig":
        kw.setdefault("max_entry_len", 16)
        kw.setdefault("max_bucket", 128)
        return OnPairConfig(**kw)

    @property
    def codec_name(self) -> str:
        return "onpair16" if self.max_entry_len == 16 else "onpair"


def auto_threshold(dataset_bytes: int) -> int:
    """threshold = max(2, floor(log2(S))) with S in MiB (§3.2.1)."""
    mib = dataset_bytes / float(1 << 20)
    if mib <= 1.0:
        return 2
    return max(2, int(math.floor(math.log2(mib))))


@dataclass
class TrainResult:
    entries: list[bytes]
    lpm: DynamicLPM
    scanned_bytes: int
    scanned_strings: int
    threshold: int
    merges_attempted: int
    merges_accepted: int


def train_dictionary(strings: list[bytes], cfg: OnPairConfig,
                     dataset_bytes: int | None = None,
                     sample_order: np.ndarray | None = None) -> TrainResult:
    """Single-pass OnPair dictionary construction (§3.2, Figure 1)."""
    if dataset_bytes is None:
        dataset_bytes = sum(len(s) for s in strings)
    threshold = cfg.threshold if cfg.threshold is not None else auto_threshold(dataset_bytes)

    # Randomly selected, shuffled sample (§3.2): expose the trainer to global
    # rather than local patterns, since construction halts when the dict fills.
    if sample_order is None:
        rng = np.random.default_rng(cfg.seed)
        sample_order = rng.permutation(len(strings))

    entries: list[bytes] = [bytes([b]) for b in range(256)]
    entry_index: set[bytes] = set(entries)
    lpm = DynamicLPM()
    for tid, e in enumerate(entries):
        lpm.insert(e, tid)

    # Local pair-frequency map: (prev_token, cur_token) -> count.
    # A count of -1 marks a pair as finalised (already merged, or rejected by
    # the OnPair16 bounds) so it is never re-attempted.
    counts: dict[tuple[int, int], int] = {}
    max_entry = cfg.max_entry_len
    max_bucket = cfg.max_bucket

    scanned = 0
    scanned_strings = 0
    attempted = accepted = 0
    full = len(entries) >= cfg.max_tokens
    search = lpm.search

    for idx in sample_order:
        if full or scanned >= cfg.sample_bytes:
            break
        s = strings[int(idx)]
        if not s:
            continue
        scanned += len(s)
        scanned_strings += 1
        prev = -1
        pos = 0
        n = len(s)
        while pos < n:
            tid, length = search(s, pos)
            pos += length
            if prev >= 0 and not full:
                key = (prev, tid)
                c = counts.get(key, 0)
                if c >= 0:
                    c += 1
                    if c >= threshold:
                        attempted += 1
                        new_bytes = entries[prev] + entries[tid]
                        ok = True
                        if max_entry is not None and len(new_bytes) > max_entry:
                            ok = False
                        elif new_bytes in entry_index:
                            ok = False
                        elif (max_bucket is not None and len(new_bytes) > 8
                              and lpm.bucket_size(new_bytes) >= max_bucket):
                            ok = False
                        if ok:
                            new_tid = len(entries)
                            entries.append(new_bytes)
                            entry_index.add(new_bytes)
                            lpm.insert(new_bytes, new_tid)
                            accepted += 1
                            # Figure 1: the last parsed token is replaced by
                            # the merged token; pair counting continues with it.
                            tid = new_tid
                            if len(entries) >= cfg.max_tokens:
                                full = True
                        counts[key] = -1
                    else:
                        counts[key] = c
            prev = tid

    return TrainResult(entries=entries, lpm=lpm, scanned_bytes=scanned,
                       scanned_strings=scanned_strings, threshold=threshold,
                       merges_attempted=attempted, merges_accepted=accepted)


class OnPairCompressor(StringCompressor):
    """Field-level compressor API over the OnPair training/parsing phases."""

    def __init__(self, cfg: OnPairConfig | None = None, variant16: bool = False):
        if cfg is None:
            cfg = OnPairConfig.onpair16() if variant16 else OnPairConfig.onpair()
        self.cfg = cfg
        self.name = cfg.codec_name
        self.dictionary: PackedDictionary | None = None
        self._lpm: DynamicLPM | None = None
        self.train_result: TrainResult | None = None
        self._train_stats: TrainStats | None = None

    # ---------------------------------------------------------------- artifact
    def to_artifact(self) -> DictArtifact:
        """Freeze the trained dictionary into a serializable artifact."""
        assert self.dictionary is not None, "train() first"
        stats = asdict(self._train_stats) if self._train_stats else {}
        return DictArtifact.from_entries(self.name, self.dictionary.entries,
                                         config=asdict(self.cfg), stats=stats)

    @classmethod
    def from_artifact(cls, artifact: DictArtifact) -> "OnPairCompressor":
        """Ready-to-use codec from an artifact — rebuilds the decode layout;
        the parsing LPM is rebuilt lazily on first compress()."""
        cfg = OnPairConfig(**artifact.config) if artifact.config else (
            OnPairConfig.onpair16() if artifact.codec == "onpair16"
            else OnPairConfig.onpair())
        comp = cls(cfg)
        comp.dictionary = PackedDictionary.build(artifact.entries)
        return comp

    def _parser(self) -> DynamicLPM:
        """The greedy-parse LPM; rebuilt from the frozen dictionary when this
        codec was reconstructed from an artifact (decode-only paths never
        pay this cost)."""
        if self._lpm is None:
            assert self.dictionary is not None, "train() first"
            self._lpm = lpm_from_entries(self.dictionary.entries)
        return self._lpm

    # ------------------------------------------------------------------ train
    def train(self, strings: list[bytes], dataset_bytes: int | None = None) -> TrainStats:
        t0 = time.perf_counter()
        result = train_dictionary(strings, self.cfg, dataset_bytes=dataset_bytes)
        self.train_result = result
        self._lpm = result.lpm
        self.dictionary = PackedDictionary.build(result.entries)
        dt = time.perf_counter() - t0
        self._train_stats = TrainStats(
            train_seconds=dt,
            sample_bytes=result.scanned_bytes,
            dict_entries=len(result.entries),
            dict_data_bytes=self.dictionary.data_bytes,
            dict_total_bytes=self.dictionary.total_bytes,
        )
        return self._train_stats

    # --------------------------------------------------------------- compress
    def compress(self, strings: list[bytes]) -> CompressedCorpus:
        # Batch-first: one vectorised table walk over the frozen dictionary
        # for the whole batch (paper §3.3 parse, but shared across strings).
        # Only bounded dictionaries qualify — the ≤16-byte entry bound keeps
        # the match loop rectangular (no per-hit tail verification), which is
        # what makes the shared walk faster than per-string parsing. Single
        # strings stay on the per-string dynamic parser, whose fixed overhead
        # is far lower once its LPM is built.
        if (self.dictionary is not None and self.dictionary.variant16
                and len(strings) >= 2):
            payload, counts = parse_batch(self.dictionary, strings)
            offsets = np.zeros(len(strings) + 1, dtype=np.int64)
            np.cumsum(counts * 2, out=offsets[1:])
            return CompressedCorpus(payload=payload.view(np.uint8),
                                    offsets=offsets,
                                    raw_bytes=sum(map(len, strings)),
                                    meta={"compressor": self.name})
        parse = self._parser().parse
        parts: list[bytes] = []
        raw = 0
        for s in strings:
            raw += len(s)
            ids = parse(s)
            parts.append(np.asarray(ids, dtype="<u2").tobytes())
        return pack_corpus(parts, raw, compressor=self.name)

    def compress_string(self, s: bytes) -> bytes:
        return np.asarray(self._parser().parse(s), dtype="<u2").tobytes()

    # ------------------------------------------------------------- decompress
    def decompress_all(self, corpus: CompressedCorpus) -> bytes:
        """Full-corpus decode. Strings are independent token streams of u16
        IDs, so the concatenated payload is itself one token stream — decoded
        with the vectorised Algorithm 3 (PackedDictionary.decode_tokens)."""
        assert self.dictionary is not None
        tokens = corpus.payload.view("<u2")
        return self.dictionary.decode_tokens(np.asarray(tokens))

    def access(self, corpus: CompressedCorpus, i: int) -> bytes:
        """Random access: one string's token slice through the vectorised
        Algorithm 3 decoder (no per-token Python loop)."""
        assert self.dictionary is not None
        return self.dictionary.decode_tokens(corpus.string_tokens(i))


def make_onpair(sample_bytes: int = 8 << 20, seed: int = 0,
                threshold: int | None = None, max_tokens: int = MAX_TOKENS) -> OnPairCompressor:
    return OnPairCompressor(OnPairConfig.onpair(
        sample_bytes=sample_bytes, seed=seed, threshold=threshold, max_tokens=max_tokens))


def make_onpair16(sample_bytes: int = 8 << 20, seed: int = 0,
                  threshold: int | None = None, max_tokens: int = MAX_TOKENS) -> OnPairCompressor:
    return OnPairCompressor(OnPairConfig.onpair16(
        sample_bytes=sample_bytes, seed=seed, threshold=threshold, max_tokens=max_tokens))
