"""Codec registry — every compressor constructible by name, with capability
flags replacing scattered ``variant16`` / isinstance checks.

The registry is the single answer to "what can codec X do?": the store asks
``device_decodable`` before routing multigets at the Pallas kernels, the
benchmark harness asks ``trainable`` before timing a training phase, and the
persistence layer asks ``token_stream`` before slicing corpora on string
boundaries. Capability flags are *static per codec* (they describe the
format, not one trained instance), which is what makes them safe to consult
on a host that has only the artifact, not the trainer.

Canonical names: ``onpair``, ``onpair16``, ``bpe``, ``fsst``, ``lz-block``,
``raw`` (paper Table 3 rows), plus ``zstd-block`` when the optional
``zstandard`` package is present. ``zlib-block`` remains an alias of
``lz-block`` for pre-v2 callers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.core.artifact import DictArtifact


@dataclass(frozen=True)
class CodecCaps:
    """What a codec's *format* supports (per codec, not per instance)."""

    #: payload is a stream of 2-byte token IDs; per-string slices are token
    #: streams, so corpora can be re-sliced on string boundaries and decoded
    #: through PackedDictionary / the device kernels.
    token_stream: bool = False
    #: every dictionary entry is <= 16 bytes (the OnPair16 §3.2.2 bound that
    #: enables the fixed-size-copy decode layout).
    bounded_entries: bool = False
    #: decodable by the Pallas/JAX kernels (requires token_stream + the
    #: 16-byte-row layout).
    device_decodable: bool = False
    #: train() builds a real dictionary/table (vs a no-op for raw/block).
    trainable: bool = False


@dataclass(frozen=True)
class CodecSpec:
    name: str
    caps: CodecCaps
    #: () or (**cfg) -> untrained codec object (StringCompressor API).
    factory: Callable[..., Any]
    #: DictArtifact -> ready-to-use codec object (no training).
    from_artifact: Callable[[DictArtifact], Any]
    aliases: tuple[str, ...] = ()
    #: False when a runtime dep is missing (spec stays listed, create raises).
    available: bool = True
    unavailable_reason: str = ""


_REGISTRY: dict[str, CodecSpec] = {}
_ALIASES: dict[str, str] = {}


def register(spec: CodecSpec) -> CodecSpec:
    _REGISTRY[spec.name] = spec
    for alias in spec.aliases:
        _ALIASES[alias] = spec.name
    return spec


def resolve(name: str) -> str:
    """Canonical codec name (follows aliases); raises KeyError if unknown."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown codec {name!r} (registered: {known})")
    return name


def get_spec(name: str) -> CodecSpec:
    return _REGISTRY[resolve(name)]


def names(include_unavailable: bool = False) -> list[str]:
    return [n for n, s in _REGISTRY.items()
            if include_unavailable or s.available]


def capabilities(name: str) -> CodecCaps:
    return get_spec(name).caps


def create(name: str, **cfg) -> Any:
    """Construct an (untrained) codec by registry name."""
    spec = get_spec(name)
    if not spec.available:
        raise RuntimeError(f"codec {spec.name!r} unavailable: "
                           f"{spec.unavailable_reason}")
    return spec.factory(**cfg)


def train(name: str, strings: list[bytes], dataset_bytes: int | None = None,
          **cfg) -> DictArtifact:
    """Train-once entry point: build codec ``name``, train on ``strings``,
    return the immutable artifact (the only thing worth persisting)."""
    codec = create(name, **cfg)
    codec.train(strings, dataset_bytes)
    return codec.to_artifact()


def codec_from_artifact(artifact: DictArtifact) -> Any:
    """Reconstruct a ready-to-use codec from an artifact — no retraining."""
    return get_spec(artifact.codec).from_artifact(artifact)


# ----------------------------------------------------------- registrations
def _register_builtin() -> None:
    from repro.core.api import RawCompressor
    from repro.core.blockcomp import ZlibBlockCompressor, ZstdBlockCompressor
    from repro.core.bpe import BPECompressor
    from repro.core.fsst import FSSTCompressor
    from repro.core.onpair import make_onpair, make_onpair16, OnPairCompressor

    register(CodecSpec(
        name="raw",
        caps=CodecCaps(),
        factory=RawCompressor,
        from_artifact=RawCompressor.from_artifact))
    register(CodecSpec(
        name="onpair",
        caps=CodecCaps(token_stream=True, trainable=True),
        factory=make_onpair,
        from_artifact=OnPairCompressor.from_artifact))
    register(CodecSpec(
        name="onpair16",
        caps=CodecCaps(token_stream=True, bounded_entries=True,
                       device_decodable=True, trainable=True),
        factory=make_onpair16,
        from_artifact=OnPairCompressor.from_artifact))
    register(CodecSpec(
        name="bpe",
        caps=CodecCaps(token_stream=True, trainable=True),
        factory=BPECompressor,
        from_artifact=BPECompressor.from_artifact))
    register(CodecSpec(
        name="fsst",
        caps=CodecCaps(bounded_entries=True, trainable=True),
        factory=FSSTCompressor,
        from_artifact=FSSTCompressor.from_artifact))
    register(CodecSpec(
        name="lz-block",
        caps=CodecCaps(),
        factory=ZlibBlockCompressor,
        from_artifact=ZlibBlockCompressor.from_artifact,
        aliases=("zlib-block",)))
    try:
        import zstandard  # noqa: F401
        zstd_ok, why = True, ""
    except ImportError:
        zstd_ok, why = False, "zstandard not installed"
    register(CodecSpec(
        name="zstd-block",
        caps=CodecCaps(),
        factory=ZstdBlockCompressor,
        from_artifact=ZstdBlockCompressor.from_artifact,
        available=zstd_ok, unavailable_reason=why))


_register_builtin()
