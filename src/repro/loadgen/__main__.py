"""``python -m repro.loadgen`` — drive a cluster, gate the SLO, write the report.

Attach to a live cluster::

    python -m repro.loadgen --spec spec.json --url tcp://h:p,h:p --duration 10

or spawn (and tear down) a local multi-process one, building a synthetic
demo corpus if the directory is empty::

    python -m repro.loadgen --spawn /tmp/lg --demo --shards 2 --duration 10

Exit status is the gate: 0 = SLO met, 1 = violated (CI wires this
straight into bench-smoke), 2 = run failed outright.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.client import connect
from repro.loadgen.cluster import LocalCluster, build_demo_corpus
from repro.loadgen.driver import run_workload
from repro.loadgen.slo import build_report, snapshot_server_states, write_report
from repro.loadgen.spec import WorkloadSpec


def _parse_metrics_addrs(raw: str | None):
    if not raw:
        return None
    out = []
    for part in raw.split(","):
        host, _, port = part.strip().rpartition(":")
        out.append((host or "127.0.0.1", int(port)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.loadgen",
        description=__doc__.splitlines()[0])
    ap.add_argument("--spec", help="WorkloadSpec JSON file (default: a "
                    "closed-loop 70/30 get/multiget zipf mix)")
    ap.add_argument("--url", help="store URL to attach to (tcp://h:p,...)")
    ap.add_argument("--spawn", metavar="DIR",
                    help="spawn a local cluster over this sharded directory "
                    "instead of attaching")
    ap.add_argument("--demo", action="store_true",
                    help="with --spawn: build a synthetic corpus under DIR "
                    "first if none exists")
    ap.add_argument("--shards", type=int, default=2,
                    help="shard count for --demo corpus build (default 2)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="with --spawn: read-only replicas per shard")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="run length in seconds (default 10)")
    ap.add_argument("--metrics-addrs",
                    help="comma-separated host:port metrics endpoints for "
                    "scrape-based collection (default: stats RPC extension)")
    ap.add_argument("--out", help="write the SLO report JSON here "
                    "(default: stdout only)")
    ap.add_argument("--dir-path",
                    help="cluster manifest directory (enables replica "
                    "autodiscovery when attaching via --url)")
    args = ap.parse_args(argv)
    if bool(args.url) == bool(args.spawn):
        ap.error("exactly one of --url / --spawn is required")

    spec = (WorkloadSpec.from_file(args.spec) if args.spec
            else WorkloadSpec())

    cluster = None
    try:
        if args.spawn:
            if args.demo:
                n = build_demo_corpus(args.spawn, n_shards=args.shards)
                print(f"demo corpus ready: {n} strings x {args.shards} shards",
                      file=sys.stderr)
            cluster = LocalCluster.spawn(args.spawn, replicas=args.replicas)
            url, connect_kw = cluster.url, cluster.connect_kw()
            metrics_addrs = cluster.metrics_addrs
        else:
            url = args.url
            connect_kw = {"dir_path": args.dir_path} if args.dir_path else {}
            metrics_addrs = _parse_metrics_addrs(args.metrics_addrs)

        with connect(url, **connect_kw) as client:
            before = snapshot_server_states(client, metrics_addrs)
            result = run_workload(client, spec, args.duration)
            after = snapshot_server_states(client, metrics_addrs)
            report = build_report(spec, result, before, after,
                                  client=client, metrics_addrs=metrics_addrs)
    except (OSError, ConnectionError, ValueError, RuntimeError) as exc:
        print(f"loadgen failed: {exc}", file=sys.stderr)
        return 2
    finally:
        if cluster is not None:
            cluster.close()

    if args.out:
        write_report(args.out, report)
    json.dump(report, sys.stdout, indent=2)
    print()
    if not report["passed"]:
        names = ", ".join(v["slo"] for v in report["violations"])
        print(f"SLO VIOLATED: {names}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
