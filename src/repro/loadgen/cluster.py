"""Spawn-or-attach: a throwaway multi-process cluster for one load run.

``python -m repro.loadgen --spawn <dir>`` needs real sockets and real
process isolation — an in-thread server shares the GIL with the driver
and understates every latency. :class:`LocalCluster` launches one
``python -m repro.net`` process per shard (plus optional read-only
replicas), waits on each ``SHARD_SERVER_READY`` announce line, records
replica addresses into the cluster manifest (so the client's replica
autodiscovery wires read load-balancing on connect), and tears everything
down on exit. Children run ``REPRO_NO_JAX=1`` — serving needs numpy only,
and skipping the jax import keeps spawn latency off the measurement.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

from repro.distributed.shard_store import record_replicas

_READY_RE = re.compile(
    r"SHARD_SERVER_READY port=(?P<port>\d+)"
    r".*?(?:metrics_port=(?P<mport>\d+))?\s+dir=")
_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _child_env() -> dict:
    env = {**os.environ, "REPRO_NO_JAX": "1"}
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


class LocalCluster:
    """Shard server processes over one sharded directory; context-managed."""

    def __init__(self, dir_path: str):
        self.dir = dir_path
        self.procs: list[subprocess.Popen] = []
        self.addresses: list[tuple[str, int]] = []      # primaries, shard order
        self.metrics_addrs: list[tuple[str, int]] = []  # primaries, shard order
        self.replica_addresses: dict[int, list[tuple[str, int]]] = {}

    # ------------------------------------------------------------------ spawn
    def _launch(self, shard: int, read_only: bool,
                metrics: bool) -> tuple[tuple[str, int], tuple[str, int] | None]:
        argv = [sys.executable, "-m", "repro.net",
                os.path.join(self.dir, f"shard-{shard:04d}")]
        if read_only:
            argv.append("--read-only")
        if metrics:
            argv += ["--metrics-port", "0"]
        proc = subprocess.Popen(argv, stdout=subprocess.PIPE,
                                stderr=subprocess.DEVNULL, text=True,
                                env=_child_env())
        line = proc.stdout.readline()
        m = _READY_RE.search(line or "")
        if not m:
            proc.terminate()
            self.close()
            raise RuntimeError(
                f"shard server {shard} (read_only={read_only}) never became "
                f"ready: {line!r}")
        self.procs.append(proc)
        addr = ("127.0.0.1", int(m.group("port")))
        maddr = (("127.0.0.1", int(m.group("mport")))
                 if m.group("mport") else None)
        return addr, maddr

    @classmethod
    def spawn(cls, dir_path: str, n_shards: int | None = None,
              replicas: int = 0, metrics: bool = True) -> "LocalCluster":
        """Launch primaries for every ``shard-NNNN`` under ``dir_path``
        (``n_shards`` limits/checks the count), plus ``replicas`` read-only
        servers per shard, recorded in the manifest for autodiscovery."""
        found = sorted(d for d in os.listdir(dir_path)
                       if re.fullmatch(r"shard-\d{4}", d))
        if not found:
            raise FileNotFoundError(f"no shard-NNNN dirs under {dir_path}")
        if n_shards is not None and len(found) != n_shards:
            raise ValueError(
                f"{dir_path} holds {len(found)} shards, expected {n_shards}")
        cluster = cls(dir_path)
        try:
            for k in range(len(found)):
                addr, maddr = cluster._launch(k, read_only=False,
                                              metrics=metrics)
                cluster.addresses.append(addr)
                if maddr:
                    cluster.metrics_addrs.append(maddr)
            if replicas:
                for k in range(len(found)):
                    addrs = [cluster._launch(k, read_only=True,
                                             metrics=False)[0]
                             for _ in range(replicas)]
                    cluster.replica_addresses[k] = addrs
                record_replicas(dir_path, cluster.replica_addresses)
        except BaseException:
            cluster.close()
            raise
        return cluster

    # ------------------------------------------------------------------ attach
    @property
    def url(self) -> str:
        hosts = ",".join(f"{h}:{p}" for h, p in self.addresses)
        return f"tcp://{hosts}"

    def connect_kw(self) -> dict:
        """Keyword args for ``repro.client.connect`` against this cluster
        (manifest path enables replica autodiscovery + save/compact)."""
        return {"dir_path": self.dir}

    # ---------------------------------------------------------------- teardown
    def close(self) -> None:
        for p in self.procs:
            p.terminate()
        for p in self.procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5)
        self.procs.clear()

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_demo_corpus(dir_path: str, n_shards: int = 2,
                      target_mib: int = 8, dataset: str = "urls",
                      seed: int = 0) -> int:
    """Train + shard a synthetic corpus under ``dir_path`` (idempotent:
    an existing manifest short-circuits). Returns ``n_strings``."""
    from repro.data.synth import load_dataset
    from repro.distributed.shard_store import MANIFEST, save_sharded
    from repro.store import CompressedStringStore

    manifest = os.path.join(dir_path, MANIFEST)
    if os.path.exists(manifest):
        import json
        with open(manifest, encoding="utf-8") as fh:
            bounds = json.load(fh)["bounds"]
        return bounds[-1][1]
    strings = load_dataset(dataset, target_mib << 20, seed=seed)
    store = CompressedStringStore.build(strings, seed=seed)
    os.makedirs(dir_path, exist_ok=True)
    save_sharded(store, dir_path, n_shards)
    return len(strings)
