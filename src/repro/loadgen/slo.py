"""SLO gating: server-side truth, merged across shards, diffed to the run.

The driver's own latencies include client scheduling noise; the gate
instead reads each shard server's ``repro_service_request_latency_us``
histogram — snapshotted before and after the run, bucket-diffed
(:func:`diff_hist_states`) so only *this run's* traffic is judged, then
pooled across shards (:func:`merge_hist_states`) into one exact
distribution. Collection rides whichever surface the deployment offers:
the ``stats`` RPC metrics extension for live shard connections, or the
``--metrics-port`` Prometheus scrape for anything that can reach HTTP.

On violation the report carries a ``trace_dump`` excerpt from the worst
shard — the gate doesn't just say "p99 blew the budget", it shows the
slowest requests' span trees from the server that served them.
"""

from __future__ import annotations

import json
import os

from repro.loadgen.driver import RunResult
from repro.loadgen.spec import WorkloadSpec
from repro.obs import (
    REGISTRY,
    diff_hist_states,
    fetch_metrics,
    fetch_traces,
    hist_state_from_rows,
    merge_hist_states,
    parse_prometheus,
    summarize_hist_state,
)

SERVER_HIST = "repro_service_request_latency_us"


# --------------------------------------------------------------- collection
def shard_clients(client) -> list | None:
    """The per-shard RPC clients under a connected ``StoreClient``, or
    ``None`` for in-process backends (no server to ask)."""
    backend = getattr(client, "backend", client)
    clients = getattr(backend, "clients", None)
    if clients and all(hasattr(c, "stats") for c in clients):
        return list(clients)
    return None


def _rows_from_stats(stats: dict) -> list[dict]:
    m = stats.get("metrics") or {}
    return m.get("metrics", m if isinstance(m, list) else [])


def collect_rpc_states(clients, name: str = SERVER_HIST) -> list[dict | None]:
    """Per-shard histogram states via the ``stats`` RPC metrics extension."""
    out = []
    for c in clients:
        try:
            rows = _rows_from_stats(c.stats(metrics=True))
            out.append(hist_state_from_rows(rows, name))
        except (OSError, ConnectionError):
            out.append(None)
    return out


def collect_scrape_states(metrics_addrs, name: str = SERVER_HIST,
                          timeout: float = 5.0) -> list[dict | None]:
    """Per-shard states via ``--metrics-port`` Prometheus scrape
    (``metrics_addrs``: ``[(host, port), ...]``)."""
    out = []
    for host, port in metrics_addrs:
        try:
            rows = parse_prometheus(fetch_metrics(host, port, timeout=timeout))
            out.append(hist_state_from_rows(rows, name))
        except (OSError, ConnectionError):
            out.append(None)
    return out


def collect_local_state(name: str = SERVER_HIST) -> list[dict | None]:
    """In-process fallback: the same series from this process's registry
    (shard:// and file:// backends run their service locally)."""
    rows = REGISTRY.snapshot()["metrics"]
    return [hist_state_from_rows(rows, name)]


def snapshot_server_states(client, metrics_addrs=None) -> list[dict | None]:
    """One before/after snapshot: RPC extension when the backend is remote,
    HTTP scrape when only metrics ports are known, local registry otherwise."""
    clients = shard_clients(client)
    if clients is not None:
        return collect_rpc_states(clients)
    if metrics_addrs:
        return collect_scrape_states(metrics_addrs)
    return collect_local_state()


# ------------------------------------------------------------------- gating
def fraction_under(state: dict | None, threshold_us: float) -> float:
    """Fraction of recorded samples at or under ``threshold_us`` (linear
    interpolation inside the straddling bucket, like the percentile read)."""
    if not state:
        return 0.0
    bounds, counts = state["bounds"], state["counts"]
    total = sum(counts)
    if total == 0:
        return 0.0
    under, lo = 0.0, 0.0
    for i, c in enumerate(counts):
        hi = bounds[i] if i < len(bounds) else float("inf")
        if hi <= threshold_us:
            under += c
        elif lo < threshold_us < hi:
            under += c * (threshold_us - lo) / (hi - lo)
            break
        else:
            break
        lo = hi
    return under / total


def _trace_excerpt(clients, worst_shard: int, metrics_addrs=None,
                   n: int = 5) -> list[dict]:
    try:
        if clients is not None:
            return clients[worst_shard].trace_dump(n)
        if metrics_addrs:
            host, port = metrics_addrs[worst_shard]
            return fetch_traces(host, port, n)
    except (OSError, ConnectionError, IndexError):
        pass
    return []


def build_report(spec: WorkloadSpec, result: RunResult,
                 before: list[dict | None], after: list[dict | None],
                 client=None, metrics_addrs=None) -> dict:
    """The run verdict: merged server percentiles, goodput under the SLO,
    per-shard breakdown, violations (each with a trace excerpt from the
    worst shard), and the client-side view for cross-checking."""
    slo = spec.slo
    deltas = [diff_hist_states(a, b)
              for a, b in zip(after, before)] if after else []
    merged = merge_hist_states(deltas)
    server = summarize_hist_state(merged)

    per_shard = []
    worst_shard, worst_p99 = 0, -1.0
    for k, d in enumerate(deltas):
        s = summarize_hist_state(d)
        per_shard.append({"shard": k, **{key: round(v, 1) if isinstance(v, float)
                                         else v for key, v in s.items()}})
        if s["p99_us"] > worst_p99:
            worst_shard, worst_p99 = k, s["p99_us"]

    goodput_frac = (fraction_under(merged, slo.p99_ms * 1e3)
                    if slo.p99_ms is not None else 1.0)
    goodput_rps = goodput_frac * result.achieved_rate

    violations = []
    for attr, pct in (("p50_ms", "p50_us"), ("p99_ms", "p99_us"),
                      ("p999_ms", "p999_us")):
        limit_ms = getattr(slo, attr)
        if limit_ms is not None and server[pct] > limit_ms * 1e3:
            violations.append({
                "slo": attr, "limit_ms": limit_ms,
                "observed_ms": round(server[pct] / 1e3, 3),
                "worst_shard": worst_shard})
    if goodput_frac < slo.min_goodput:
        violations.append({"slo": "min_goodput", "limit": slo.min_goodput,
                           "observed": round(goodput_frac, 4),
                           "worst_shard": worst_shard})
    if result.error_rate > slo.max_error_rate:
        violations.append({"slo": "max_error_rate",
                           "limit": slo.max_error_rate,
                           "observed": round(result.error_rate, 6),
                           "worst_shard": worst_shard})

    if violations:
        excerpt = _trace_excerpt(shard_clients(client) if client else None,
                                 worst_shard, metrics_addrs)
        for v in violations:
            v["trace_excerpt"] = excerpt

    return {
        "spec": spec.to_dict(),
        "run": result.summary(),
        "server_latency": {k: round(v, 1) if isinstance(v, float) else v
                           for k, v in server.items()},
        "per_shard": per_shard,
        "goodput": {"fraction_under_slo": round(goodput_frac, 4),
                    "rps_under_slo": round(goodput_rps, 1)},
        "slo": slo.to_dict(),
        "violations": violations,
        "passed": not violations,
    }


def write_report(path: str, report: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=False)
        fh.write("\n")
    os.replace(tmp, path)
