"""Declarative workload specs and the deterministic op schedule they expand to.

A :class:`WorkloadSpec` is the whole experiment on one page: the op mix,
the key-popularity distribution, the loop discipline (closed = fixed
concurrency, open = target arrival rate), and the :class:`SLO` the run is
gated on. Same spec + same seed ⇒ byte-identical schedule — reruns are
comparable and regressions are attributable to the code, not the dice.

The schedule is materialised up front (:func:`build_schedule`) rather than
sampled on the fly so the driver's issue loop does no RNG work on the hot
path and the determinism contract is a pure-function property that a test
can assert directly.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import NamedTuple

import numpy as np

OP_KINDS = ("get", "multiget", "scan", "locate", "scan_prefix", "append",
            "extend")
LOOPS = ("closed", "open")
DISTRIBUTIONS = ("zipf", "uniform", "sequential")

#: multiplicative scatter (Knuth's 2^32/phi) so zipf-hot ranks don't all
#: land on shard 0 — popularity stays skewed, placement becomes uniform
_SCATTER = 2654435761


class Op(NamedTuple):
    """One scheduled operation.

    ``at_s`` is the intended arrival time (open loop paces to it; closed
    loop ignores it). ``ids`` carries the target ids for reads / the scan
    ``[lo, hi)`` pair; ``n_payload`` the string count for writes.
    """

    at_s: float
    kind: str
    ids: tuple
    n_payload: int


@dataclass
class SLO:
    """The gate: merged *server-side* latency targets + delivery floors."""

    p50_ms: float | None = None
    p99_ms: float | None = 50.0
    p999_ms: float | None = None
    #: minimum fraction of requests under ``p99_ms`` (goodput floor)
    min_goodput: float = 0.0
    #: maximum fraction of errored ops
    max_error_rate: float = 0.0

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SLO":
        return cls(**{k: d[k] for k in d if k in cls.__dataclass_fields__})


@dataclass
class WorkloadSpec:
    """Everything a run needs besides the target URL and the wall clock."""

    #: op kind -> relative weight; zero/missing kinds never issue
    mix: dict = field(default_factory=lambda: {"get": 0.7, "multiget": 0.3})
    #: key popularity over ``[0, n_strings)``
    distribution: str = "zipf"
    zipf_s: float = 1.1           # zipf exponent (>1); ignored otherwise
    multiget_fanout: int = 16
    scan_span: int = 256
    #: reverse-lookup ops: prefix length for scan_prefix queries, per-query
    #: hit cap, and the fraction of locate ops aimed at absent strings
    prefix_len: int = 4
    prefix_limit: int = 64
    locate_miss_fraction: float = 0.1
    #: tiering knobs: redirect this fraction of read keys into the coldest
    #: ``cold_band`` tail of the id space, so a tiered store sees a long
    #: tail of demoted-segment hits instead of a pure zipf head
    cold_fraction: float = 0.0
    cold_band: float = 0.5
    append_bytes: int = 64        # synthetic payload size per written string
    extend_batch: int = 32
    read_preference: str | None = None
    #: hedge point reads after this many ms; ``None`` disables hedging
    hedge_ms: float | None = None
    loop: str = "closed"
    concurrency: int = 64         # closed loop: in-flight op cap
    rate: float = 1000.0          # open loop: target arrivals per second
    seed: int = 0
    slo: SLO = field(default_factory=SLO)

    def __post_init__(self) -> None:
        if self.loop not in LOOPS:
            raise ValueError(f"loop must be one of {LOOPS}: {self.loop!r}")
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"distribution must be one of {DISTRIBUTIONS}: "
                f"{self.distribution!r}")
        bad = [k for k in self.mix if k not in OP_KINDS]
        if bad:
            raise ValueError(f"unknown op kinds in mix: {bad}")
        if not any(w > 0 for w in self.mix.values()):
            raise ValueError("mix needs at least one positive weight")
        if not 0.0 <= self.cold_fraction <= 1.0:
            raise ValueError(
                f"cold_fraction must be in [0, 1]: {self.cold_fraction!r}")
        if not 0.0 < self.cold_band <= 1.0:
            raise ValueError(
                f"cold_band must be in (0, 1]: {self.cold_band!r}")
        if isinstance(self.slo, dict):
            self.slo = SLO.from_dict(self.slo)

    # ------------------------------------------------------------- round-trip
    def to_dict(self) -> dict:
        d = asdict(self)
        d["slo"] = self.slo.to_dict()
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        known = {k: v for k, v in d.items() if k in cls.__dataclass_fields__}
        return cls(**known)

    @classmethod
    def from_json(cls, s: str) -> "WorkloadSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_file(cls, path: str) -> "WorkloadSpec":
        with open(path, encoding="utf-8") as fh:
            return cls.from_json(fh.read())


def _popularity_ids(spec: WorkloadSpec, rng: np.random.Generator,
                    n_strings: int, count: int) -> np.ndarray:
    """``count`` key ids drawn from the spec's popularity distribution."""
    if count == 0:
        return np.empty(0, dtype=np.int64)
    if spec.distribution == "uniform":
        ids = rng.integers(0, n_strings, size=count, dtype=np.int64)
    elif spec.distribution == "sequential":
        ids = np.arange(count, dtype=np.int64) % n_strings
    else:
        # zipf over ranks 1..n via the truncated CDF (exact, no rejection),
        # then rank -> id scatter so hot keys spread across shards
        ranks = min(n_strings, 1 << 20)
        pmf = 1.0 / np.power(np.arange(1, ranks + 1, dtype=np.float64),
                             spec.zipf_s)
        cdf = np.cumsum(pmf)
        cdf /= cdf[-1]
        drawn = np.searchsorted(cdf, rng.random(count), side="left")
        ids = (drawn.astype(np.int64) * _SCATTER) % n_strings
    # cold-skew redirect, drawn only when the knob is on so older specs
    # keep byte-identical schedules (same guard discipline as locate miss)
    if spec.cold_fraction > 0.0:
        band0 = int(n_strings * (1.0 - spec.cold_band))
        pick = rng.random(count) < float(spec.cold_fraction)
        k = int(pick.sum())
        if k:
            ids = ids.copy()
            ids[pick] = rng.integers(band0, n_strings, size=k,
                                     dtype=np.int64)
    return ids


def build_schedule(spec: WorkloadSpec, n_strings: int,
                   n_ops: int) -> list[Op]:
    """Expand a spec into ``n_ops`` concrete operations.

    Pure in ``(spec, n_strings, n_ops)``: one seeded generator drives kind
    choice, key choice, and (open loop) arrival jitter, so two calls with
    equal inputs return equal schedules — the reproducibility contract the
    determinism test pins down.
    """
    if n_strings <= 0:
        raise ValueError("n_strings must be positive")
    rng = np.random.default_rng(spec.seed)
    kinds = [k for k in OP_KINDS if spec.mix.get(k, 0) > 0]
    weights = np.array([spec.mix[k] for k in kinds], dtype=np.float64)
    weights /= weights.sum()
    chosen = rng.choice(len(kinds), size=n_ops, p=weights)

    # arrival times: open loop gets a deterministic exponential (Poisson)
    # schedule at the target rate; closed loop issues as fast as the
    # concurrency window drains, so arrivals are all-zero
    if spec.loop == "open":
        gaps = rng.exponential(1.0 / max(spec.rate, 1e-9), size=n_ops)
        arrivals = np.cumsum(gaps)
    else:
        arrivals = np.zeros(n_ops)

    # locate miss flags, drawn only when the mix asks for locate so specs
    # predating reverse lookup keep byte-identical schedules
    miss = np.empty(0, dtype=bool)
    if "locate" in kinds:
        n_locate = int(np.sum(chosen == kinds.index("locate")))
        miss = rng.random(n_locate) < float(spec.locate_miss_fraction)

    # reads vastly outnumber writes; draw one popularity pool and slice it
    fanout = max(1, int(spec.multiget_fanout))
    need = int(np.sum(chosen == kinds.index("get")) if "get" in kinds else 0)
    if "multiget" in kinds:
        need += fanout * int(np.sum(chosen == kinds.index("multiget")))
    for k in ("scan", "locate", "scan_prefix"):
        if k in kinds:
            need += int(np.sum(chosen == kinds.index(k)))
    pool = _popularity_ids(spec, rng, n_strings, need)

    schedule: list[Op] = []
    cursor = 0
    mcursor = 0
    span = max(1, int(spec.scan_span))
    for i, ki in enumerate(chosen):
        kind = kinds[ki]
        at = float(arrivals[i])
        if kind == "get":
            schedule.append(Op(at, kind, (int(pool[cursor]),), 0))
            cursor += 1
        elif kind == "multiget":
            ids = tuple(int(x) for x in pool[cursor:cursor + fanout])
            cursor += fanout
            schedule.append(Op(at, kind, ids, 0))
        elif kind == "scan":
            lo = int(pool[cursor]) % max(1, n_strings - span)
            cursor += 1
            schedule.append(Op(at, kind, (lo, lo + span), 0))
        elif kind == "locate":
            # ids = the stored string the driver queries with; n_payload=1
            # flags a deliberate miss (driver perturbs the query string)
            schedule.append(Op(at, kind, (int(pool[cursor]),),
                               1 if miss[mcursor] else 0))
            cursor += 1
            mcursor += 1
        elif kind == "scan_prefix":
            schedule.append(Op(at, kind, (int(pool[cursor]),), 0))
            cursor += 1
        elif kind == "append":
            schedule.append(Op(at, kind, (), 1))
        else:  # extend
            schedule.append(Op(at, kind, (), max(1, int(spec.extend_batch))))
    return schedule


def payload_strings(spec: WorkloadSpec, rng: np.random.Generator,
                    count: int) -> list[bytes]:
    """Synthetic write payloads (driver-side; not part of the schedule so
    the schedule stays cheap to build and compare)."""
    raw = rng.integers(97, 123, size=count * spec.append_bytes,
                       dtype=np.uint8)
    body = raw.tobytes()
    k = spec.append_bytes
    return [body[i * k:(i + 1) * k] for i in range(count)]
