"""Closed- and open-loop drivers over a :class:`repro.client.StoreClient`.

Closed loop holds a fixed number of requests in flight (throughput probe:
how fast can the stack drain a saturating client). Open loop fires ops at
their scheduled arrival times regardless of completions (latency probe:
what does a *paced* workload see, queueing included) — latencies are
measured from the *intended* arrival, not the issue instant, so a driver
that falls behind cannot hide server queueing (no coordinated omission).

Both loops ride the client's async surface (``get_async`` coalesces point
reads into batched multiget RPCs; hedged variants engage when the spec
sets ``hedge_ms``), so one Python thread sustains thousands of in-flight
ops without a thread per request.
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from repro.loadgen.spec import Op, WorkloadSpec, build_schedule, payload_strings
from repro.obs import Histogram, summarize_hist_state


@dataclass
class RunResult:
    """What one driver run observed, client side."""

    loop: str
    duration_s: float
    ops_issued: int = 0
    ops_ok: int = 0
    ops_failed: int = 0
    per_kind: dict = field(default_factory=dict)
    #: open loop only: ops issued behind their scheduled arrival
    late: int = 0
    bytes_read: int = 0
    #: client-observed latency histogram state (open loop: from intended
    #: arrival; closed loop: from issue) — mergeable/summarizable
    latency_state: dict | None = None
    first_errors: list = field(default_factory=list)

    @property
    def achieved_rate(self) -> float:
        return self.ops_ok / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def error_rate(self) -> float:
        n = self.ops_issued
        return self.ops_failed / n if n else 0.0

    def summary(self) -> dict:
        return {
            "loop": self.loop,
            "duration_s": round(self.duration_s, 3),
            "ops_issued": self.ops_issued,
            "ops_ok": self.ops_ok,
            "ops_failed": self.ops_failed,
            "error_rate": round(self.error_rate, 6),
            "late": self.late,
            "achieved_rate": round(self.achieved_rate, 1),
            "bytes_read": self.bytes_read,
            "per_kind": dict(self.per_kind),
            "client_latency": summarize_hist_state(self.latency_state),
            "first_errors": list(self.first_errors),
        }


class _Run:
    """Shared completion bookkeeping for both loops (thread-safe: client
    completion callbacks fire on pool/IO threads)."""

    def __init__(self, spec: WorkloadSpec, client,
                 queries: dict[int, bytes] | None = None):
        self.spec = spec
        self.client = client
        #: id -> stored string, prefetched before timing starts so locate /
        #: scan_prefix ops don't pay a read on the measured path
        self.queries = queries or {}
        self.hist = Histogram("loadgen_observed_latency_us")
        self.lock = threading.Lock()
        self.per_kind: dict[str, int] = {}
        self.ok = 0
        self.failed = 0
        self.bytes_read = 0
        self.first_errors: list[str] = []
        self.outstanding = 0
        self.drained = threading.Condition(self.lock)
        self._payload_rng = np.random.default_rng(spec.seed + 1)
        # scans/prefix scans are sync on the client; a small side pool keeps
        # them from stalling the issue loop without thread-per-op
        self._scan_pool = (
            ThreadPoolExecutor(max_workers=4, thread_name_prefix="lg-scan")
            if (spec.mix.get("scan", 0) > 0
                or spec.mix.get("scan_prefix", 0) > 0) else None)

    # ------------------------------------------------------------------ issue
    def issue(self, op: Op, t_ref: float, on_done=None) -> None:
        """Fire one op; record completion against ``t_ref`` (intended
        arrival for open loop, issue time for closed)."""
        spec, client = self.spec, self.client
        with self.lock:
            self.outstanding += 1
            self.per_kind[op.kind] = self.per_kind.get(op.kind, 0) + 1
        try:
            if op.kind == "get":
                if spec.hedge_ms is not None:
                    fut = client.get_hedged_async(
                        op.ids[0], hedge_ms=spec.hedge_ms,
                        read_preference=spec.read_preference)
                else:
                    fut = client.get_async(
                        op.ids[0], read_preference=spec.read_preference)
            elif op.kind == "multiget":
                if spec.hedge_ms is not None:
                    fut = client.multiget_hedged_async(
                        list(op.ids), hedge_ms=spec.hedge_ms,
                        read_preference=spec.read_preference)
                else:
                    fut = client.multiget_async(
                        list(op.ids), read_preference=spec.read_preference)
            elif op.kind == "scan":
                lo, hi = op.ids
                fut = self._scan_pool.submit(client.scan, lo, hi)
            elif op.kind == "locate":
                s = self.queries[op.ids[0]]
                if op.n_payload:  # scheduled miss: perturb past any match
                    s = s + b"\x00@@miss@@"
                fut = client.locate_async(
                    s, read_preference=spec.read_preference)
            elif op.kind == "scan_prefix":
                prefix = self.queries[op.ids[0]][:spec.prefix_len]
                fut = self._scan_pool.submit(
                    client.scan_prefix, prefix, spec.prefix_limit)
            elif op.kind == "append":
                fut = client.append_async(
                    payload_strings(spec, self._payload_rng, 1)[0])
            else:  # extend
                fut = client.extend_async(
                    payload_strings(spec, self._payload_rng, op.n_payload))
        except Exception as exc:  # submission itself failed
            self._complete(op, t_ref, None, exc, on_done)
            return
        fut.add_done_callback(
            lambda f: self._complete(op, t_ref, f, f.exception(), on_done))

    def _complete(self, op: Op, t_ref: float, fut, exc, on_done) -> None:
        dt_us = (time.perf_counter() - t_ref) * 1e6
        nbytes = 0
        if exc is None and fut is not None and op.kind in (
                "get", "multiget", "scan"):
            res = fut.result()
            nbytes = (len(res) if isinstance(res, (bytes, bytearray))
                      else sum(len(v) for v in res))
        elif exc is None and fut is not None and op.kind == "scan_prefix":
            nbytes = sum(len(s) for _gid, s in fut.result())
        with self.lock:
            self.outstanding -= 1
            if exc is None:
                self.ok += 1
                self.hist.record(dt_us)
                self.bytes_read += nbytes
            else:
                self.failed += 1
                if len(self.first_errors) < 8:
                    self.first_errors.append(f"{op.kind}: {exc!r}")
            self.drained.notify_all()
        if on_done is not None:
            on_done()

    # ------------------------------------------------------------------ drain
    def wait_drained(self, timeout_s: float = 30.0) -> None:
        deadline = time.perf_counter() + timeout_s
        with self.lock:
            while self.outstanding > 0:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                self.drained.wait(left)
        if self._scan_pool is not None:
            self._scan_pool.shutdown(wait=False)

    def result(self, loop: str, duration_s: float, late: int) -> RunResult:
        return RunResult(
            loop=loop, duration_s=duration_s,
            ops_issued=sum(self.per_kind.values()),
            ops_ok=self.ok, ops_failed=self.failed,
            per_kind=dict(self.per_kind), late=late,
            bytes_read=self.bytes_read,
            latency_state=self.hist.state(),
            first_errors=list(self.first_errors))


def _run_closed(run: _Run, schedule: list[Op], duration_s: float) -> RunResult:
    spec = run.spec
    window = threading.Semaphore(max(1, int(spec.concurrency)))
    start = time.perf_counter()
    deadline = start + duration_s
    for op in itertools.cycle(schedule):
        window.acquire()
        now = time.perf_counter()
        if now >= deadline:
            window.release()
            break
        run.issue(op, now, on_done=window.release)
    run.wait_drained()
    return run.result("closed", time.perf_counter() - start, late=0)


def _run_open(run: _Run, schedule: list[Op], duration_s: float) -> RunResult:
    start = time.perf_counter()
    deadline = start + duration_s
    late = 0
    for op in schedule:
        target = start + op.at_s
        if target >= deadline:
            break
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        elif now - target > 0.001:
            late += 1  # issue loop fell >1ms behind the schedule
        # t_ref = intended arrival: queueing delay counts against the SLO
        run.issue(op, target)
        if time.perf_counter() >= deadline:
            break
    run.wait_drained()
    return run.result("open", time.perf_counter() - start, late=late)


def run_workload(client, spec: WorkloadSpec, duration_s: float,
                 schedule: list[Op] | None = None) -> RunResult:
    """Drive ``client`` with ``spec`` for ``duration_s`` seconds.

    Writes in the mix require a writable backend — a read-only target
    surfaces as per-op errors in the result, not a crash, so mixed specs
    degrade visibly instead of aborting the read measurement.
    """
    if schedule is None:
        n = estimate_n_ops(spec, duration_s)
        schedule = build_schedule(spec, max(1, client.n_strings), n)
    if not schedule:
        raise ValueError("empty schedule")
    # prefetch locate / scan_prefix query strings outside the measured
    # window — the measured op is the reverse lookup, not the read
    qids = sorted({op.ids[0] for op in schedule
                   if op.kind in ("locate", "scan_prefix")})
    queries = dict(zip(qids, client.multiget(qids))) if qids else None
    run = _Run(spec, client, queries)
    if spec.loop == "open":
        return _run_open(run, schedule, duration_s)
    return _run_closed(run, schedule, duration_s)


def estimate_n_ops(spec: WorkloadSpec, duration_s: float) -> int:
    """Schedule length to materialise up front. Open loop: the arrival
    process fixes it (rate × duration + slack). Closed loop: a generous
    guess — the driver cycles the schedule, so too-small only repeats ops,
    never starves the window."""
    if spec.loop == "open":
        return max(16, int(spec.rate * duration_s * 1.25) + 64)
    return max(1024, int(spec.concurrency) * 256)
