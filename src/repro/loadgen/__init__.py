"""repro.loadgen — SLO-gated traffic harness over the serving stack.

MLPerf-style load generation for the compressed string store: a
declarative :class:`WorkloadSpec` (op mix, key popularity, loop
discipline, seeded — same spec + seed ⇒ identical schedule), closed- and
open-loop drivers over :class:`repro.client.StoreClient`'s async surface,
and an SLO gate judged on *server-side* latency histograms (snapshot →
diff → merge across shards), with ``trace_dump`` excerpts from the worst
shard attached to every violation.

``python -m repro.loadgen --spec spec.json --url tcp://... --duration 10``
drives a live cluster; ``--spawn <dir>`` launches (and tears down) a
local multi-process one, ``--demo`` builds a synthetic corpus first.
"""

from repro.loadgen.cluster import LocalCluster, build_demo_corpus
from repro.loadgen.driver import RunResult, estimate_n_ops, run_workload
from repro.loadgen.slo import (
    SERVER_HIST,
    build_report,
    collect_rpc_states,
    collect_scrape_states,
    fraction_under,
    snapshot_server_states,
    write_report,
)
from repro.loadgen.spec import (
    SLO,
    Op,
    WorkloadSpec,
    build_schedule,
    payload_strings,
)

__all__ = [
    "SERVER_HIST",
    "SLO",
    "LocalCluster",
    "Op",
    "RunResult",
    "WorkloadSpec",
    "build_demo_corpus",
    "build_report",
    "build_schedule",
    "collect_rpc_states",
    "collect_scrape_states",
    "estimate_n_ops",
    "fraction_under",
    "payload_strings",
    "run_workload",
    "snapshot_server_states",
    "write_report",
]
