"""Fault-tolerant training loop.

Production posture at 1000+ nodes:
* **restart-from-latest**: the loop begins by probing the checkpoint
  directory; any committed step resumes bit-exactly (data order is a pure
  function of step — repro.data.pipeline).
* **preemption handling**: SIGTERM/SIGINT set a flag; the loop finishes the
  in-flight step, writes a synchronous checkpoint, and exits cleanly.
* **straggler watchdog**: per-step wall times feed a rolling window; a step
  slower than `straggler_factor` x the window median is counted and surfaced
  (on real fleets this triggers hot-spare swaps; here it logs + metrics).
* **async checkpointing** every `ckpt_every` steps off the critical path.
"""

from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.checkpoint import ckpt as ckpt_lib


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 32
    log_every: int = 10


@dataclass
class LoopStats:
    steps_run: int = 0
    resumed_from: int | None = None
    straggler_steps: int = 0
    preempted: bool = False
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


class TrainLoop:
    def __init__(self, train_step, state, batch_fn, cfg: LoopConfig,
                 abstract_state=None, shardings=None, install_signals=True):
        self.train_step = train_step
        self.state = state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.abstract_state = abstract_state
        self.shardings = shardings
        self.stats = LoopStats()
        self._stop = False
        self.ckpt = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir)
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    signal.signal(sig, self._on_preempt)
                except ValueError:
                    pass  # not on main thread (tests)

    def _on_preempt(self, signum, frame):
        self._stop = True
        self.stats.preempted = True

    def maybe_resume(self) -> int:
        step = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if step is not None and self.abstract_state is not None:
            self.state, step = ckpt_lib.restore(
                self.cfg.ckpt_dir, self.abstract_state, step, self.shardings)
            self.stats.resumed_from = step
            return step
        return int(np.asarray(self.state["step"]))

    def run(self, log=print) -> LoopStats:
        step = self.maybe_resume()
        window: deque[float] = deque(maxlen=self.cfg.straggler_window)
        while step < self.cfg.total_steps and not self._stop:
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            loss = float(np.asarray(metrics["loss"]))  # sync point
            dt = time.perf_counter() - t0
            if window and dt > self.cfg.straggler_factor * float(np.median(window)):
                self.stats.straggler_steps += 1
                log(f"[watchdog] step {step}: {dt:.3f}s vs median "
                    f"{float(np.median(window)):.3f}s — straggler suspected")
            window.append(dt)
            self.stats.losses.append(loss)
            self.stats.step_times.append(dt)
            self.stats.steps_run += 1
            step += 1
            if step % self.cfg.log_every == 0:
                log(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f} ms)")
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(self.state, step)
                ckpt_lib.gc(self.cfg.ckpt_dir, self.cfg.keep_ckpts)
        if self._stop:
            # preemption: synchronous final save so no work is lost
            self.ckpt.wait()
            ckpt_lib.save(jax_to_np(self.state), step, self.cfg.ckpt_dir)
            log(f"[preempt] saved step {step} and exiting")
        self.ckpt.wait()
        return self.stats


def jax_to_np(tree):
    import jax
    return jax.tree.map(lambda x: np.asarray(x), tree)
