"""repro subpackage."""
