"""Production mesh construction (spec: single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so smoke tests and benches see the real (1-device) CPU.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
