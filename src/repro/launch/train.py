"""Training launcher: end-to-end driver wiring every substrate together.

  PYTHONPATH=src python -m repro.launch.train \
      --arch mamba2-780m --smoke --steps 50 --batch 8 --seq 256 \
      --data book_titles --ckpt-dir /tmp/repro_run

On this CPU container use --smoke (reduced same-family config). On real
hardware drop --smoke and pass --mesh data,model (e.g. 16,16); the same
script is the per-host entry under multi-controller JAX.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.corpus import CompressedCorpusStore
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.data.synth import load_dataset
from repro.distributed.sharding import use_mesh
from repro.models.model import build_params
from repro.optim.adamw import AdamWConfig, init_state
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--data", default="book_titles")
    ap.add_argument("--data-mib", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="",
                    help="data,model axis sizes (default: single device)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"arch={cfg.name} params={cfg.n_params() / 1e6:.1f}M "
          f"(smoke={args.smoke})")

    # ---- data plane: OnPair-compressed corpus + OnPair tokenizer ----------
    strings = load_dataset(args.data, args.data_mib << 20)
    store = CompressedCorpusStore.build(strings, sample_bytes=2 << 20)
    print(f"corpus: {store.n_docs} docs, ratio {store.compression_ratio:.2f}x,"
          f" resident {store.memory_bytes / (1 << 20):.1f} MiB compressed")
    # the OnPair dictionary is the vocab: override model vocab when smoke
    pipe = TokenPipeline(store, BatchSpec(global_batch=args.batch,
                                          seq_len=args.seq, seed=0))

    if args.smoke:
        from dataclasses import replace
        cfg = replace(cfg, vocab_size=store.tokenizer.vocab_size)

    # ---- model/optimizer ---------------------------------------------------
    params = build_params(cfg, seed=0)
    opt = AdamWConfig(lr=args.lr)
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches,
                              schedule_total=args.steps)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        ctx = use_mesh(mesh)
    else:
        import contextlib
        ctx = contextlib.nullcontext()

    def batch_fn(step: int):
        b = pipe.batch(step)
        return {"tokens": jnp.asarray(b["tokens"]),
                "targets": jnp.asarray(b["targets"])}

    with ctx:
        jitted = jax.jit(step_fn)
        loop = TrainLoop(jitted, state, batch_fn,
                         LoopConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir, log_every=10),
                         abstract_state=jax.eval_shape(lambda: state))
        stats = loop.run()
    print(f"done: {stats.steps_run} steps, resumed_from={stats.resumed_from}, "
          f"loss {stats.losses[0]:.3f} -> {stats.losses[-1]:.3f}, "
          f"stragglers={stats.straggler_steps}")


if __name__ == "__main__":
    main()
