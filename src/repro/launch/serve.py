"""Serving launcher: batched prefill + decode with on-device OnPair
detokenisation (the paper's decompression path in the serving loop).

Prompts can come from the CLI or straight out of the compressed corpus
store (``--doc-ids``): the corpus lives in memory compressed, and prompt
materialisation is a batched store multiget through the Pallas decoder.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --prompts "the quick" "compression" --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --doc-ids 3 17 4242 --max-new 8

``--shard-server`` flips the launcher into its other role: a per-shard RPC
server process for the multi-process serving tier (``repro.net``) — no LM,
and no jax needed on the host (heavy imports only happen on the LM path):

  PYTHONPATH=src python -m repro.launch.serve \
      --shard-server /data/corpus/shard-0002 --port 9102
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--shard-server", default=None, metavar="SHARD_DIR",
                    help="serve this shard directory (<dir>/shard-000k) over "
                         "TCP via repro.net.shard_server and exit when "
                         "interrupted; skips the LM entirely")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--shard-server bind host")
    ap.add_argument("--port", type=int, default=0,
                    help="--shard-server bind port (0 = kernel-assigned)")
    ap.add_argument("--read-only", action="store_true",
                    help="--shard-server: serve as a read-only replica")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="also serve Prometheus /metrics + the /traces "
                         "slow-request dump on this port (0 = "
                         "kernel-assigned); applies to both roles")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+",
                    default=["the quick brown", "in memory database"])
    ap.add_argument("--doc-ids", type=int, nargs="*", default=None,
                    help="additionally serve prompts fetched by id from the "
                         "OnPair-compressed corpus store (repro.store)")
    ap.add_argument("--store-dir", default=None,
                    help="open a persisted CompressedStringStore (built with "
                         "store.save(dir)) instead of compressing in-process; "
                         "the store's saved dictionary artifact becomes the "
                         "tokenizer vocabulary")
    ap.add_argument("--writable", action="store_true",
                    help="open --store-dir as a MutableStringStore (accepts "
                         "appends against the frozen dictionary; versioned "
                         "directory layout)")
    ap.add_argument("--append", nargs="*", default=None, metavar="DOC",
                    help="append these documents to the writable store "
                         "before serving (their new ids are also served as "
                         "prompts); prints the drift snapshot")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    if args.shard_server:
        # RPC-server role: stdlib + numpy only — never pull in jax/the LM
        from repro.net.shard_server import run
        run(args.shard_server, host=args.host, port=args.port,
            read_only=args.read_only, metrics_port=args.metrics_port)
        return

    if args.metrics_port is not None:
        # LM path: expose the store/client/kernel metrics this process
        # records while it serves (scrape http://host:port/metrics)
        from repro.obs import start_metrics_server
        metrics = start_metrics_server(port=args.metrics_port)
        print(f"metrics: http://127.0.0.1:{metrics.port}/metrics", flush=True)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.core.tokenizer import OnPairTokenizer
    from repro.data.synth import load_dataset
    from repro.models.model import build_params, serve_decode, serve_prefill

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    client = None
    if args.store_dir:
        # persisted-store path through the v3 client layer: the store URL
        # decides the backend (writable vs read-only here; a sharded dir or
        # a remote cluster would be the same call with another scheme), and
        # the saved dictionary artifact IS the vocab — nothing is retrained
        from repro.client import connect
        from repro.core import registry
        scheme = "mut" if args.writable else "file"
        client = connect(f"{scheme}://{args.store_dir}")
        codec = registry.resolve(client.backend.artifact.codec)
        if codec not in ("onpair", "onpair16"):
            raise SystemExit(
                f"--store-dir: store was built with codec {codec!r}; the LM "
                "tokenizer vocabulary is an OnPair dictionary — rebuild the "
                "store with codec='onpair16'")
        tok = OnPairTokenizer.from_artifact(client.backend.artifact)
    else:
        # OnPair tokenizer trained on a small corpus (vocab == dictionary)
        corpus_strings = load_dataset("book_titles", 1 << 20)
        tok = OnPairTokenizer.train(corpus_strings, sample_bytes=1 << 20)
    from dataclasses import replace
    cfg = replace(cfg, vocab_size=tok.vocab_size)
    params = build_params(cfg, seed=0)

    if args.append:
        # ingest path: parse new docs against the store's frozen dictionary
        if client is None or not args.writable:
            raise SystemExit("--append requires --store-dir with --writable")
        new_ids = client.extend([d.encode() for d in args.append])
        client.save()  # ingest is durable, not in-memory only
        drift = client.backend.drift.snapshot()
        print(f"appended {len(new_ids)} docs (ids {new_ids[0]}..{new_ids[-1]}), "
              f"tail {client.stats()['backend']['n_tail_strings']} strings, "
              f"saved to {args.store_dir}, drift {drift['drift']:.3f} "
              f"(compact recommended: {drift['should_compact']})")
        args.doc_ids = list(args.doc_ids or []) + new_ids

    prompt_bytes = [p.encode() for p in args.prompts]
    if args.doc_ids:
        # corpus path: the store answers the prompt fetch as one batched,
        # length-bucketed kernel decode over the compressed payload
        if client is None:
            from repro.client import wrap
            from repro.core.codec import Encoder
            from repro.store import CompressedStringStore
            artifact = tok.to_artifact()
            client = wrap(CompressedStringStore(
                artifact, Encoder(artifact).encode(corpus_strings)))
        docs = client.multiget(args.doc_ids)
        prompt_bytes += docs
        # display names only; latin-1 roundtrips arbitrary doc bytes
        args.prompts = list(args.prompts) + [d.decode("latin-1") for d in docs]
        snap = client.stats()["backend"]
        print(f"store: {snap['n_strings']} docs in {snap['n_segments']} "
              f"segments ({snap['backend']} backend), fetched "
              f"{len(docs)} prompts, jit shapes {snap['jit_shapes']}")

    ids = tok.encode_batch(prompt_bytes, bos=True)
    L = max(len(s) for s in ids)
    tokens = np.zeros((len(ids), L), np.int32)
    for i, s in enumerate(ids):
        tokens[i, : len(s)] = s

    t0 = time.perf_counter()
    logits, cache = serve_prefill(params, {"tokens": jnp.asarray(tokens)},
                                  cfg, max_seq=args.max_seq)
    print(f"prefill: {tokens.shape} in {time.perf_counter() - t0:.2f}s")

    def decode_step(p, c, b):
        return serve_decode(p, c, b, cfg)

    decode = jax.jit(decode_step)
    outs = [list(s) for s in ids]
    tok_ids = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.perf_counter()
    for _ in range(args.max_new):
        for i, t in enumerate(np.asarray(tok_ids)[:, 0]):
            outs[i].append(int(t))
        logits, cache = decode(params, cache, {"token": tok_ids})
        tok_ids = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.perf_counter() - t0
    n_tok = args.max_new * len(args.prompts)
    print(f"decode: {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, untrained weights)")
    for prompt, seq in zip(args.prompts, outs):
        text = tok.decode(np.asarray(seq))
        print(f"  {prompt!r} -> {text[:80]!r}")


if __name__ == "__main__":
    main()
