"""Serving launcher: batched prefill + decode with on-device OnPair
detokenisation (the paper's decompression path in the serving loop).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --prompts "the quick" "compression" --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.tokenizer import OnPairTokenizer
from repro.data.synth import load_dataset
from repro.models.model import build_params, serve_decode, serve_prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+",
                    default=["the quick brown", "in memory database"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    # OnPair tokenizer trained on a small corpus (vocab == dictionary)
    tok = OnPairTokenizer.train(load_dataset("book_titles", 1 << 20),
                                sample_bytes=1 << 20)
    from dataclasses import replace
    cfg = replace(cfg, vocab_size=tok.vocab_size)
    params = build_params(cfg, seed=0)

    ids = tok.encode_batch([p.encode() for p in args.prompts], bos=True)
    L = max(len(s) for s in ids)
    tokens = np.zeros((len(ids), L), np.int32)
    for i, s in enumerate(ids):
        tokens[i, : len(s)] = s

    t0 = time.perf_counter()
    logits, cache = serve_prefill(params, {"tokens": jnp.asarray(tokens)},
                                  cfg, max_seq=args.max_seq)
    print(f"prefill: {tokens.shape} in {time.perf_counter() - t0:.2f}s")

    decode = jax.jit(lambda p, c, b: serve_decode(p, c, b, cfg))
    outs = [list(s) for s in ids]
    tok_ids = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.perf_counter()
    for _ in range(args.max_new):
        for i, t in enumerate(np.asarray(tok_ids)[:, 0]):
            outs[i].append(int(t))
        logits, cache = decode(params, cache, {"token": tok_ids})
        tok_ids = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.perf_counter() - t0
    n_tok = args.max_new * len(args.prompts)
    print(f"decode: {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, untrained weights)")
    for prompt, seq in zip(args.prompts, outs):
        text = tok.decode(np.asarray(seq))
        print(f"  {prompt!r} -> {text[:80]!r}")


if __name__ == "__main__":
    main()
