"""Roofline report generator: reads results/dryrun/*/*.json and emits the
EXPERIMENTS.md §Roofline table (three terms, bottleneck, MODEL_FLOPS ratio,
and the 'what would move it' line per cell).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

MOVE_HINTS = {
    "compute": "more useful-FLOP fraction: cut remat recompute / capacity padding",
    "memory": "fuse scan-carried temporaries; larger microbatch per device; bf16 master",
    "collective": "reshard to cut per-layer all-gathers; overlap via scanned FSDP; "
                  "int8-compress cross-pod grads",
}


def load_records(mesh: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, mesh, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "error" not in rec:
            rec["_file"] = os.path.basename(path)
            out.append(rec)
    return out


def roofline_fraction(rec: dict) -> float:
    """Useful-compute fraction of the bound step time: how close the cell is
    to its compute roofline = model_flops / (chips * peak * bound_time)."""
    bound = max(rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"])
    if bound <= 0:
        return 0.0
    ideal = rec["model_flops"] / rec["chips"] / 197e12
    return ideal / bound


def fmt_row(rec: dict) -> dict:
    return {
        "arch": rec["arch"], "shape": rec["shape"], "tag": rec.get("tag", ""),
        "t_compute_s": round(rec["t_compute_s"], 5),
        "t_memory_s": round(rec["t_memory_s"], 5),
        "t_collective_s": round(rec["t_collective_s"], 5),
        "bottleneck": rec["bottleneck"],
        "useful_ratio": round(rec.get("useful_ratio", 0), 4),
        "roofline_frac": round(roofline_fraction(rec), 5),
        "move": MOVE_HINTS[rec["bottleneck"]],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = [fmt_row(r) for r in load_records(args.mesh)
            if not r.get("tag")]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if args.md:
        print("| arch | shape | compute (s) | memory (s) | collective (s) | "
              "bottleneck | MODEL/HLO | roofline frac |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']} | "
                  f"{r['t_memory_s']} | {r['t_collective_s']} | "
                  f"{r['bottleneck']} | {r['useful_ratio']} | "
                  f"{r['roofline_frac']} |")
    else:
        for r in rows:
            print(",".join(str(r[k]) for k in
                           ("arch", "shape", "t_compute_s", "t_memory_s",
                            "t_collective_s", "bottleneck", "useful_ratio",
                            "roofline_frac")))


if __name__ == "__main__":
    main()
