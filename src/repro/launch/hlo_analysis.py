"""Loop-corrected roofline extraction from compiled HLO text.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip count
(verified empirically in this environment), which silently under-reports
FLOPs/bytes for scan-over-layers models by ~n_layers x. This module parses
the post-SPMD compiled HLO text instead:

  1. split the module into named computations (regions + ENTRY),
  2. find `while` ops, map their body/condition regions, and recover the trip
     count from the loop-bound constant in the condition region,
  3. attribute every op to its region and scale by the product of enclosing
     trip counts (nested scans compose: the SSD chunk scan inside the blocks
     scan gets n_blocks x n_chunks),
  4. dot FLOPs are reconstructed from result shape x contracted dims (operand
     shapes resolved through a per-region symbol table, since HLO text prints
     operands by name only),
  5. memory traffic ~= sum over ops of (output bytes + operand bytes), with
     aliasing-aware special cases: get-tuple-element / bitcast / parameter /
     tuple are free; dynamic-update-slice counts only the update operand
     (in-place); fusion sub-computations are skipped (the fusion call site
     carries the shape).

All numbers are per-device: the compiled module is the per-partition SPMD
program. Collective bytes count each op's result size (= payload shuffled
per device per execution).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^(%[\w.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "get-tuple-element", "bitcast", "tuple", "constant",
             "after-all", "iota", "partition-id", "replica-id"}

_OP_RE = re.compile(r"\s([a-z0-9\-]+)\(")


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Region:
    name: str
    lines: list[str] = field(default_factory=list)
    defs: dict[str, str] = field(default_factory=dict)  # op name -> shape text


def split_regions(text: str) -> dict[str, Region]:
    regions: dict[str, Region] = {}
    cur: Region | None = None
    for line in text.splitlines():
        s = line.strip()
        m = re.match(r"(ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{", s)
        if m:
            cur = Region(name=m.group(2))
            regions[m.group(2)] = cur
            if m.group(1):
                regions["__entry__"] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(s.rstrip(","))
        if dm:
            name, rhs = dm.groups()
            cur.lines.append(s)
            shape = rhs.split(" ", 1)[0]
            cur.defs[name] = shape
    return regions


def _trip_count(cond_region: Region) -> int:
    best = 1
    for line in cond_region.lines:
        m = re.search(r"s32\[\]\s+constant\((\d+)\)", line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def region_multipliers(regions: dict[str, Region]) -> dict[str, float]:
    """Multiplier per region = product of enclosing while trip counts."""
    whiles: list[tuple[str, str, int]] = []
    for rname, region in regions.items():
        if rname == "__entry__":
            continue
        for line in region.lines:
            if " while(" in line:
                mb = re.search(r"body=(%[\w.\-]+)", line)
                mc = re.search(r"condition=(%[\w.\-]+)", line)
                if mb and mc and mc.group(1) in regions:
                    whiles.append((rname, mb.group(1),
                                   _trip_count(regions[mc.group(1)])))
    mult: dict[str, float] = {regions["__entry__"].name: 1.0}
    for _ in range(8):  # fixpoint over nesting (depth is tiny)
        changed = False
        for parent, body, trip in whiles:
            if parent in mult and mult.get(body) != mult[parent] * trip:
                mult[body] = mult[parent] * trip
                changed = True
        if not changed:
            break
    return mult


def _operands(rhs: str) -> list[str]:
    inner = rhs.split("(", 1)
    if len(inner) < 2:
        return []
    args = inner[1].rsplit(")", 1)[0] if ")" in inner[1] else inner[1]
    return re.findall(r"%[\w.\-]+", args.split("), ")[0])


def analyze_hlo(text: str) -> dict:
    """Loop-corrected per-device {flops, bytes, collectives{kind: bytes}}."""
    regions = split_regions(text)
    if "__entry__" not in regions:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0}
    mult = region_multipliers(regions)
    # global fallback symbol table (names are unique enough post-SPMD)
    global_defs: dict[str, str] = {}
    for r in regions.values():
        global_defs.update(r.defs)

    flops = 0.0
    traffic = 0.0
    coll: dict[str, float] = {}

    for rname, region in regions.items():
        if rname == "__entry__":
            continue
        scale = mult.get(rname)
        if scale is None:
            continue  # fusion / reducer sub-computations: counted at call site

        def shape_of(opname: str) -> str:
            return region.defs.get(opname) or global_defs.get(opname, "")

        for line in region.lines:
            name, rhs = _DEF_RE.match(line.rstrip(",")).groups()
            om = _OP_RE.search(" " + rhs)
            op = om.group(1) if om else ""
            # result may be a tuple "(f32[..], f32[..]) op(...)": sum every
            # shape literal before the op mnemonic (combined all-reduces!)
            out_shape = rhs.split(f" {op}(")[0] if op else rhs.split(" ", 1)[0]
            out_b = _shapes_bytes(out_shape)
            kind = next((c for c in _COLLECTIVES if op == c
                         or op == c + "-start"), None)
            if op in _FREE_OPS:
                continue
            ops_list = _operands(rhs)
            if op == "dynamic-update-slice" and len(ops_list) >= 2:
                upd_b = _shapes_bytes(shape_of(ops_list[1]))
                traffic += scale * 2 * upd_b          # read update + write slice
            elif op == "while":
                continue  # body accounted via multipliers
            else:
                in_b = sum(_shapes_bytes(shape_of(o)) for o in ops_list)
                traffic += scale * (out_b + in_b)
            if kind:
                coll[kind] = coll.get(kind, 0.0) + scale * out_b
            if op == "dot":
                contracted = 1
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                lhs_shape = shape_of(ops_list[0]) if ops_list else ""
                mdim = _SHAPE_RE.match(lhs_shape)
                if mcd and mdim and mcd.group(1):
                    lhs_dims = [int(d) for d in mdim.group(2).split(",") if d]
                    for i in mcd.group(1).split(","):
                        contracted *= lhs_dims[int(i)]
                out_elems = 1
                sm = _SHAPE_RE.match(out_shape)
                if sm:
                    for d in sm.group(2).split(","):
                        if d:
                            out_elems *= int(d)
                flops += scale * 2.0 * out_elems * contracted
            elif op == "convolution":
                flops += scale * 2.0 * out_b

    return {"flops": flops, "bytes": traffic, "collectives": coll,
            "collective_bytes": float(sum(coll.values()))}
