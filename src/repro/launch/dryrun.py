import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract the roofline inputs.

For each cell this script:
  1. builds abstract state/inputs (ShapeDtypeStruct only — no allocation),
  2. jax.jit(step).lower(...).compile() under the target mesh,
  3. records memory_analysis(), cost_analysis(), and the collective operand
     bytes parsed from the post-SPMD HLO,
  4. appends one JSON record to results/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import REGISTRY, runnable_cells
from repro.distributed.sharding import (batch_specs, cache_specs_tree,
                                        param_shardings, replicated, use_mesh)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.models.model import cache_specs, input_specs
from repro.models.transformer import abstract_params
from repro.optim.adamw import AdamWConfig
from repro.train.state import make_abstract_state, state_shardings
from repro.train.train_step import (make_decode_step, make_prefill_step,
                                    make_train_step)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# v5e constants (per spec)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 fsdp: bool | None = None, microbatches: int = 1,
                 remat: bool = True, extra_tag: str = "") -> dict:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if fsdp is None:
        fsdp = cfg.n_params() * 2 > 8e9  # >8 GB of bf16 params -> FSDP
    opt = AdamWConfig(quantized_moments=cfg.n_params() > 50e9)
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.size), "fsdp": fsdp,
        "quantized_moments": opt.quantized_moments,
        "microbatches": microbatches, "remat": remat, "tag": extra_tag,
    }
    t0 = time.perf_counter()
    with use_mesh(mesh):
        inputs = input_specs(cfg, shape)
        in_batch_sh = batch_specs(inputs, mesh)
        if shape.kind == "train":
            abstract = make_abstract_state(cfg, opt)
            st_sh = state_shardings(abstract, mesh, cfg, fsdp)
            step = make_train_step(cfg, opt, microbatches=microbatches,
                                   remat=remat)
            jitted = jax.jit(step, in_shardings=(st_sh, in_batch_sh),
                             out_shardings=(st_sh, replicated(mesh)))
            lowered = jitted.lower(abstract, inputs)
        elif shape.kind == "prefill":
            aparams = abstract_params(cfg)
            p_sh = param_shardings(aparams, mesh, cfg, fsdp)
            step = make_prefill_step(cfg, max_seq=shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, in_batch_sh))
            lowered = jitted.lower(aparams, inputs)
        else:  # decode
            aparams = abstract_params(cfg)
            p_sh = param_shardings(aparams, mesh, cfg, fsdp)
            acache = cache_specs(cfg, shape)
            c_sh = cache_specs_tree(acache, mesh, cfg, shape)
            step = make_decode_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, in_batch_sh),
                             out_shardings=(replicated(mesh), c_sh))
            lowered = jitted.lower(aparams, acache, inputs)
        record["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = round(time.perf_counter() - t1, 2)

        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
        } if mem is not None else {}
        cost = compiled.cost_analysis() or {}
        # cost_analysis() is jax-version sensitive: some releases (e.g. the
        # 0.4.37 on this container) return a one-element list of per-program
        # dicts, others the flat dict itself. Accept both shapes.
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        # raw XLA numbers (while bodies counted ONCE — kept for reference)
        record["flops_hlo_raw"] = float(cost.get("flops", 0.0))
        record["bytes_hlo_raw"] = float(cost.get("bytes accessed", 0.0))
        text = compiled.as_text()
        # loop-corrected per-device analysis (trip-count aware; see
        # hlo_analysis.py). Params are read once per step on top of op traffic.
        corr = analyze_hlo(text)
        record["flops"] = corr["flops"]
        record["bytes_accessed"] = corr["bytes"] + record["memory"].get(
            "argument_size_in_bytes", 0)
        record["collectives"] = {k: int(v) for k, v in corr["collectives"].items()}
        record["collective_bytes_total"] = int(corr["collective_bytes"])
    # roofline terms — analyze_hlo numbers are PER-DEVICE (post-SPMD module)
    chips = record["chips"]
    record["t_compute_s"] = record["flops"] / PEAK_FLOPS
    record["t_memory_s"] = record["bytes_accessed"] / HBM_BW
    record["t_collective_s"] = record["collective_bytes_total"] / ICI_BW
    terms = {"compute": record["t_compute_s"], "memory": record["t_memory_s"],
             "collective": record["t_collective_s"]}
    record["bottleneck"] = max(terms, key=terms.get)
    nd = 6 * cfg.n_active_params() * shape.global_batch * (
        shape.seq_len if shape.kind == "train" else 1)
    if shape.kind != "train":
        nd = 2 * cfg.n_active_params() * shape.global_batch * (
            shape.seq_len if shape.kind == "prefill" else 1)
    record["model_flops"] = float(nd)
    hlo_cluster_flops = record["flops"] * chips
    record["useful_ratio"] = (record["model_flops"] / hlo_cluster_flops
                              if hlo_cluster_flops else 0.0)
    return record


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    d = os.path.abspath(os.path.join(RESULTS_DIR, mesh))
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(d, f"{arch}__{shape}{suffix}.json")


def run_cell(arch: str, shape: str, multi_pod: bool, skip_done: bool,
             **kw) -> dict | None:
    path = cell_path(arch, shape, multi_pod, kw.get("extra_tag", ""))
    if skip_done and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    try:
        rec = analyze_cell(arch, shape, multi_pod, **kw)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = (runnable_cells() if args.all
             else [(args.arch, args.shape)])
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.skip_done,
                       microbatches=args.microbatches, extra_tag=args.tag)
        status = ("ERROR " + rec["error"]) if "error" in rec else (
            f"ok {rec['bottleneck']:>10s} comp={rec['t_compute_s']:.4f}s "
            f"mem={rec['t_memory_s']:.4f}s coll={rec['t_collective_s']:.4f}s "
            f"(compile {rec.get('compile_s', 0):.0f}s)")
        print(f"[{rec['mesh']}] {arch:24s} {shape:12s} {status}", flush=True)
        if not args.all and "error" not in rec:
            print("memory_analysis:", json.dumps(rec["memory"], indent=1))
            print("cost_analysis: flops(raw)=%.4e bytes(raw)=%.4e" % (
                rec["flops_hlo_raw"], rec["bytes_hlo_raw"]))
            print("loop-corrected: flops=%.4e bytes=%.4e" % (
                rec["flops"], rec["bytes_accessed"]))
            print("collectives:", json.dumps(rec["collectives"], indent=1))


if __name__ == "__main__":
    main()
