"""repro subpackage."""
