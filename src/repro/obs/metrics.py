"""Process-wide serving metrics: counters, gauges, bucketed histograms.

One :class:`MetricsRegistry` per process (module-level ``REGISTRY``) collects
every serving-layer metric under one naming scheme
(``repro_store_multiget_latency_us{backend="numpy"}`` …) and exports them as
Prometheus text exposition (:func:`render_prometheus`, served by
``repro.obs.http``) and as JSON snapshots (the ``stats`` RPC extension).

Design constraints, in order:

* **Off the hot path's critical section.** A :class:`Counter` increment is
  one lock-free int add (CPython attribute store); a :class:`Histogram`
  record is a bisect into ~30 fixed bucket bounds plus two adds under a
  per-histogram lock that is never shared across instruments. No
  per-sample list ever grows (``tools/check_hotpath.py`` enforces this
  repo-wide for the serving modules).
* **Mergeable across processes and shards.** Histograms are fixed-bucket:
  two snapshots with the same bounds merge by summing counts
  (:func:`merge_hist_states`), so a client can pool per-shard latency
  distributions into one exact merged histogram — merged percentiles equal
  pooled-sample percentiles within one bucket's resolution.
* **Instance-isolated, process-aggregated.** Each store/service/server owns
  its *own* instrument (per-instance ``stats()`` stays meaningful — two
  shards never share a counter), while :meth:`MetricsRegistry.register`
  attaches it to the process registry; export merges instruments sharing a
  ``(name, labels)`` identity, exactly like scraping N collectors.

Stdlib only — serving hosts need neither numpy nor jax for metrics.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left


def default_latency_buckets_us() -> tuple[float, ...]:
    """Geometric microsecond buckets 1us..~67s (factor 2, 27 bounds).

    Factor-2 spacing bounds every reported percentile within 2x of the true
    sample percentile across six decades of latency — tight enough to gate
    a p99 SLO, small enough that a histogram is ~30 ints.
    """
    return tuple(float(1 << k) for k in range(27))


def _check_labels(labels: dict | None) -> tuple[tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Instrument:
    """Shared identity: ``name`` + frozen ``labels`` key."""

    def __init__(self, name: str, labels: dict | None = None):
        self.name = str(name)
        self.labels = _check_labels(labels)

    @property
    def key(self) -> tuple:
        return (self.name, self.labels)

    def label_dict(self) -> dict:
        return dict(self.labels)


class Counter(_Instrument):
    """Monotonic event count. ``inc`` takes one uncontended per-counter
    lock (~100ns) — exact under concurrent handler threads (replica-routing
    tests assert on exact op deltas), never shared across instruments, and
    never held around any I/O or decode work."""

    kind = "counter"

    def __init__(self, name: str, labels: dict | None = None):
        super().__init__(name, labels)
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def state(self) -> dict:
        return {"value": self.value}


class Gauge(_Instrument):
    """Point-in-time value (queue depth, adaptive window, resident bytes)."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict | None = None):
        super().__init__(name, labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, dv: float) -> None:
        self.value += float(dv)

    def state(self) -> dict:
        return {"value": self.value}


class Histogram(_Instrument):
    """Fixed-bucket latency histogram: exact p50/p99/p999 within bucket
    resolution, constant memory, snapshot-mergeable across processes.

    ``bounds`` are ascending finite upper bucket edges; one implicit
    overflow bucket catches everything above the last edge. Values are
    recorded in the unit the name declares (``*_us`` → microseconds — use
    :meth:`record_seconds` from ``perf_counter`` deltas).
    """

    kind = "histogram"

    def __init__(self, name: str, labels: dict | None = None,
                 bounds: tuple[float, ...] | None = None):
        super().__init__(name, labels)
        self.bounds: tuple[float, ...] = tuple(
            float(b) for b in (bounds or default_latency_buckets_us()))
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly ascending")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: overflow bucket
        self.sum = 0.0
        self._lock = threading.Lock()

    # ------------------------------------------------------------- recording
    def record(self, value: float) -> None:
        idx = bisect_left(self.bounds, value)
        with self._lock:
            self.counts[idx] += 1
            self.sum += value

    def record_seconds(self, seconds: float) -> None:
        self.record(seconds * 1e6)

    # ------------------------------------------------------------- reporting
    @property
    def count(self) -> int:
        return sum(self.counts)

    def percentile(self, p: float) -> float:
        """Value at percentile ``p`` (0..100), linearly interpolated inside
        the bucket the rank falls in — exact to within one bucket width."""
        return _state_percentile(self.state(), p)

    def summary(self) -> dict:
        """The serving-layer latency summary schema: same keys every
        surface reports (matches ``repro.core.metrics.latency_summary``,
        plus p999)."""
        return summarize_hist_state(self.state())

    def state(self) -> dict:
        """JSON-serializable snapshot (finite bounds only — the overflow
        bucket is ``counts[-1]``), the merge/transport format."""
        with self._lock:
            return {"bounds": list(self.bounds), "counts": list(self.counts),
                    "sum": self.sum}

    def merge_state(self, state: dict) -> None:
        """Fold another snapshot (same bounds) into this histogram."""
        if list(state["bounds"]) != list(self.bounds):
            raise ValueError("cannot merge histograms with different bounds")
        with self._lock:
            for i, c in enumerate(state["counts"]):
                self.counts[i] += int(c)
            self.sum += float(state["sum"])

    @classmethod
    def from_state(cls, state: dict, name: str = "",
                   labels: dict | None = None) -> "Histogram":
        h = cls(name, labels, bounds=tuple(state["bounds"]))
        h.counts = [int(c) for c in state["counts"]]
        h.sum = float(state["sum"])
        return h


# ----------------------------------------------------------- state helpers
def _state_percentile(state: dict, p: float) -> float:
    bounds, counts = state["bounds"], state["counts"]
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = max(1.0, math.ceil(total * min(max(p, 0.0), 100.0) / 100.0))
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c >= rank:
            lo = bounds[i - 1] if i else 0.0
            hi = bounds[i] if i < len(bounds) else bounds[-1] * 2
            return lo + (hi - lo) * (rank - cum) / c
        cum += c
    return bounds[-1] * 2  # unreachable; overflow upper estimate


def merge_hist_states(states) -> dict | None:
    """Pool histogram snapshots (same bounds) into one; exact — the merged
    counts equal a histogram of the pooled samples. ``None`` when no state
    was supplied (a backend without histograms)."""
    merged: dict | None = None
    for state in states:
        if not state:
            continue
        if merged is None:
            merged = {"bounds": list(state["bounds"]),
                      "counts": [int(c) for c in state["counts"]],
                      "sum": float(state["sum"])}
        else:
            if list(state["bounds"]) != merged["bounds"]:
                raise ValueError(
                    "cannot merge histograms with different bounds")
            for i, c in enumerate(state["counts"]):
                merged["counts"][i] += int(c)
            merged["sum"] += float(state["sum"])
    return merged


def diff_hist_states(after: dict | None, before: dict | None) -> dict | None:
    """Bucket-wise ``after - before`` for two snapshots of the SAME
    (growing) histogram — the state a load run contributes on top of
    whatever the server had already served. Negative deltas (a restarted
    server) clamp to zero rather than corrupt percentiles. ``before=None``
    means "no prior snapshot": the after state passes through unchanged."""
    if not after:
        return None
    if not before:
        return {"bounds": list(after["bounds"]),
                "counts": [int(c) for c in after["counts"]],
                "sum": float(after["sum"])}
    if list(after["bounds"]) != list(before["bounds"]):
        raise ValueError("cannot diff histograms with different bounds")
    counts = [max(0, int(a) - int(b))
              for a, b in zip(after["counts"], before["counts"])]
    return {"bounds": list(after["bounds"]), "counts": counts,
            "sum": max(0.0, float(after["sum"]) - float(before["sum"]))}


def summarize_hist_state(state: dict | None) -> dict:
    """Snapshot -> the unified latency summary dict (us units)."""
    if not state or not sum(state["counts"]):
        return {"p50_us": 0.0, "p99_us": 0.0, "p999_us": 0.0,
                "count": 0, "mean_us": 0.0}
    n = sum(state["counts"])
    return {"p50_us": _state_percentile(state, 50.0),
            "p99_us": _state_percentile(state, 99.0),
            "p999_us": _state_percentile(state, 99.9),
            "count": n,
            "mean_us": state["sum"] / n}


# --------------------------------------------------------------- registry
class MetricsRegistry:
    """Process-wide instrument collection.

    Two ways in:

    * :meth:`counter` / :meth:`gauge` / :meth:`histogram` — get-or-create a
      shared series by ``(name, labels)`` (callers incrementing the same
      logical metric from several sites share one object);
    * :meth:`register` — attach a caller-owned instrument (per-store /
      per-service isolation); export merges same-identity instruments by
      summing, exactly like a Prometheus scrape over N collectors.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: list[_Instrument] = []
        self._shared: dict[tuple, _Instrument] = {}

    # ------------------------------------------------------------- creation
    def _get_or_create(self, cls, name: str, labels: dict | None, **kw):
        key = (name, _check_labels(labels))
        with self._lock:
            inst = self._shared.get(key)
            if inst is None:
                inst = cls(name, labels, **kw)
                self._shared[key] = inst
                self._instruments.append(inst)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r}{dict(key[1])} already registered as "
                    f"{inst.kind}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None,
                  **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels, bounds=bounds)

    def register(self, instrument: _Instrument) -> _Instrument:
        with self._lock:
            self._instruments.append(instrument)
        return instrument

    def unregister(self, instrument: _Instrument) -> None:
        with self._lock:
            try:
                self._instruments.remove(instrument)
            except ValueError:
                pass

    # -------------------------------------------------------------- export
    def _merged(self) -> list[tuple[str, str, tuple, dict]]:
        """(kind, name, labels, merged-state) per series — same-identity
        instruments pool (counters/gauges sum, histograms merge counts)."""
        with self._lock:
            instruments = list(self._instruments)
        series: dict[tuple, dict] = {}
        order: list[tuple] = []
        for inst in instruments:
            key = (inst.kind,) + inst.key
            if key not in series:
                series[key] = (inst.state() if inst.kind != "histogram"
                               else merge_hist_states([inst.state()]))
                order.append(key)
            elif inst.kind == "histogram":
                merged = merge_hist_states([series[key], inst.state()])
                series[key] = merged
            else:
                series[key] = {"value": series[key]["value"] + inst.value}
        return [(kind, name, labels, series[(kind, name, labels)])
                for kind, name, labels in order]

    def snapshot(self) -> dict:
        """JSON-safe registry dump: the ``stats`` RPC metrics extension and
        the cross-process merge format."""
        out: list[dict] = []
        for kind, name, labels, state in self._merged():
            out.append({"type": kind, "name": name,
                        "labels": dict(labels), **state})
        return {"metrics": out}

    def render_prometheus(self) -> str:
        return render_prometheus(self)

    def clear(self) -> None:
        """Drop every instrument (tests only — live code never resets)."""
        with self._lock:
            self._instruments.clear()
            self._shared.clear()


def _fmt_labels(labels: tuple, extra: tuple[tuple[str, str], ...] = ()) -> str:
    pairs = tuple(labels) + tuple(extra)
    if not pairs:
        return ""
    def esc(v: str) -> str:
        return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in pairs) + "}"


def _fmt_value(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def render_prometheus(registry: "MetricsRegistry") -> str:
    """Prometheus text exposition (format 0.0.4) of every series.

    Histograms emit the standard cumulative ``_bucket{le=...}`` series plus
    ``_sum``/``_count`` — bucket counts at ``le="+Inf"`` equal the series'
    op count, the invariant the acceptance test scrapes for.
    """
    lines: list[str] = []
    typed: set[str] = set()
    for kind, name, labels, state in registry._merged():
        if name not in typed:
            lines.append(f"# TYPE {name} {kind}")
            typed.add(name)
        if kind == "histogram":
            cum = 0
            for bound, c in zip(state["bounds"], state["counts"]):
                cum += c
                lines.append(
                    f"{name}_bucket"
                    f"{_fmt_labels(labels, (('le', _fmt_value(bound)),))}"
                    f" {cum}")
            cum += state["counts"][-1]
            lines.append(
                f"{name}_bucket{_fmt_labels(labels, (('le', '+Inf'),))} {cum}")
            lines.append(f"{name}_sum{_fmt_labels(labels)}"
                         f" {_fmt_value(state['sum'])}")
            lines.append(f"{name}_count{_fmt_labels(labels)} {cum}")
        else:
            lines.append(f"{name}{_fmt_labels(labels)}"
                         f" {_fmt_value(state['value'])}")
    return "\n".join(lines) + "\n"


#: the process-wide registry every serving module exports through
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
