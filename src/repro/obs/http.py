"""Metrics/trace export over HTTP — the ``--metrics-port`` surface.

A tiny threaded stdlib HTTP server exposing the process registry and
tracer::

    GET /metrics        Prometheus text exposition (scrape target)
    GET /traces?n=16    slow-request trace dump as JSON
    GET /healthz        "ok" liveness probe

Runs as a daemon thread next to the serving socket; ``port=0`` binds a
kernel-assigned port (reported via :attr:`MetricsServer.port` and the shard
server's READY announce line).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import TRACER, Tracer


class _Handler(BaseHTTPRequestHandler):
    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib handler naming)
        srv: "MetricsServer" = self.server.metrics_server  # type: ignore[attr-defined]
        url = urlparse(self.path)
        if url.path == "/metrics":
            self._send(200, srv.registry.render_prometheus().encode(),
                       "text/plain; version=0.0.4; charset=utf-8")
        elif url.path == "/traces":
            n = int(parse_qs(url.query).get("n", ["16"])[0])
            self._send(200, json.dumps(srv.tracer.trace_dump(n)).encode(),
                       "application/json")
        elif url.path == "/healthz":
            self._send(200, b"ok\n", "text/plain")
        else:
            self._send(404, b"not found\n", "text/plain")

    def log_message(self, *args) -> None:  # scrapes are not server logs
        pass


class MetricsServer:
    """Threaded exposition server over one registry + tracer pair."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.registry = registry if registry is not None else REGISTRY
        self.tracer = tracer if tracer is not None else TRACER
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.metrics_server = self  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return int(self._httpd.server_address[1])

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.2},
            daemon=True,
            name=f"metrics-server-{self.port}",
        )
        self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(port: int = 0, host: str = "127.0.0.1",
                         registry: MetricsRegistry | None = None,
                         tracer: Tracer | None = None) -> MetricsServer:
    """Bind + serve ``/metrics`` (Prometheus), ``/traces``, ``/healthz`` on
    a daemon thread; returns the running server (``.port`` for port 0)."""
    return MetricsServer(port=port, host=host, registry=registry,
                         tracer=tracer).start()
