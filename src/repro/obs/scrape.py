"""Scrape-side helpers: read a ``--metrics-port`` endpoint back into states.

The shard servers export their registries as Prometheus text exposition
(``repro.obs.http``); a load driver gating an SLO needs the *states* back —
per-shard histogram bucket counts it can :func:`merge_hist_states` /
:func:`diff_hist_states` exactly as if it had called the ``stats`` RPC
metrics extension. Text exposition is lossless for that purpose: cumulative
``_bucket{le=...}`` counts de-cumulate to exact per-bucket counts, and
``_sum`` rides along, so a scraped histogram state is byte-equivalent to
the server's own ``Histogram.state()``.

Stdlib only, like the rest of :mod:`repro.obs`.
"""

from __future__ import annotations

import json
import re
import urllib.request

_SERIES_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"')


def fetch_text(url: str, timeout: float = 5.0) -> str:
    """GET one exposition/trace endpoint (``http://host:port/metrics``)."""
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.read().decode("utf-8")


def fetch_metrics(host: str, port: int, timeout: float = 5.0) -> str:
    return fetch_text(f"http://{host}:{port}/metrics", timeout=timeout)


def fetch_traces(host: str, port: int, n: int = 16,
                 timeout: float = 5.0) -> list[dict]:
    """The server's slow-request log via HTTP (same data as OP_TRACE_DUMP)."""
    return json.loads(
        fetch_text(f"http://{host}:{port}/traces?n={int(n)}",
                   timeout=timeout))


def _unescape(v: str) -> str:
    return v.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")


def _parse_labels(raw: str | None) -> dict:
    if not raw:
        return {}
    return {m.group("k"): _unescape(m.group("v"))
            for m in _LABEL_RE.finditer(raw)}


def parse_prometheus(text: str) -> list[dict]:
    """Exposition text -> the registry ``snapshot()`` row shape.

    Counters/gauges become ``{"type", "name", "labels", "value"}`` rows;
    histogram ``_bucket``/``_sum``/``_count`` families reassemble into one
    ``{"type": "histogram", "name", "labels", "bounds", "counts", "sum"}``
    row whose de-cumulated counts (overflow bucket included) match the
    exporting server's ``Histogram.state()`` exactly.
    """
    typed: dict[str, str] = {}
    scalars: list[dict] = []
    # histogram assembly keyed on (name, sorted non-le labels)
    hists: dict[tuple, dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                typed[parts[2]] = parts[3]
            continue
        m = _SERIES_RE.match(line)
        if not m:
            continue
        name, labels = m.group("name"), _parse_labels(m.group("labels"))
        value = float(m.group("value")) if m.group("value") != "+Inf" else 0.0
        base, _, suffix = name.rpartition("_")
        if suffix in ("bucket", "sum", "count") and typed.get(base) == "histogram":
            le = labels.pop("le", None)
            key = (base, tuple(sorted(labels.items())))
            h = hists.setdefault(key, {"buckets": [], "sum": 0.0})
            if suffix == "bucket":
                h["buckets"].append((le, value))
            elif suffix == "sum":
                h["sum"] = value
            continue
        scalars.append({"type": typed.get(name, "counter"), "name": name,
                        "labels": labels, "value": value})
    out = list(scalars)
    for (name, labels), h in hists.items():
        finite = [(float(le), int(c)) for le, c in h["buckets"]
                  if le not in (None, "+Inf")]
        finite.sort(key=lambda bc: bc[0])
        inf = [int(c) for le, c in h["buckets"] if le == "+Inf"]
        total = inf[0] if inf else (finite[-1][1] if finite else 0)
        counts, prev = [], 0
        for _, cum in finite:
            counts.append(cum - prev)
            prev = cum
        counts.append(total - prev)  # overflow bucket
        out.append({"type": "histogram", "name": name,
                    "labels": dict(labels),
                    "bounds": [b for b, _ in finite],
                    "counts": counts, "sum": h["sum"]})
    return out


def find_series(rows: list[dict], name: str,
                labels: dict | None = None) -> list[dict]:
    """Rows matching ``name`` whose labels contain every ``labels`` pair."""
    want = (labels or {}).items()
    return [r for r in rows
            if r["name"] == name and all(r["labels"].get(k) == str(v)
                                         for k, v in want)]


def hist_state_from_rows(rows: list[dict], name: str,
                         labels: dict | None = None) -> dict | None:
    """First matching histogram row as a mergeable ``state`` dict."""
    for r in find_series(rows, name, labels):
        if r["type"] == "histogram":
            return {"bounds": list(r["bounds"]), "counts": list(r["counts"]),
                    "sum": float(r["sum"])}
    return None
