"""End-to-end request tracing: trace ids, span stacks, slow-request ring.

A *trace* is one logical request (one ``client.multiget``), identified by a
16-hex-char trace id minted at the outermost span. *Spans* are named timed
sections inside it — ``client.multiget`` → ``rpc.multiget`` (socket) →
``server.multiget`` → ``service.coalesce`` (micro-batch wait) →
``store.decode`` (kernel/numpy dispatch, batch size annotated) — linked by
parent span ids, so a dump shows exactly where a request's time went across
threads and, via the :mod:`repro.net.protocol` trace header, across
processes.

Two propagation mechanisms:

* **thread-local ambient context** — :meth:`Tracer.span` opens a child of
  the current context and activates itself for the body, so nested calls
  (store inside service inside server) need no plumbing. When *no* ambient
  context exists and ``root=False``, ``span`` is a no-op: untraced hot
  paths pay one ``getattr``.
* **explicit contexts** — queue hops (the micro-batching service) and wire
  hops (the RPC frame's optional trace header) carry a
  :class:`TraceContext` value; :meth:`Tracer.activate` installs it on the
  receiving thread and :meth:`Tracer.record` books spans with explicit
  timestamps (e.g. a coalesce-wait span measured enqueue→drain).

Finished spans land in a bounded ring (constant memory — the hot-path lint
forbids unbounded sample lists); :meth:`Tracer.trace_dump` groups the ring
by trace id and returns the *slowest* ``n`` recent requests, the on-server
slow-request log the ISSUE's SLO work reads.

Stdlib only; timestamps are ``perf_counter`` relative to process start
(``time.time()`` is banned from serving modules by ``tools/check_hotpath``).
"""

from __future__ import annotations

import itertools
import threading
import time
import uuid
from collections import deque
from contextlib import contextmanager
from typing import NamedTuple


class TraceContext(NamedTuple):
    """What crosses a thread/queue/wire hop: which trace, which span."""

    trace_id: str  # 16 lowercase hex chars
    span_id: int   # u64, unique within the minting process


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


class Tracer:
    """Per-process span recorder with ambient (thread-local) context."""

    def __init__(self, max_spans: int = 4096):
        self._tls = threading.local()
        self._spans: deque = deque(maxlen=int(max_spans))
        self._ids = itertools.count(1)  # next() is atomic under the GIL
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------- context
    def current(self) -> TraceContext | None:
        return getattr(self._tls, "ctx", None)

    def activate(self, ctx: TraceContext | None) -> TraceContext | None:
        """Install ``ctx`` as this thread's ambient context; returns the
        previous one for :meth:`restore` (always pair them)."""
        prev = self.current()
        self._tls.ctx = ctx
        return prev

    def restore(self, prev: TraceContext | None) -> None:
        self._tls.ctx = prev

    def new_context(
        self, parent: TraceContext | None = None, *, inherit: bool = True
    ) -> tuple[TraceContext, int]:
        """Allocate a span context: child of ``parent`` (default: the
        ambient context) or a fresh trace root. Returns ``(ctx,
        parent_span_id)`` — parent id 0 marks a root span."""
        if parent is None and inherit:
            parent = self.current()
        if parent is None:
            return TraceContext(new_trace_id(), next(self._ids)), 0
        return (TraceContext(parent.trace_id, next(self._ids)),
                parent.span_id)

    # ------------------------------------------------------------ recording
    def record(self, name: str, ctx: TraceContext, parent_id: int,
               start_s: float, duration_s: float, **annotations) -> None:
        """Book one finished span with explicit ``perf_counter`` times."""
        self._spans.append({
            "name": name,
            "trace_id": ctx.trace_id,
            "span_id": ctx.span_id,
            "parent_id": parent_id,
            "start_us": (start_s - self._epoch) * 1e6,
            "duration_us": duration_s * 1e6,
            "annotations": annotations,
        })

    def record_child(self, name: str, parent: TraceContext | None,
                     start_s: float, duration_s: float,
                     **annotations) -> TraceContext:
        """Allocate + book a child span of ``parent`` in one call (queue
        hops where the span's lifetime is known only after the fact)."""
        ctx, pid = self.new_context(parent, inherit=parent is not None)
        self.record(name, ctx, pid, start_s, duration_s, **annotations)
        return ctx

    @contextmanager
    def span(self, name: str, *, root: bool = False, **annotations):
        """Timed section as a child of the ambient context.

        No ambient context and ``root=False`` → no-op (yields ``None``);
        ``root=True`` mints a new trace when none is active. The span's
        context is ambient for the body, so nested spans chain parentage.
        """
        parent = self.current()
        if parent is None and not root:
            yield None
            return
        ctx, pid = self.new_context(parent)
        prev = self.activate(ctx)
        t0 = time.perf_counter()
        try:
            yield ctx
        finally:
            self.restore(prev)
            self.record(name, ctx, pid, t0, time.perf_counter() - t0,
                        **annotations)

    # -------------------------------------------------------------- reading
    def trace_dump(self, n: int = 16) -> list[dict]:
        """The ``n`` slowest recent traces (slowest first), each with its
        spans in start order — the per-server slow-request log."""
        by_trace: dict[str, list[dict]] = {}
        for span in list(self._spans):  # snapshot; deque mutates under us
            by_trace.setdefault(span["trace_id"], []).append(span)
        traces = []
        for trace_id, spans in by_trace.items():
            spans.sort(key=lambda s: s["start_us"])
            roots = [s for s in spans if s["parent_id"] == 0]
            duration = max((s["duration_us"] for s in (roots or spans)))
            traces.append({
                "trace_id": trace_id,
                "duration_us": duration,
                "root": (roots or spans)[0]["name"],
                "n_spans": len(spans),
                "spans": spans,
            })
        traces.sort(key=lambda t: -t["duration_us"])
        return traces[: int(n)]

    def clear(self) -> None:
        self._spans.clear()


#: the process-wide tracer every serving module records into
TRACER = Tracer()


def trace_dump(n: int = 16) -> list[dict]:
    """Module-level shortcut onto the process tracer's slow-request ring."""
    return TRACER.trace_dump(n)
