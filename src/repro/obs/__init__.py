"""repro.obs — unified observability for the serving stack.

One process-wide :data:`REGISTRY` of lock-cheap counters/gauges/fixed-bucket
histograms (mergeable across shards and processes), one :data:`TRACER`
carrying per-request trace ids through client → socket → service coalesce →
store decode, and the export surfaces that read them: Prometheus text via
:func:`start_metrics_server` (``--metrics-port``), the ``stats`` RPC metrics
extension, and the per-server slow-request log :func:`trace_dump`.

Stdlib only — importable on numpy-less, jax-less serving hosts.
"""

from repro.obs.http import MetricsServer, start_metrics_server
from repro.obs.metrics import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_latency_buckets_us,
    diff_hist_states,
    get_registry,
    merge_hist_states,
    render_prometheus,
    summarize_hist_state,
)
from repro.obs.scrape import (
    fetch_metrics,
    fetch_traces,
    find_series,
    hist_state_from_rows,
    parse_prometheus,
)
from repro.obs.trace import TRACER, TraceContext, Tracer, new_trace_id, trace_dump

__all__ = [
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "TRACER",
    "TraceContext",
    "Tracer",
    "default_latency_buckets_us",
    "diff_hist_states",
    "fetch_metrics",
    "fetch_traces",
    "find_series",
    "get_registry",
    "hist_state_from_rows",
    "merge_hist_states",
    "new_trace_id",
    "parse_prometheus",
    "render_prometheus",
    "start_metrics_server",
    "summarize_hist_state",
    "trace_dump",
]
