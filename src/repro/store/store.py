"""CompressedStringStore — batched random-access serving over OnPair corpora.

The paper's headline property (per-string independent compression => O(1)
random access) turned into a serving subsystem: a trained OnPair/OnPair16
dictionary plus a :class:`~repro.core.api.CompressedCorpus` become an
in-memory store answering ``get(i)`` / ``multiget(ids)`` / ``scan(lo, hi)``.

Hot path (``multiget``): cache misses are routed through the segment layer
to their token streams, *length-bucketed* into a small set of static padded
``(B, T)`` shapes, and decoded by the Pallas per-string kernel
(``repro.kernels.onpair_decode.decode_compact`` via
``OnPairDevice.multiget_decode``). Pinning both the batch dim and the token
dim to at most ``num_buckets`` bucket capacities keeps the number of
jit-compiled decode shapes bounded (<= num_buckets, default 4) no matter the
query mix. When JAX is unavailable — or the dictionary is unbounded OnPair,
which the 16-byte-row kernel cannot decode — the store falls back to the
vectorised numpy ``PackedDictionary.decode_tokens`` path.
"""

from __future__ import annotations

import heapq
import json
import os
import threading
import time
from itertools import islice

import numpy as np

from repro.core import registry
from repro.core.api import CompressedCorpus
from repro.core.artifact import DictArtifact
from repro.core.codec import Encoder
from repro.core.index import SegmentIndex, dump_indexes, load_indexes
from repro.core.packed import PackedDictionary
from repro.obs import TRACER
from repro.store.cache import LRUCache
from repro.store.segment import SegmentedCorpus
from repro.store.stats import StoreStats

try:
    if os.environ.get("REPRO_NO_JAX"):  # opt-out: numpy-only serving hosts
        raise ImportError("REPRO_NO_JAX is set")
    from repro.kernels.ops import OnPairDevice
    _HAVE_JAX = True
except Exception:  # pragma: no cover - container without jax
    OnPairDevice = None
    _HAVE_JAX = False

#: quantiles of the corpus token-count distribution that seed the bucket
#: capacities (the last one is stretched to cover the true maximum).
_BUCKET_QUANTILES = (0.5, 0.9, 0.99, 1.0)


def _ceil8(x: int) -> int:
    return max(8, (int(x) + 7) // 8 * 8)


def write_json_atomic(path: str, obj: dict) -> None:
    """Write JSON via temp-file + rename so readers never see a torn file."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
    os.replace(tmp, path)


class CompressedStringStore:
    """Queryable in-memory store over one compressed corpus.

    ``source`` is either a trained token-stream codec (the pre-v2 calling
    convention), a serialized :class:`DictArtifact` — the store is exactly
    the consumer the artifact split exists for: open a dictionary that was
    trained elsewhere and serve, no trainer state required — or an
    ``(artifact, codec)`` pair when both are already loaded (shared-
    dictionary layouts open N stores without N table or artifact rebuilds).
    """

    def __init__(self, source, corpus: CompressedCorpus,
                 *, strings_per_segment: int = 4096,
                 cache_bytes: int = 8 << 20, batch_size: int = 256,
                 num_buckets: int = 4, backend: str = "auto",
                 use_pallas: bool = True):
        self._artifact: DictArtifact | None
        if isinstance(source, tuple):
            self._artifact, compressor = source
        elif isinstance(source, DictArtifact):
            self._artifact = source
            compressor = registry.codec_from_artifact(source)
        else:
            self._artifact = None
            compressor = source
        if getattr(compressor, "dictionary", None) is None:
            raise ValueError("source must be a trained token-stream codec "
                             "or a DictArtifact (train() first)")
        caps = registry.capabilities(compressor.name)
        if not caps.token_stream:
            raise ValueError("store requires a token-stream codec "
                             f"(registry capability), got {compressor.name!r}")
        if num_buckets < 1 or num_buckets > len(_BUCKET_QUANTILES):
            raise ValueError(f"num_buckets must be in 1..{len(_BUCKET_QUANTILES)}")
        self.compressor = compressor
        self.dictionary: PackedDictionary = compressor.dictionary
        self.corpus = corpus
        self.segments = SegmentedCorpus.from_corpus(corpus, strings_per_segment)
        self.cache = LRUCache(cache_bytes)
        self.batch_size = int(batch_size)
        self.num_buckets = int(num_buckets)
        self.use_pallas = use_pallas
        self._lock = threading.Lock()
        # reverse-lookup state: per-segment indexes (built lazily on the
        # first locate/scan_prefix, eagerly at seal time once active) and
        # the query-side encoder (lazy: most stores never locate)
        self._seg_indexes: dict[int, SegmentIndex] = {}
        self._locate_encoder: Encoder | None = None
        # hot/cold tiering (repro.store.tier); None until enable_tiering()
        self.tier = None

        # ----- backend resolution: per-codec registry capability, not an
        # isinstance/variant16 probe — an artifact opened on a jax-less host
        # resolves to numpy, a device-decodable codec routes to the kernels.
        jax_ok = _HAVE_JAX and caps.device_decodable
        if backend == "auto":
            backend = "jax" if jax_ok else "numpy"
        elif backend == "jax" and not jax_ok:
            raise ValueError(
                "jax backend unavailable: " +
                (f"codec {compressor.name!r} is not device-decodable "
                 "(registry capability)" if _HAVE_JAX else "jax not importable"))
        elif backend not in ("jax", "numpy"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        # stats carries the resolved backend as a metric label, so it is
        # created only once backend resolution has run
        self.stats = StoreStats(backend=backend)
        self._device = OnPairDevice(self.dictionary) if backend == "jax" else None
        self._set_bucket_caps(corpus.token_counts())

    def _set_bucket_caps(self, counts: np.ndarray) -> None:
        """Length buckets: static token capacities from corpus quantiles."""
        if counts.size == 0:
            caps = [8]
        else:
            qs = _BUCKET_QUANTILES[-self.num_buckets:]
            caps = sorted({_ceil8(np.quantile(counts, q)) for q in qs})
            max_count = int(counts.max())
            if caps[-1] < max_count:
                caps.append(_ceil8(max_count))
                if len(caps) > self.num_buckets:
                    caps = caps[-self.num_buckets:]
        self.bucket_caps = np.asarray(caps, dtype=np.int64)

    # ------------------------------------------------------------ construction
    @classmethod
    def build(cls, strings: list[bytes], *, codec: str | None = None,
              variant16: bool = True, sample_bytes: int = 4 << 20,
              seed: int = 0, **store_kw) -> "CompressedStringStore":
        """Train a dictionary on ``strings``, compress them, open a store.

        ``codec`` is any registered token-stream codec name; the legacy
        ``variant16`` flag maps to onpair16/onpair when ``codec`` is None.
        """
        if codec is None:
            codec = "onpair16" if variant16 else "onpair"
        comp = registry.create(codec, sample_bytes=sample_bytes, seed=seed)
        comp.train(strings)
        return cls(comp, comp.compress(strings), **store_kw)

    # ------------------------------------------------------------- persistence
    #: directory layout written by save() / read by open()
    _DICT_FILE = "dictionary.rpa"
    _CORPUS_FILE = "corpus.rpc"
    _META_FILE = "store.json"
    #: optional reverse-lookup sidecar (per-segment fingerprint tables +
    #: sort permutations); loaders validate it against the live
    #: segmentation and silently rebuild on any mismatch
    _INDEX_FILE = "index.npz"
    #: manifest of the versioned (writable-store) directory layout
    _CURRENT_FILE = "current.json"
    #: construction params persisted in store.json and restored by open()
    _STORE_KW = ("strings_per_segment", "cache_bytes", "batch_size",
                 "num_buckets")

    @property
    def artifact(self) -> DictArtifact:
        """The store's dictionary as an immutable, serializable artifact."""
        if self._artifact is None:
            self._artifact = self.compressor.to_artifact()
        return self._artifact

    def snapshot_corpus(self) -> CompressedCorpus:
        """The store's full compressed payload as one corpus. The writable
        subclass overrides this to flatten sealed segments + tail — the
        construction-time ``self.corpus`` does not cover appended data."""
        return self.corpus

    def store_meta(self, **extra) -> dict:
        """The store.json payload: codec + construction params (+ extras)."""
        meta = {"format_version": 1, "codec": self.artifact.codec,
                "n_strings": self.n_strings,
                "strings_per_segment": self.segments.strings_per_segment,
                "cache_bytes": self.cache.capacity_bytes,
                "batch_size": self.batch_size,
                "num_buckets": self.num_buckets}
        meta.update(extra)
        return meta

    def save(self, dir_path: str) -> None:
        """Persist dictionary artifact + compressed corpus + store config so
        :meth:`open` serves identical results without retraining."""
        os.makedirs(dir_path, exist_ok=True)
        self.artifact.save(os.path.join(dir_path, self._DICT_FILE))
        self.corpus.save(os.path.join(dir_path, self._CORPUS_FILE))
        with self._lock:
            blob = self._dump_index_locked()
            tier_meta = self._tier_meta_locked()
        write_json_atomic(os.path.join(dir_path, self._META_FILE),
                          self.store_meta(**tier_meta))
        if blob is not None:
            with open(os.path.join(dir_path, self._INDEX_FILE), "wb") as f:
                f.write(blob)
        if tier_meta:
            self.tier.copy_cold_files(tier_meta["cold_segments"], dir_path)

    @classmethod
    def open_corpus_dir(cls, dir_path: str, source,
                        mmap: bool = True, **overrides) -> "CompressedStringStore":
        """Open a directory holding corpus.rpc + store.json against an
        already-loaded artifact or codec (shared-dictionary layouts:
        sharding opens N corpora against one dictionary)."""
        with open(os.path.join(dir_path, cls._META_FILE)) as f:
            meta = json.load(f)
        corpus = CompressedCorpus.load(
            os.path.join(dir_path, cls._CORPUS_FILE), mmap=mmap)
        kw = {k: meta[k] for k in cls._STORE_KW}
        kw.update(overrides)
        store = cls(source, corpus, **kw)
        store._load_index(dir_path)
        store._attach_tier(dir_path, meta)
        return store

    @classmethod
    def _resolve_current(cls, dir_path: str) -> str:
        """Follow a versioned directory's ``current.json`` manifest to its
        current generation subdirectory; a plain flat store directory
        resolves to itself."""
        cur = os.path.join(dir_path, cls._CURRENT_FILE)
        if os.path.exists(cur):
            with open(cur) as f:
                return os.path.join(dir_path, json.load(f)["current"])
        return dir_path

    @classmethod
    def open(cls, dir_path: str, mmap: bool = True,
             **overrides) -> "CompressedStringStore":
        """Open a saved store: mmap the artifact + corpus, no retraining.
        ``overrides`` replace saved construction params (e.g. ``backend=``).
        A versioned (writable-store) directory opens read-only at its
        current generation."""
        dir_path = cls._resolve_current(dir_path)
        artifact = DictArtifact.load(
            os.path.join(dir_path, cls._DICT_FILE), mmap=mmap)
        return cls.open_corpus_dir(dir_path, artifact, mmap=mmap, **overrides)

    # ----------------------------------------------------------------- tiering
    def enable_tiering(self, **params):
        """Get-or-create the store's :class:`~repro.store.tier.TierManager`.
        Parameters only apply on first creation; a later call with different
        thresholds updates them in place."""
        from repro.store.tier import TierManager
        if self.tier is None:
            self.tier = TierManager(self, **params)
        elif params:
            for k in ("demote_below", "promote_above", "halflife_s"):
                if k in params:
                    setattr(self.tier, k, float(params[k]))
        return self.tier

    def _tier_meta_locked(self) -> dict:
        """store.json extras describing the tier state (``{}`` when the
        tier is off or empty — old stores stay byte-identical)."""
        if self.tier is None or not self.tier.cold:
            return {}
        return {"tier_params": self.tier.params(),
                "cold_segments": self.tier.cold_items_locked()}

    def _attach_tier(self, dir_path: str, meta: dict) -> None:
        """Re-adopt cold segments persisted by a save (called after
        ``_load_index`` so both sidecars validate against the same live
        segmentation)."""
        cold = meta.get("cold_segments")
        if not cold:
            return
        tier = self.enable_tiering(**meta.get("tier_params", {}))
        tier.attach(dir_path, cold)

    # -------------------------------------------------------------- tail hooks
    # A store may hold strings beyond the sealed SegmentedCorpus: the writable
    # subclass (repro.store.mutable) keeps an open *tail* of appended strings.
    # The read path is written against these hooks so get/multiget/scan/stats
    # stay correct across sealed + tail data; the read-only base has no tail.
    def _tail_n(self) -> int:
        return 0

    def _tail_payload_bytes(self) -> int:
        return 0

    def _tail_string_tokens(self, local: int) -> np.ndarray:
        raise IndexError(f"tail string {local} does not exist "
                         "(read-only store has no tail)")

    def _tail_scan(self, lo: int, hi: int) -> list[bytes]:
        return []

    def _tail_locate(self, payload: bytes) -> int | None:
        """Tail-local id of the string whose encoded form is ``payload``.
        Call under ``self._lock``; the read-only base has no tail."""
        return None

    def _tail_prefix_hits(self, prefix: bytes,
                          after: tuple[bytes, int] | None
                          ) -> list[tuple[bytes, int]]:
        """Sorted ``(string, gid)`` tail matches of ``prefix`` past the
        ``after`` cursor. Call under ``self._lock``."""
        return []

    def _string_tokens(self, gid: int) -> np.ndarray:
        """u16 token IDs of global string ``gid`` (sealed or tail).
        Call under ``self._lock``."""
        sealed = self.segments.n_strings
        if gid < sealed:
            return self.segments.string_tokens(gid)
        return self._tail_string_tokens(gid - sealed)

    # ---------------------------------------------------------------- queries
    @property
    def n_sealed(self) -> int:
        """Strings living in sealed (immutable) segments."""
        return self.segments.n_strings

    @property
    def n_strings(self) -> int:
        return self.segments.n_strings + self._tail_n()

    def __len__(self) -> int:
        return self.n_strings

    @property
    def memory_bytes(self) -> int:
        """Resident footprint: compressed payload + offsets of every sealed
        segment (including segments sealed from an appended tail, which the
        construction-time corpus does not cover) + the full dictionary
        (decode matrix and LPM tables included) + decoded-string cache + any
        unsealed tail payload. Demoted (cold) segments do not count: their
        payload/offsets are ``np.memmap`` views over the ``cold-*.rlz``
        container, so the kernel can drop those pages under pressure."""
        cold = self.tier.cold if self.tier is not None else ()
        seg_bytes = sum(s.payload_bytes + s.offsets.nbytes
                        for s in self.segments.segments
                        if s.index not in cold)
        return (seg_bytes + self.dictionary.resident_bytes
                + self.cache.current_bytes + self._tail_payload_bytes())

    def get(self, i: int) -> bytes:
        """Point lookup of string ``i``."""
        return self.multiget([i])[0]

    def multiget(self, ids) -> list[bytes]:
        """Batched point lookup; duplicates decode once, order is preserved.

        Raises IndexError if any id is out of ``[0, n_strings)`` (before any
        decode work happens).
        """
        t0 = time.perf_counter()
        ids = [int(i) for i in ids]
        n = self.n_strings
        for i in ids:
            if not 0 <= i < n:
                raise IndexError(f"string id {i} out of range [0, {n})")
        with self._lock:
            if self.tier is not None:
                self.tier.note_reads_locked(ids)
            results: dict[int, bytes] = {}
            misses: list[int] = []
            for i in ids:  # unique-preserving cache probe: duplicates decode once
                if i in results:
                    continue
                hit = self.cache.get(i)
                if hit is not None:
                    results[i] = hit
                else:
                    results[i] = b""  # claimed; overwritten by decode below
                    misses.append(i)
            if misses:
                with TRACER.span("store.decode", batch=len(misses),
                                 backend=self.backend):
                    self._decode_misses(misses, results)
            out = [results[i] for i in ids]
        self.stats.record_multiget(len(ids), time.perf_counter() - t0)
        return out

    def scan(self, lo: int, hi: int) -> list[bytes]:
        """Decode the contiguous id range [lo, hi) segment by segment: each
        segment's covered slice is one token stream, decoded in a single
        vectorised pass and split on per-string byte boundaries. Ranges may
        extend past the sealed segments into the unsealed tail."""
        n = self.n_strings
        if not (0 <= lo <= hi <= n):
            raise IndexError(f"scan range [{lo}, {hi}) not within [0, {n}]")
        with self._lock:
            out = self._scan_locked(lo, hi)
            self.stats.scan_strings += hi - lo
        return out

    def _scan_locked(self, lo: int, hi: int) -> list[bytes]:
        out: list[bytes] = []
        for seg in self.segments.overlapping(lo, hi):
            s_lo = max(lo, seg.base_id)
            s_hi = min(hi, seg.base_id + seg.n_strings)
            if s_lo >= s_hi:
                continue
            l0, l1 = s_lo - seg.base_id, s_hi - seg.base_id
            if self.tier is not None and seg.index in self.tier.cold:
                out.extend(self.tier.decode_range_locked(seg.index, l0, l1))
                continue
            tokens = np.asarray(seg.tokens(l0, l1), dtype=np.int64)
            decoded = self.dictionary.decode_tokens(tokens)
            counts = seg.token_counts()[l0:l1]
            out.extend(self._split_decoded(decoded, tokens, counts))
        sealed = self.segments.n_strings
        if hi > sealed:
            out.extend(self._tail_scan(max(lo, sealed) - sealed, hi - sealed))
        return out

    # --------------------------------------------------- reverse lookup
    #: optimistic encode attempts before locate takes the store lock for
    #: the whole encode+probe (mirrors MutableStringStore.extend: a
    #: compact() swapping the dictionary between the query parse and the
    #: probe would compare encodings from different generations — byte
    #: verification would then give false misses, or even a false hit if
    #: two generations encode different strings to the same bytes)
    _MAX_LOCATE_RETRIES = 3

    def locate(self, s: bytes) -> int | None:
        """Exact-match reverse lookup: the id whose ``get`` returns ``s``.

        The query is encoded once against the store's dictionary and
        compared in *compressed* form — no decompression on the probe
        path. Duplicated strings resolve to their lowest id; absent
        strings return ``None``. Exact match only: see :meth:`scan_prefix`
        for prefix enumeration.
        """
        return self.locate_batch([s])[0]

    def locate_batch(self, strings) -> list[int | None]:
        """Batched :meth:`locate`; one encoder pass, order preserved."""
        strings = [bytes(s) for s in strings]
        if not strings:
            return []
        t0 = time.perf_counter()
        out = None
        for _ in range(self._MAX_LOCATE_RETRIES):
            version = getattr(self, "version_id", 0)
            payloads = self._encode_queries(strings)
            with self._lock:
                if getattr(self, "version_id", 0) == version:
                    out = [self._locate_payload_locked(p) for p in payloads]
                    break
            # compact() swapped generations mid-parse: re-encode and retry
        if out is None:
            # retries exhausted: encode under the store lock itself, where
            # no swap can interleave (same escape hatch as extend())
            with self._lock:
                corpus = self._query_encoder().encode(strings)
                out = [self._locate_payload_locked(corpus.string_payload(i))
                       for i in range(len(strings))]
        n_hits = sum(1 for r in out if r is not None)
        self.stats.record_locate(len(strings), n_hits,
                                 time.perf_counter() - t0)
        return out

    def scan_prefix(self, prefix: bytes, limit: int | None = 100,
                    after: tuple[bytes, int] | None = None
                    ) -> list[tuple[int, bytes]]:
        """Strings starting with ``prefix``: ``[(id, string), ...]`` in
        ``(string, id)`` order.

        Served from the per-segment sorted sidecars (binary search + one
        independent decode per probed entry) merged with a linear filter
        over the unsealed tail. ``after`` is an exclusive ``(string, id)``
        resume cursor for pagination; ``limit=None`` returns every match.
        Results reflect the dictionary generation at call time — a
        concurrent ``compact()`` does not change ids, but paginating
        across one may re-observe strings the swap re-filed.
        """
        prefix = bytes(prefix)
        with self._lock:
            runs: list[list[tuple[bytes, int]]] = []
            for seg in self.segments.segments:
                if seg.n_strings == 0:
                    continue
                idx = self._segment_index_locked(seg)
                base = seg.base_id
                seg_after = ((after[0], after[1] - base)
                             if after is not None else None)
                hits = idx.scan_prefix(
                    prefix, limit,
                    lambda loc, b=base: self._decode_one_locked(b + loc),
                    after=seg_after)
                if hits:
                    runs.append([(s, base + loc) for loc, s in hits])
            tail_hits = self._tail_prefix_hits(prefix, after)
            if tail_hits:
                runs.append(tail_hits)
            merged = heapq.merge(*runs)
            if limit is not None:
                merged = islice(merged, limit)
            out = [(gid, s) for s, gid in merged]
        self.stats.prefix_scans += 1
        self.stats.scan_strings += len(out)
        return out

    def _query_encoder(self) -> Encoder:
        """Encoder for query strings; shares the compressor's tables. The
        writable subclass returns its tail encoder instead (identical
        encodings by construction — same generation, same tables)."""
        if self._locate_encoder is None:
            self._locate_encoder = Encoder(self.artifact,
                                           codec=self.compressor)
        return self._locate_encoder

    def _encode_queries(self, strings: list[bytes]) -> list[bytes]:
        """Compressed form of each query, current dictionary generation."""
        corpus = self._query_encoder().encode(strings)
        buf = corpus.payload.tobytes()
        off = corpus.offsets
        return [buf[off[i]:off[i + 1]] for i in range(len(strings))]

    def _locate_payload_locked(self, payload: bytes) -> int | None:
        """Probe sealed segments in id order, then the tail; first
        byte-verified hit is the lowest global id."""
        for seg in self.segments.segments:
            if seg.n_strings == 0:
                continue
            idx = self._segment_index_locked(seg)
            loc = idx.locate(payload, seg.payload, seg.offsets)
            if loc is not None:
                return seg.base_id + loc
        loc = self._tail_locate(payload)
        if loc is not None:
            return self.segments.n_strings + loc
        return None

    def _segment_index_locked(self, seg) -> SegmentIndex:
        """The segment's reverse-lookup index, built on first use. The
        count re-check guards against segment-slot reuse (appending to an
        empty corpus replaces the placeholder segment in slot 0)."""
        idx = self._seg_indexes.get(seg.index)
        if idx is not None and idx.n == seg.n_strings:
            return idx
        raw = self._scan_locked(seg.base_id, seg.base_id + seg.n_strings)
        idx = SegmentIndex.build(seg.payload, seg.offsets, raw)
        self._seg_indexes[seg.index] = idx
        return idx

    def _decode_one_locked(self, gid: int) -> bytes:
        """One string through the LRU cache (scan_prefix probe path)."""
        hit = self.cache.get(gid)
        if hit is not None:
            return hit
        results = {gid: b""}
        self._decode_misses([gid], results)
        return results[gid]

    def _dump_index_locked(self) -> bytes | None:
        """Serialised sidecar of every up-to-date segment index, or None
        when nothing is built (lazy rebuild is cheaper than a forced
        decode of segments nobody has located in)."""
        live: dict[int, tuple[int, SegmentIndex]] = {}
        for seg in self.segments.segments:
            idx = self._seg_indexes.get(seg.index)
            if idx is not None and seg.n_strings and idx.n == seg.n_strings:
                live[seg.index] = (seg.base_id, idx)
        return dump_indexes(live) if live else None

    def _load_index(self, dir_path: str) -> None:
        """Adopt a persisted index sidecar if it matches the live
        segmentation (position + base id + count); mismatches are dropped
        per segment and rebuilt lazily."""
        path = os.path.join(dir_path, self._INDEX_FILE)
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            data = f.read()
        with self._lock:
            layout = {seg.index: (seg.base_id, seg.n_strings)
                      for seg in self.segments.segments if seg.n_strings}
            self._seg_indexes.update(load_indexes(data, layout))

    def stats_snapshot(self) -> dict:
        snap = self.stats.snapshot(self.cache.stats())
        snap.update(backend=self.backend, n_strings=self.n_strings,
                    n_sealed_strings=self.n_sealed,
                    n_tail_strings=self._tail_n(),
                    n_segments=self.segments.n_segments,
                    bucket_caps=[int(c) for c in self.bucket_caps],
                    memory_bytes=self.memory_bytes)
        if self.tier is not None:
            snap["tier"] = self.tier.snapshot()
        return snap

    # --------------------------------------------------------------- internals
    def _split_decoded(self, decoded: bytes, tokens: np.ndarray,
                       counts: np.ndarray) -> list[bytes]:
        """Split one decoded byte run back into per-string slices."""
        tok_lens = self.dictionary.lens[tokens].astype(np.int64)
        byte_cum = np.zeros(tokens.size + 1, dtype=np.int64)
        np.cumsum(tok_lens, out=byte_cum[1:])
        bounds = byte_cum[np.concatenate(([0], np.cumsum(counts)))]
        return [decoded[int(bounds[k]) : int(bounds[k + 1])]
                for k in range(len(counts))]

    def _decode_misses(self, misses: list[int], results: dict[int, bytes]) -> None:
        if self.tier is not None and self.tier.cold:
            hot, cold = self.tier.split_misses_locked(misses)
            if cold:
                self.tier.decode_misses_locked(cold, results)
                for pairs in cold.values():
                    for gid, _ in pairs:
                        self.cache.put(gid, results[gid])
                misses = hot
                if not misses:
                    return
        token_lists = [np.asarray(self._string_tokens(i), dtype=np.int32)
                       for i in misses]
        if self._device is not None:
            self._decode_jax(misses, token_lists, results)
        else:
            self._decode_numpy(misses, token_lists, results)
        for i in misses:
            self.cache.put(i, results[i])

    def _decode_jax(self, misses: list[int], token_lists: list[np.ndarray],
                    results: dict[int, bytes]) -> None:
        counts = np.asarray([t.size for t in token_lists], dtype=np.int64)
        if counts.size and int(counts.max()) > int(self.bucket_caps[-1]):
            # appended strings can exceed every build-time bucket: grow a new
            # top bucket instead of indexing past the table. Growth is
            # geometric (at least 2x the previous top) so steadily longer
            # appends mint O(log max_tokens) extra jit shapes, not one per
            # oversized batch.
            self.bucket_caps = np.append(
                self.bucket_caps,
                max(_ceil8(int(counts.max())), 2 * int(self.bucket_caps[-1])))
        buckets = np.searchsorted(self.bucket_caps, counts, side="left")
        for b in np.unique(buckets):
            cap = int(self.bucket_caps[int(b)])
            members = [k for k in range(len(misses)) if buckets[k] == b]
            for c0 in range(0, len(members), self.batch_size):
                chunk = members[c0 : c0 + self.batch_size]
                t0 = time.perf_counter()
                decoded = self._device.multiget_decode(
                    [token_lists[k] for k in chunk], pad_tokens=cap,
                    pad_batch=self.batch_size, use_pallas=self.use_pallas)
                dt = time.perf_counter() - t0
                for k, val in zip(chunk, decoded):
                    results[misses[k]] = val
                self.stats.record_decode_batch(
                    (self.batch_size, cap), len(chunk),
                    sum(len(v) for v in decoded), dt, jitted=True)

    def _decode_numpy(self, misses: list[int], token_lists: list[np.ndarray],
                      results: dict[int, bytes]) -> None:
        """Fallback: all misses concatenate into ONE token stream (strings are
        independent), decoded by the vectorised host path and re-split."""
        t0 = time.perf_counter()
        counts = np.asarray([t.size for t in token_lists], dtype=np.int64)
        tokens = (np.concatenate(token_lists).astype(np.int64)
                  if token_lists else np.zeros(0, dtype=np.int64))
        decoded = self.dictionary.decode_tokens(tokens)
        parts = self._split_decoded(decoded, tokens, counts)
        dt = time.perf_counter() - t0
        for i, val in zip(misses, parts):
            results[i] = val
        self.stats.record_decode_batch(
            (len(misses), int(counts.max()) if counts.size else 0),
            len(misses), len(decoded), dt, jitted=False)
