"""MutableStringStore — the write path of the serving subsystem.

OnPair compresses every string independently against a trained dictionary,
so *new* strings can be parsed against a **frozen** dictionary without any
retraining — the ingestion model of an in-memory database. The mutable
store layers that lifecycle over :class:`CompressedStringStore`:

* ``append``/``extend`` parse incoming strings with the saved-artifact
  :class:`~repro.core.codec.Encoder` into an open **tail** (a list of
  per-string token-stream payloads);
* once the tail reaches ``strings_per_segment`` strings it is **sealed**
  into the immutable :class:`~repro.store.segment.SegmentedCorpus` layout —
  reads (`get`/`multiget`/`scan`) answer consistently across sealed + tail
  data the whole time;
* a :class:`~repro.store.drift.DriftMonitor` watches the achieved ratio of
  appended data against the train-time ratio; when the distribution drifts,
  ``compact()`` re-trains a dictionary on the live data and rewrites every
  segment against it, swapping the store's state (and, when the store is
  backed by a directory, a new **versioned artifact directory** via the
  atomic-manifest pattern of ``write_json_atomic``).

On disk a mutable store is a *versioned* directory::

    <dir>/current.json     atomic manifest: {"current": "v0000", ...}
    <dir>/v0000/           one flat store layout per dictionary generation
        dictionary.rpa       (train-once artifact)
        corpus.rpc           (sealed segments + unsealed tail strings)
        store.json           (construction params + n_tail + drift state)
    <dir>/v0001/           written by compact(); manifest swap is atomic

``open()`` also accepts a plain read-only store directory (no manifest) so
any persisted :class:`CompressedStringStore` can be reopened writable.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np

from repro.core import registry
from repro.core.api import CompressedCorpus
from repro.core.artifact import DictArtifact
from repro.core.codec import Encoder
from repro.core.index import SegmentIndex
from repro.store.drift import DriftMonitor
from repro.store.segment import SegmentedCorpus
from repro.store.store import CompressedStringStore, write_json_atomic

try:
    if os.environ.get("REPRO_NO_JAX"):  # opt-out: numpy-only serving hosts
        raise ImportError("REPRO_NO_JAX is set")
    from repro.kernels.ops import OnPairDevice
except Exception:  # pragma: no cover - container without jax
    OnPairDevice = None


def _empty_corpus() -> CompressedCorpus:
    return CompressedCorpus(payload=np.zeros(0, dtype=np.uint8),
                            offsets=np.zeros(1, dtype=np.int64), raw_bytes=0)


def _corpus_payloads(corpus: CompressedCorpus) -> list[bytes]:
    """Per-string payload bytes via one buffer copy + slicing (cheaper than
    n ``string_payload`` calls, each of which materialises its own array)."""
    buf = corpus.payload.tobytes()
    off = corpus.offsets
    return [buf[off[i]:off[i + 1]] for i in range(corpus.n_strings)]


class MutableStringStore(CompressedStringStore):
    """Appendable store over a frozen dictionary, with drift-triggered
    compaction.

    ``corpus`` may be ``None`` to start an empty store that is populated
    purely by appends (the dictionary still comes from ``source`` — an
    artifact trained elsewhere, or a trained codec).
    """

    #: optimistic encode attempts before extend() takes the store lock for
    #: the whole encode+ingest; bounds the compact-race retry (a compact()
    #: swapping the dictionary between parse and ingest invalidates the batch)
    _MAX_ENCODE_RETRIES = 3

    def __init__(self, source, corpus: CompressedCorpus | None = None, *,
                 drift_threshold: float = 0.2, auto_compact: bool = False,
                 train_ratio: float | None = None,
                 encode_backend: str = "numpy",
                 async_seal: bool = True, **store_kw):
        # Refuse non-token-stream codecs up front with an append-specific
        # error: the tail files per-string u16 token payloads
        # (_tail_string_tokens does frombuffer("<u2")) and _tail_scan walks a
        # dictionary that raw/block codecs don't have — appends would
        # silently corrupt instead of failing here.
        self._check_token_stream(source)
        # tail state must exist before super().__init__ — the overridden
        # n_strings property can be consulted during construction
        self._tail: list[bytes] = []       # compressed payload per string
        self._tail_raw: list[int] = []     # decoded byte length per string
        self._tail_bytes = 0
        self._n_total = 0
        # reverse-lookup tail map: compressed payload -> lowest tail-local
        # id. None until the first tail locate builds it; _ingest_locked
        # then maintains it incrementally so the write path pays nothing
        # before anyone queries.
        self._tail_map: dict[bytes, int] | None = None
        if corpus is None:
            corpus = _empty_corpus()
        super().__init__(source, corpus, **store_kw)
        self._n_total = self.segments.n_strings
        if encode_backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown encode_backend {encode_backend!r} "
                             "(one of 'numpy', 'pallas')")
        if encode_backend == "pallas" and OnPairDevice is None:
            raise ValueError("encode_backend='pallas' unavailable: "
                             "jax not importable (or REPRO_NO_JAX set)")
        self.encode_backend = encode_backend
        # frozen-dict parser; shares the compressor's already-built tables
        # (numpy) or the store's device tables (pallas, AOT-warmed here so
        # the first extend() pays no compile)
        self._encoder = self._make_encoder(self.artifact, self.compressor,
                                           self._device)
        self._encode_lock = threading.Lock()     # serialises lazy LPM rebuild
        self._io_lock = threading.RLock()        # serialises save/swap/prune
        self._dirty = False                      # unsaved appends/compacts
        base = train_ratio if train_ratio is not None else (
            corpus.ratio if corpus.compressed_bytes else None)
        self.drift = DriftMonitor(threshold=drift_threshold,
                                  baseline_ratio=base)
        self.auto_compact = auto_compact
        self.version_id = 0          # bumped by every compact()
        self.compactions = 0
        self._dir: str | None = None  # set by save()/open(): compact() target
        # ----- off-thread tail seals: a sealing extend() only *requests* a
        # seal; segment construction (join + cumsum + optional index decode)
        # runs on a background worker that commits under the lock iff the
        # tail identity it snapshotted is still current (_tail_gen guard).
        self.async_seal = bool(async_seal)
        self._sealing = False                    # worker thread active
        self._tail_gen = 0                       # bumped when the tail's
        #                                          prefix is invalidated
        self._seal_done_cv = threading.Condition(self._lock)

    @staticmethod
    def _check_token_stream(source) -> None:
        name = getattr(source, "codec", None)          # DictArtifact
        if name is None:
            obj = source[1] if isinstance(source, tuple) else source
            name = getattr(obj, "name", None)          # trained codec
        if name is None:
            return  # malformed source: super().__init__ gives the right error
        try:
            caps = registry.capabilities(name)
        except Exception:
            return  # unknown codec: super().__init__ gives the right error
        if not caps.token_stream:
            raise ValueError(
                f"MutableStringStore requires a token-stream codec: appends "
                f"file per-string u16 token payloads into the tail, but "
                f"{name!r} is not token_stream (registry capability); "
                "use a read-only CompressedStringStore for block codecs")

    def _make_encoder(self, artifact, compressor, device) -> Encoder:
        """Build (and AOT-warm) the tail encoder for the current generation.

        On the pallas backend the encoder shares the store's decode device
        when there is one (store backend jax); a numpy-store/pallas-encode
        mix builds a device from the already-packed dictionary. compact()
        calls this outside the lock so warm-up never blocks readers.
        """
        if self.encode_backend == "pallas":
            if device is None:
                device = OnPairDevice(compressor.dictionary)
            enc = Encoder(artifact, backend="pallas", codec=compressor,
                          device=device)
            enc.warm()
            return enc
        return Encoder(artifact, codec=compressor)

    # -------------------------------------------------------------- tail hooks
    def _tail_n(self) -> int:
        return len(self._tail)

    def _tail_payload_bytes(self) -> int:
        return self._tail_bytes

    def _tail_string_tokens(self, local: int) -> np.ndarray:
        return np.frombuffer(self._tail[local], dtype="<u2")

    def _tail_scan(self, lo: int, hi: int) -> list[bytes]:
        if lo >= hi:
            return []
        parts = self._tail[lo:hi]
        counts = np.asarray([len(p) // 2 for p in parts], dtype=np.int64)
        tokens = np.frombuffer(b"".join(parts), dtype="<u2").astype(np.int64)
        decoded = self.dictionary.decode_tokens(tokens)
        return self._split_decoded(decoded, tokens, counts)

    def _tail_locate(self, payload: bytes) -> int | None:
        if self._tail_map is None:
            # first tail locate: build the map once; ingest maintains it
            # from here on
            m: dict[bytes, int] = {}
            for local, p in enumerate(self._tail):
                m.setdefault(p, local)
            self._tail_map = m
        return self._tail_map.get(payload)

    def _tail_prefix_hits(self, prefix, after):
        n = len(self._tail)
        if n == 0:
            return []
        sealed = self.segments.n_strings
        hits = []
        for local, s in enumerate(self._tail_scan(0, n)):
            if not s.startswith(prefix):
                continue
            gid = sealed + local
            if after is not None and (s, gid) <= after:
                continue
            hits.append((s, gid))
        hits.sort()
        return hits

    @property
    def n_strings(self) -> int:
        # a plain int read: monotonic for unlocked readers even while a seal
        # is moving strings from the tail into a new segment under the lock
        return self._n_total

    # ------------------------------------------------------ reverse lookup
    def _query_encoder(self) -> Encoder:
        # queries must parse against the exact generation the tail was
        # encoded with — share the tail encoder instead of building one
        return self._encoder

    def _encode_queries(self, strings: list[bytes]) -> list[bytes]:
        # serialise against extend()'s lazy LPM rebuild, exactly like the
        # optimistic encode pass of extend() itself
        with self._encode_lock:
            return super()._encode_queries(strings)

    # ----------------------------------------------------------------- writes
    def append(self, s: bytes) -> int:
        """Parse one string against the frozen dictionary and append it.
        Returns the new string's global id (ids are assigned contiguously)."""
        return self.extend([s])[0]

    def extend(self, strings: list[bytes]) -> list[int]:
        """Batched append: one Encoder pass, then one locked tail update."""
        strings = [bytes(s) for s in strings]
        if not strings:
            return []
        raw_lens = [len(s) for s in strings]
        ids = None
        for _ in range(self._MAX_ENCODE_RETRIES):
            with self._encode_lock:
                version = self.version_id
                encoder = self._encoder
                corpus = encoder.encode(strings)
            payloads = _corpus_payloads(corpus)
            with self._lock:
                if version == self.version_id:
                    ids = self._ingest_locked(payloads, raw_lens)
                    break
            # a compact() swapped the dictionary while we were parsing: the
            # payloads reference the OLD token table — re-parse and retry
        if ids is None:
            # retries exhausted (back-to-back auto_compact swaps): encode
            # under the store lock itself. compact()'s swap needs this lock
            # too, so the dictionary cannot change mid-parse — readers stall
            # for one batch parse, but livelock is impossible.
            with self._lock:
                corpus = self._encoder.encode(strings)
                ids = self._ingest_locked(_corpus_payloads(corpus), raw_lens)
        if self.auto_compact and self.drift.should_compact():
            self.compact()
        return ids

    def seal(self) -> None:
        """Force-seal the current tail into a (possibly short) segment.
        Joins any in-flight background seal first, then seals the remainder
        inline — on return the tail is empty."""
        with self._seal_done_cv:
            while self._sealing:
                self._seal_done_cv.wait()
            self._seal_tail_locked()

    def seal_barrier(self) -> None:
        """Block until no background seal is pending: afterwards the tail
        is strictly shorter than ``strings_per_segment`` (until the next
        sealing extend). compact() and save() call this so their snapshots
        never race a half-built segment."""
        with self._seal_done_cv:
            while self._sealing:
                self._seal_done_cv.wait()

    def _ingest_locked(self, payloads: list[bytes], raw_lens: list[int],
                       assign_ids: bool = True) -> list[int]:
        """``assign_ids=False`` re-files payloads whose ids are already
        published (compact's delta re-parse) without touching ``_n_total``.

        Group-commit: the whole batch appends to the tail with one drift
        observation (DriftMonitor explicitly accepts per-batch observation)
        — no per-string Python loop on the hot write path. Crossing a seal
        boundary only *requests* sealing: the background worker builds the
        segment off-thread (``async_seal=False`` restores inline seals).
        """
        self._dirty = True
        n = len(payloads)
        ids = list(range(self._n_total, self._n_total + n)) if assign_ids else []
        if self._tail_map is not None:
            start = len(self._tail)
            for j, p in enumerate(payloads):
                self._tail_map.setdefault(p, start + j)
        self._tail.extend(payloads)
        self._tail_raw.extend(raw_lens)
        comp = sum(map(len, payloads))
        self._tail_bytes += comp
        self.drift.observe(sum(raw_lens), comp)
        if assign_ids:
            self._n_total += n
        spc = self.segments.strings_per_segment
        if len(self._tail) >= spc:
            if self.async_seal:
                self._request_seal_locked()
            else:
                while len(self._tail) >= spc:
                    self._seal_tail_locked(spc)
        return ids

    def _seal_tail_locked(self, k: int | None = None) -> None:
        """Seal the first ``k`` tail strings (all of them when None) into a
        segment, inline under the lock."""
        n = len(self._tail)
        k = n if k is None else min(k, n)
        if k == 0:
            return
        parts = self._tail[:k]
        offsets = np.zeros(k + 1, dtype=np.int64)
        np.cumsum([len(p) for p in parts], out=offsets[1:])
        payload = np.frombuffer(b"".join(parts), dtype=np.uint8)
        # once anyone has issued a reverse lookup, keep the index current:
        # build the new segment's index at seal time (tail decoded before
        # it is cleared). Stores nobody locates in never pay this decode.
        raw = (self._tail_scan(0, k)
               if (self._seg_indexes or self._tail_map is not None)
               else None)
        self._commit_seal_locked(k, payload, offsets,
                                 sum(self._tail_raw[:k]), raw)

    def _commit_seal_locked(self, k: int, payload: np.ndarray,
                            offsets: np.ndarray, raw_bytes: int,
                            raw: list[bytes] | None) -> None:
        """Append the built segment and drop the first ``k`` tail strings.
        Bumps ``_tail_gen``: any other in-flight seal snapshot of the old
        tail prefix is now stale and must abandon its commit."""
        self.segments.append_segment(payload, offsets, raw_bytes=raw_bytes)
        if raw is not None:
            seg = self.segments.segments[-1]
            self._seg_indexes[seg.index] = SegmentIndex.build(
                seg.payload, seg.offsets, raw)
        del self._tail[:k]
        del self._tail_raw[:k]
        self._tail_bytes -= int(offsets[-1])
        if self._tail_map is not None:
            # a partial seal shifts every remaining tail-local id
            m: dict[bytes, int] = {}
            for local, p in enumerate(self._tail):
                m.setdefault(p, local)
            self._tail_map = m
        self._tail_gen += 1

    def _request_seal_locked(self) -> None:
        if self._sealing:
            return  # worker already draining; it re-checks the boundary
        self._sealing = True
        threading.Thread(target=self._seal_worker, daemon=True,
                         name="repro-seal").start()

    def _seal_worker(self) -> None:
        """Drain the tail down below the seal boundary, one segment per
        iteration. Each round snapshots the first ``spc`` payloads under
        the lock, builds the segment arrays (and the optional reverse-index
        decode) OFF the lock, and commits only if neither a compaction
        (version_id) nor a competing seal/swap (_tail_gen) invalidated the
        snapshot meanwhile."""
        while True:
            with self._lock:
                spc = self.segments.strings_per_segment
                if len(self._tail) < spc:
                    self._sealing = False
                    self._seal_done_cv.notify_all()
                    return
                version, gen = self.version_id, self._tail_gen
                parts = self._tail[:spc]
                raw_bytes = sum(self._tail_raw[:spc])
                need_raw = bool(self._seg_indexes) \
                    or self._tail_map is not None
                dictionary = self.dictionary
            offsets = np.zeros(spc + 1, dtype=np.int64)
            np.cumsum([len(p) for p in parts], out=offsets[1:])
            payload = np.frombuffer(b"".join(parts), dtype=np.uint8)
            raw = (self._decode_payloads(parts, dictionary)
                   if need_raw else None)
            with self._lock:
                if self.version_id != version or self._tail_gen != gen:
                    continue  # snapshot went stale: re-evaluate from scratch
                self._commit_seal_locked(spc, payload, offsets,
                                         raw_bytes, raw)

    @staticmethod
    def _decode_payloads(parts: list[bytes], dictionary) -> list[bytes]:
        """Decode token-stream payloads against a *captured* dictionary
        (the seal worker must not read self.dictionary off-lock)."""
        counts = np.asarray([len(p) // 2 for p in parts], dtype=np.int64)
        tokens = np.frombuffer(b"".join(parts), dtype="<u2").astype(np.int64)
        decoded = dictionary.decode_tokens(tokens)
        tok_lens = dictionary.lens[tokens].astype(np.int64)
        byte_cum = np.zeros(tokens.size + 1, dtype=np.int64)
        np.cumsum(tok_lens, out=byte_cum[1:])
        bounds = byte_cum[np.concatenate(([0], np.cumsum(counts)))]
        return [decoded[int(bounds[i]):int(bounds[i + 1])]
                for i in range(len(counts))]

    # ------------------------------------------------------------- compaction
    def compact(self, *, sample_strings: int | None = None,
                dir_path: str | None = None, prune_old: bool = True) -> dict:
        """Re-train the dictionary on (a sample of) the live data, re-encode
        every live string, and atomically swap the store's state.

        Training and bulk re-encoding run *outside* the store lock — reads
        and appends keep being served from the old state; strings appended
        meanwhile are re-parsed against the new dictionary during the final
        locked swap. When the store is directory-backed (``save``/``open``),
        the rewrite lands in a new ``v{n+1}`` subdirectory and the
        ``current.json`` manifest is swapped atomically; stale version
        directories are pruned afterwards (``prune_old=False`` keeps them).
        """
        t0 = time.perf_counter()
        self.seal_barrier()  # never snapshot a half-built background segment
        n0 = self.n_strings
        # decode the live data in per-segment lock windows — ids < n0 are
        # immutable, so chunked reads see the same bytes as one big scan
        # while concurrent reads/appends keep interleaving
        live: list[bytes] = []
        chunk = max(1, self.segments.strings_per_segment)
        for lo in range(0, n0, chunk):
            with self._lock:
                live.extend(self._scan_locked(lo, min(lo + chunk, n0)))
        if not live:
            return {"n_strings": 0, "ratio_before": 0.0, "ratio_after": 0.0,
                    "train_s": 0.0, "total_s": 0.0,
                    "version": self._version_name(), "dir": self._dir}
        raw = sum(len(s) for s in live)
        with self._lock:
            compressed_before = (self.segments.payload_bytes
                                 + self._tail_bytes)
        ratio_before = raw / max(1, compressed_before)

        # re-train on a sample of live data (the codec's own sample_bytes
        # cap still applies inside train())
        sample = live
        if sample_strings is not None and sample_strings < len(live):
            step = max(1, len(live) // sample_strings)
            sample = live[::step][:sample_strings]
        new_comp = registry.codec_from_artifact(self.artifact)
        t_train0 = time.perf_counter()
        new_comp.train(sample)
        train_s = time.perf_counter() - t_train0
        new_corpus = new_comp.compress(live)
        # artifact freeze and device-table upload both happen OUTSIDE the
        # lock — the locked swap only assigns
        new_artifact = new_comp.to_artifact()
        new_device = (OnPairDevice(new_comp.dictionary)
                      if self.backend == "jax" else None)
        # tail encoder for the new generation — built (and, on the pallas
        # backend, AOT-warmed) outside the lock like the device tables
        new_encoder = self._make_encoder(new_artifact, new_comp, new_device)

        with self._lock:
            # strings appended while we were retraining: decode them from
            # the old state, then re-parse against the new dictionary. Their
            # ids are already published, so _n_total never moves — lock-free
            # n_strings readers stay monotonic through the whole swap
            delta = self._scan_locked(n0, self._n_total)
            self._swap_state_locked(new_comp, new_corpus, new_artifact,
                                    new_device, new_encoder)
            if delta:
                d_corpus = new_comp.compress(delta)
                self._ingest_locked(
                    [d_corpus.string_payload(i) for i in range(len(delta))],
                    [len(s) for s in delta], assign_ids=False)
            compressed_after = self.segments.payload_bytes + self._tail_bytes
        self.compactions += 1

        target = dir_path or self._dir
        old_version = f"v{self.version_id - 1:04d}"
        if target is not None:
            # one holder writes the directory at a time: a concurrent save()
            # must not recreate (or point the manifest at) the generation
            # this prune is deleting
            with self._io_lock:
                self.save(target)  # writes v{id}/ then swaps current.json
                if prune_old:
                    shutil.rmtree(os.path.join(target, old_version),
                                  ignore_errors=True)
        raw_total = raw + sum(len(s) for s in delta)
        return {"n_strings": self.n_strings,
                "ratio_before": round(ratio_before, 4),
                "ratio_after": round(raw_total / max(1, compressed_after), 4),
                "train_s": round(train_s, 4),
                "total_s": round(time.perf_counter() - t0, 4),
                "version": f"v{self.version_id:04d}",
                "dir": target}

    def _swap_state_locked(self, compressor, corpus: CompressedCorpus,
                           artifact: DictArtifact | None = None,
                           device=None, encoder: Encoder | None = None) -> None:
        """Replace dictionary + corpus + segments in one locked step. Decoded
        values are unchanged byte-for-byte, but cached entries belong to the
        rewritten segments' old token streams — drop them all. Pass the
        pre-frozen ``artifact`` so the token table is not re-serialized
        while every reader and writer is blocked on the lock."""
        self.compressor = compressor
        self._artifact = artifact           # re-frozen lazily when None
        self.dictionary = compressor.dictionary
        self.corpus = corpus
        self.segments = SegmentedCorpus.from_corpus(
            corpus, self.segments.strings_per_segment)
        self._set_bucket_caps(corpus.token_counts())
        if self.backend == "jax":
            self._device = (device if device is not None
                            else OnPairDevice(self.dictionary))
        self._encoder = (encoder if encoder is not None else
                         self._make_encoder(self.artifact, self.compressor,
                                            self._device))
        self._dirty = True
        self._tail = []
        self._tail_raw = []
        self._tail_bytes = 0
        # reverse-lookup state is generation-scoped: fingerprints index the
        # *encoded* forms, which the rewrite just changed wholesale
        self._seg_indexes = {}
        self._tail_map = None
        self._locate_encoder = None
        # _n_total is deliberately NOT reset: acknowledged ids must never
        # un-publish, and the caller re-files any delta beyond the corpus
        self.cache.clear()
        self.drift.reset(corpus.ratio if corpus.compressed_bytes else None)
        if self.tier is not None:
            # cold state is segment-scoped: the rewrite folded every cold
            # segment's data back into the new (hot) generation
            self.tier.clear_locked()
        self._tail_gen += 1   # in-flight seal snapshots are now stale
        self.version_id += 1

    # ------------------------------------------------------------- persistence
    def _version_name(self) -> str:
        return f"v{self.version_id:04d}"

    def snapshot_corpus(self) -> CompressedCorpus:
        with self._lock:
            return self._to_corpus_locked()

    def _to_corpus_locked(self) -> CompressedCorpus:
        """One flat CompressedCorpus over sealed segments + unsealed tail."""
        parts = [s.payload for s in self.segments.segments]
        parts += [np.frombuffer(p, dtype=np.uint8) for p in self._tail]
        payload = (np.concatenate(parts) if parts
                   else np.zeros(0, dtype=np.uint8))
        offs = [np.zeros(1, dtype=np.int64)]
        base = 0
        for seg in self.segments.segments:
            if seg.n_strings:
                offs.append(seg.offsets[1:] + base)
            base += seg.payload_bytes
        for p in self._tail:
            base += len(p)
            offs.append(np.asarray([base], dtype=np.int64))
        raw = self.segments.raw_bytes + sum(self._tail_raw)
        return CompressedCorpus(payload=payload,
                                offsets=np.concatenate(offs),
                                raw_bytes=int(raw),
                                meta={"compressor": self.compressor.name})

    def save(self, dir_path: str) -> None:
        """Write the current dictionary generation as ``<dir>/v{id}/`` (flat
        store layout, tail included in the corpus) and atomically point the
        ``current.json`` manifest at it.

        Dictionary, corpus, version name and meta are all snapshotted in ONE
        locked section — a compact() landing mid-save must never pair the
        new dictionary with the old generation's corpus on disk — and the
        whole snapshot+write sequence holds the IO lock, so it serialises
        against compact()'s own save+prune (a stale generation is never
        recreated after its prune, and the manifest never points backwards).
        """
        self.seal_barrier()  # the snapshot below must see a settled tail
        with self._io_lock:
            self._save_io_locked(dir_path)

    def _save_io_locked(self, dir_path: str) -> None:
        with self._lock:
            vname = self._version_name()
            artifact = self.artifact
            corpus = self._to_corpus_locked()
            meta = self.store_meta(
                mutable=True, n_tail=len(self._tail),
                version_id=self.version_id,
                encode_backend=self.encode_backend,
                async_seal=self.async_seal,
                train_ratio=self.drift.baseline_ratio,
                drift_raw_bytes=self.drift.raw_bytes,
                drift_compressed_bytes=self.drift.compressed_bytes,
                drift_observations=self.drift.observations,
                drift_threshold=self.drift.threshold,
                **self._tier_meta_locked())
            manifest = {"format_version": 1, "current": vname,
                        "codec": artifact.codec, "n_strings": self.n_strings,
                        "compactions": self.compactions}
            # captured in the same locked snapshot as the corpus: the
            # sidecar on disk must describe exactly the segments it sits
            # next to
            index_blob = self._dump_index_locked()
            # cleared HERE, inside the snapshot's locked section: an append
            # landing while the files below are written re-marks the store
            # dirty and is not covered by this snapshot
            self._dirty = False
        sub = os.path.join(dir_path, vname)
        os.makedirs(sub, exist_ok=True)
        artifact.save(os.path.join(sub, self._DICT_FILE))
        corpus.save(os.path.join(sub, self._CORPUS_FILE))
        write_json_atomic(os.path.join(sub, self._META_FILE), meta)
        if index_blob is not None:
            with open(os.path.join(sub, self._INDEX_FILE), "wb") as f:
                f.write(index_blob)
        if meta.get("cold_segments"):
            # the cold containers are immutable once written, so copying
            # them after the snapshot's lock dropped cannot tear
            self.tier.copy_cold_files(meta["cold_segments"], sub)
        write_json_atomic(os.path.join(dir_path, self._CURRENT_FILE),
                          manifest)
        # when upgrading a plain (flat) store directory to the versioned
        # layout, drop the superseded flat files: a reader must never find
        # two generations disagreeing in one directory
        stale_names = [self._DICT_FILE, self._CORPUS_FILE, self._META_FILE,
                       self._INDEX_FILE]
        stale_names += [n for n in os.listdir(dir_path)
                        if n.startswith("cold-") and n.endswith(".rlz")]
        for name in stale_names:
            stale = os.path.join(dir_path, name)
            if os.path.exists(stale):
                os.remove(stale)
        self._dir = dir_path

    @classmethod
    def open(cls, dir_path: str, mmap: bool = True,
             **overrides) -> "MutableStringStore":
        """Reopen a mutable store: versioned layout (``current.json``) or a
        plain read-only store directory. An unsealed tail saved with the
        corpus is split back out so appends keep sealing on the same
        boundaries."""
        sub = cls._resolve_current(dir_path)
        with open(os.path.join(sub, cls._META_FILE)) as f:
            meta = json.load(f)
        artifact = DictArtifact.load(os.path.join(sub, cls._DICT_FILE),
                                     mmap=mmap)
        corpus = CompressedCorpus.load(os.path.join(sub, cls._CORPUS_FILE),
                                       mmap=mmap)
        n, n_tail = corpus.n_strings, int(meta.get("n_tail", 0))
        sealed = corpus.slice_strings(0, n - n_tail) if n_tail else corpus
        kw = {k: meta[k] for k in cls._STORE_KW}
        kw["train_ratio"] = meta.get("train_ratio")
        kw["drift_threshold"] = meta.get("drift_threshold", 0.2)
        # saved on a jax host, reopened on a numpy-only one: fall back
        eb = meta.get("encode_backend", "numpy")
        kw["encode_backend"] = eb if OnPairDevice is not None else "numpy"
        kw["async_seal"] = meta.get("async_seal", True)
        kw.update(overrides)  # caller overrides beat every saved param
        store = cls(artifact, sealed, **kw)
        if n_tail:
            lens = store.dictionary.lens
            payloads, raws = [], []
            for i in range(n - n_tail, n):
                toks = np.asarray(corpus.string_tokens(i), dtype=np.int64)
                payloads.append(corpus.string_payload(i))
                raws.append(int(lens[toks].astype(np.int64).sum()))
            with store._lock:
                store._ingest_locked(payloads, raws)
        # restore the drift window exactly as saved (the tail re-ingest above
        # re-observed only the tail; overwrite with the persisted counters)
        if "drift_raw_bytes" in meta:
            store.drift.raw_bytes = int(meta["drift_raw_bytes"])
            store.drift.compressed_bytes = int(meta["drift_compressed_bytes"])
            store.drift.observations = int(meta["drift_observations"])
        store.version_id = int(meta.get("version_id", 0))
        store._load_index(sub)
        store._attach_tier(sub, meta)
        store._dir = dir_path
        store._dirty = False   # tail restore above is not an unsaved append
        return store

    # ------------------------------------------------------------------ stats
    def stats_snapshot(self) -> dict:
        snap = super().stats_snapshot()
        snap.update(drift=self.drift.snapshot(), compactions=self.compactions,
                    version=self._version_name())
        return snap
