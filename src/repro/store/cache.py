"""Byte-budgeted LRU cache for decoded strings, with hit/miss accounting.

Point-lookup traffic against a compressed store is typically heavily skewed
(Zipfian ids); caching decoded strings turns the common case into a dict hit
and leaves the Pallas batch decoder serving the miss tail. Capacity is in
*decoded payload bytes* so the resident budget is explicit next to the
compressed corpus's own footprint.
"""

from __future__ import annotations


class LRUCache:
    """LRU over ``int id -> bytes`` with a decoded-bytes capacity budget.

    ``capacity_bytes=0`` disables caching (every get misses, puts drop) —
    used by benchmarks to measure the pure decode path.
    """

    def __init__(self, capacity_bytes: int = 8 << 20):
        self.capacity_bytes = int(capacity_bytes)
        self._data: dict[int, bytes] = {}  # dict preserves insertion = LRU order
        self.current_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: int) -> bool:
        return key in self._data

    _MISSING = object()  # sentinel: b"" is a valid cached value

    def get(self, key: int) -> bytes | None:
        val = self._data.pop(key, self._MISSING)
        if val is self._MISSING:
            self.misses += 1
            return None
        self._data[key] = val  # reinsert = move to most-recent position
        self.hits += 1
        return val

    def put(self, key: int, value: bytes) -> None:
        if self.capacity_bytes <= 0:
            return
        if len(value) > self.capacity_bytes:
            # never admit an entry the budget can't hold: it would evict the
            # whole cache and then pin current_bytes over capacity forever
            return
        old = self._data.pop(key, None)
        if old is not None:
            self.current_bytes -= len(old)
        self._data[key] = value
        self.current_bytes += len(value)
        while self.current_bytes > self.capacity_bytes and len(self._data) > 1:
            old_key = next(iter(self._data))
            self.current_bytes -= len(self._data.pop(old_key))
            self.evictions += 1

    def clear(self) -> None:
        self._data.clear()
        self.current_bytes = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"entries": len(self._data), "bytes": self.current_bytes,
                "capacity_bytes": self.capacity_bytes, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "hit_rate": round(self.hit_rate, 4)}
