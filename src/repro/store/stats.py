"""Per-store serving counters: lookups, batches, bytes, latency percentiles.

Latency/percentile math lives in ``repro.core.metrics`` (latency_summary /
throughput_mib_s) so the store, the service layer, and the benchmark harness
all report identical definitions of p50/p99 and MiB/s.
"""

from __future__ import annotations

import time

from repro.core.metrics import throughput_mib_s
from repro.obs import REGISTRY, Counter, Histogram


class StoreStats:
    """Mutable counters updated by the store's hot path."""

    def __init__(self, backend: str = "unknown") -> None:
        self.started_at = time.perf_counter()
        self.lookups = 0            # ids requested (incl. duplicates/cached)
        self.decoded_strings = 0    # strings actually decoded (cache misses)
        self.decoded_bytes = 0
        self.batches = 0            # kernel/numpy decode invocations
        self.padded_rows = 0        # batch rows incl. padding (waste metric)
        self.decode_seconds = 0.0
        self.scan_strings = 0
        self.cold_lookups = 0       # misses decoded from the RLZ cold tier
        self.locates = 0            # reverse lookups (queries, incl. misses)
        self.locate_hits = 0        # reverse lookups that found an id
        self.prefix_scans = 0       # scan_prefix calls
        self.jit_shapes: set[tuple[int, int]] = set()  # (B, T) decode shapes
        # per-store instruments (snapshot() stays instance-scoped) registered
        # into the process registry, labelled by the resolved decode backend
        labels = {"backend": backend}
        self._lat = REGISTRY.register(Histogram(
            "repro_store_multiget_latency_us", labels=labels))
        self._lookups_total = REGISTRY.register(Counter(
            "repro_store_lookups_total", labels=labels))
        self._locate_lat = REGISTRY.register(Histogram(
            "repro_store_locate_latency_us", labels=labels))

    # ------------------------------------------------------------- recording
    def record_multiget(self, n_ids: int, seconds: float) -> None:
        self.lookups += n_ids
        self._lookups_total.inc(n_ids)
        self._lat.record_seconds(seconds)

    def record_locate(self, n_queries: int, n_hits: int,
                      seconds: float) -> None:
        self.locates += n_queries
        self.locate_hits += n_hits
        self._locate_lat.record_seconds(seconds)

    def record_decode_batch(self, shape: tuple[int, int], n_real: int,
                            nbytes: int, seconds: float,
                            jitted: bool) -> None:
        self.batches += 1
        self.padded_rows += shape[0]
        self.decoded_strings += n_real
        self.decoded_bytes += nbytes
        self.decode_seconds += seconds
        if jitted:
            self.jit_shapes.add(shape)

    # ------------------------------------------------------------- reporting
    def snapshot(self, cache_stats: dict | None = None) -> dict:
        elapsed = time.perf_counter() - self.started_at
        lat = self._lat.summary()
        return {
            "lookups": self.lookups,
            "decoded_strings": self.decoded_strings,
            "decoded_bytes": self.decoded_bytes,
            "scan_strings": self.scan_strings,
            "cold_lookups": self.cold_lookups,
            "locates": self.locates,
            "locate_hits": self.locate_hits,
            "prefix_scans": self.prefix_scans,
            "batches": self.batches,
            "padded_rows": self.padded_rows,
            "pad_efficiency": round(
                self.decoded_strings / self.padded_rows, 4
            ) if self.padded_rows else 1.0,
            "jit_shapes": sorted(self.jit_shapes),
            "decode_mib_s": round(
                throughput_mib_s(self.decoded_bytes, self.decode_seconds), 2
            ) if self.decode_seconds else 0.0,
            "lookups_per_s": round(self.lookups / elapsed, 1) if elapsed else 0.0,
            "multiget_latency": lat,
            "multiget_latency_hist": self._lat.state(),
            "cache": cache_stats or {},
        }
