"""Compression-ratio drift monitoring for writable stores.

A frozen dictionary keeps compressing incoming strings well only while they
look like the data it was trained on (the relative-LZ web-collection result:
a fixed reference dictionary works on new crawls until the distribution
drifts). :class:`DriftMonitor` watches the achieved ratio of post-train
appends against the ratio at train time and answers one question —
``should_compact()`` — which the writable store turns into a re-train +
segment rewrite (:meth:`repro.store.mutable.MutableStringStore.compact`).

Drift is the *fractional degradation* of the ratio::

    drift = max(0, 1 - observed_ratio / baseline_ratio)

so ``threshold=0.2`` means "compact when appended data compresses 20% worse
than the training-time corpus did". A minimum observed-bytes floor keeps a
handful of unlucky strings from triggering a full rewrite.
"""

from __future__ import annotations

from time import perf_counter as _perf_counter

import numpy as np


class DriftMonitor:
    """Achieved-vs-train-time compression ratio tracker.

    ``observe(raw, compressed)`` is called once per appended string (or
    batch); observations accumulate until :meth:`reset` — i.e. they cover
    everything parsed against the *current* dictionary since the last
    (re)train. When no train-time ratio is known (a store that started
    empty), the first ``min_bytes`` of observations seed the baseline.
    """

    def __init__(self, threshold: float = 0.2,
                 baseline_ratio: float | None = None,
                 min_bytes: int = 1 << 14,
                 read_halflife_s: float = 30.0):
        if not 0.0 < threshold < 1.0:
            raise ValueError(f"threshold must be in (0, 1), got {threshold}")
        self.threshold = float(threshold)
        self.baseline_ratio = baseline_ratio
        self.min_bytes = int(min_bytes)
        self.raw_bytes = 0
        self.compressed_bytes = 0
        self.observations = 0
        # per-segment read-rate EWMA (the tiering temperature signal):
        # segment index -> [decayed read count, last update perf_counter]
        self.read_halflife_s = float(read_halflife_s)
        self._read_ewma: dict[int, list[float]] = {}

    # -------------------------------------------------------------- recording
    def observe(self, raw_bytes: int, compressed_bytes: int) -> None:
        self.raw_bytes += int(raw_bytes)
        self.compressed_bytes += int(compressed_bytes)
        self.observations += 1
        if self.baseline_ratio is None and self.raw_bytes >= self.min_bytes:
            # no train-time ratio was known (store started empty): the first
            # min_bytes of appends seed the baseline, so later distribution
            # shifts still trip should_compact()
            self.baseline_ratio = self.observed_ratio
            self.raw_bytes = 0
            self.compressed_bytes = 0
            self.observations = 0

    def reset(self, baseline_ratio: float | None = None) -> None:
        """Start a fresh observation window (after a compaction). The
        read-rate EWMA resets too: segment indexes belong to the rewritten
        generation."""
        self.baseline_ratio = baseline_ratio
        self.raw_bytes = 0
        self.compressed_bytes = 0
        self.observations = 0
        self._read_ewma.clear()

    # ---------------------------------------------------- read-rate EWMA
    # Exponentially-decayed per-segment read counts: the decayed count C
    # halves every ``read_halflife_s`` idle seconds, and the steady-state
    # rate it converges to is ``C * ln2 / halflife`` reads/s — tiering's
    # temperature signal (repro.store.tier), a first-class measure instead
    # of raw lookup counters.
    _LN2 = 0.6931471805599453

    def note_reads(self, counts: dict[int, int],
                   now: float | None = None) -> None:
        """Fold ``{segment_index: reads}`` from one batched lookup into the
        per-segment EWMA. ``now`` is a ``time.perf_counter()`` timestamp
        (injectable so tests can steer the clock)."""
        if now is None:
            now = _perf_counter()
        for seg, c in counts.items():
            ent = self._read_ewma.get(seg)
            if ent is None:
                self._read_ewma[seg] = [float(c), now]
            else:
                dt = max(0.0, now - ent[1])
                ent[0] = ent[0] * 0.5 ** (dt / self.read_halflife_s) + c
                ent[1] = now

    def read_rate(self, seg: int, now: float | None = None) -> float:
        """Decay-weighted reads/s for one segment (0.0 if never read)."""
        ent = self._read_ewma.get(seg)
        if ent is None:
            return 0.0
        if now is None:
            now = _perf_counter()
        decayed = ent[0] * 0.5 ** (max(0.0, now - ent[1])
                                   / self.read_halflife_s)
        return decayed * self._LN2 / self.read_halflife_s

    def read_rates(self, now: float | None = None) -> dict[int, float]:
        """Read rate of every segment that has ever been read."""
        if now is None:
            now = _perf_counter()
        return {seg: self.read_rate(seg, now=now) for seg in self._read_ewma}

    # -------------------------------------------------------------- decisions
    @property
    def observed_ratio(self) -> float | None:
        if self.compressed_bytes == 0:
            return None
        return self.raw_bytes / self.compressed_bytes

    @property
    def drift(self) -> float:
        """Fractional ratio degradation vs the baseline (0.0 = no drift)."""
        obs = self.observed_ratio
        if obs is None or not self.baseline_ratio:
            return 0.0
        return max(0.0, 1.0 - obs / self.baseline_ratio)

    def should_compact(self) -> bool:
        """True once enough appended bytes compress badly enough."""
        return self.raw_bytes >= self.min_bytes and self.drift > self.threshold

    def snapshot(self) -> dict:
        return {"baseline_ratio": self.baseline_ratio,
                "observed_ratio": self.observed_ratio,
                "drift": round(self.drift, 4),
                "threshold": self.threshold,
                "observed_raw_bytes": self.raw_bytes,
                "observed_compressed_bytes": self.compressed_bytes,
                "observations": self.observations,
                "should_compact": self.should_compact()}


def segment_ratio(dictionary, segment) -> float:
    """Achieved compression ratio of one sealed segment, derived entirely
    from its token stream (decoded length = sum of token entry lengths)."""
    if segment.payload_bytes == 0:
        return 1.0
    tokens = np.asarray(segment.tokens(), dtype=np.int64)
    raw = int(dictionary.lens[tokens].astype(np.int64).sum())
    return raw / segment.payload_bytes


def segment_report(store) -> list[dict]:
    """Per-segment achieved ratios for a store — the drift monitor's view of
    which sealed segments a compaction would rewrite most profitably."""
    base = getattr(store.drift, "baseline_ratio", None) \
        if hasattr(store, "drift") else None
    rows = []
    for seg in store.segments.segments:
        r = segment_ratio(store.dictionary, seg)
        rows.append({"segment": seg.index, "base_id": seg.base_id,
                     "n_strings": seg.n_strings, "ratio": round(r, 4),
                     "drift": round(max(0.0, 1.0 - r / base), 4)
                     if base else 0.0})
    return rows
