"""repro.store — the batched random-access serving subsystem.

Layered between the compression algorithms (repro.core) / device kernels
(repro.kernels) and the launchers (repro.launch):

  segment  — multi-segment corpus layout + global->(segment, local) routing
  cache    — byte-budgeted LRU over decoded strings
  store    — CompressedStringStore: get / multiget / scan with
             length-bucketed static-shape Pallas decode (numpy fallback),
             plus save(dir)/open(dir) persistence over the DictArtifact +
             CompressedCorpus containers (no retraining on open)
  mutable  — MutableStringStore: the write path — frozen-dictionary
             append into an open tail, sealing into immutable segments,
             drift-triggered compact() with versioned-directory swap
  drift    — DriftMonitor: achieved vs train-time compression ratio,
             plus the per-segment read-rate EWMA (tiering temperature)
  tier     — TierManager: RLZ cold tier (repro.core.rlz) with
             temperature-driven demotion/promotion behind the store API
  service  — micro-batching request queue coalescing point lookups
             (reads and appends share one worker)
  stats    — serving counters surfaced through repro.core.metrics

Segment-sharded multi-host persistence lives in
``repro.distributed.shard_store`` (one shared dictionary artifact, one
openable store directory per shard).
"""

from repro.store.cache import LRUCache
from repro.store.drift import DriftMonitor
from repro.store.mutable import MutableStringStore
from repro.store.segment import Segment, SegmentedCorpus
from repro.store.service import StoreService
from repro.store.stats import StoreStats
from repro.store.store import CompressedStringStore
from repro.store.tier import TierManager, tier_op

__all__ = ["CompressedStringStore", "DriftMonitor", "LRUCache",
           "MutableStringStore", "Segment", "SegmentedCorpus",
           "StoreService", "StoreStats", "TierManager", "tier_op"]
