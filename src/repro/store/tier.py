"""Tiered storage: mmap'd RLZ cold tier behind the hot OnPair segments.

The memory-at-scale story: a store's sealed segments split into two
temperature tiers behind one unchanged read API.

* **hot** — the segment's OnPair token payload + offsets live on the heap,
  decoded by the store's usual kernel/numpy path.
* **cold** — the segment has been re-encoded with :mod:`repro.core.rlz`
  against the trained dictionary's entry blob and written as a
  ``cold-<seg>.rlz`` container next to ``index.npz``; both the RLZ factor
  arrays *and* the original OnPair payload/offsets are reopened with
  ``np.memmap``, so none of the segment's bytes stay resident. Point reads
  decode from the RLZ factors (O(factors-per-string) random access); the
  mmap'd OnPair payload keeps ``locate``/``scan_prefix``'s compressed-form
  probes — and a later byte-exact promotion — working unchanged.

Temperature is the per-segment read-rate EWMA kept by
:class:`~repro.store.drift.DriftMonitor`: :meth:`TierManager.tick` demotes
segments whose rate fell below ``demote_below`` on a background worker, and
a read burst above ``promote_above`` promotes a cold segment straight back
to the heap. ``demote``/``promote`` are also explicit operator RPCs
(``repro.net.protocol.OP_TIER``).

State machine per sealed segment::

    hot --(rate <= demote_below at tick, off-thread re-encode)--> cold
    cold --(rate >= promote_above, or explicit promote)---------> hot

Obs: ``repro_store_tier_bytes{tier=hot|cold}`` gauges and the
``repro_store_cold_get_latency_us`` histogram.
"""

from __future__ import annotations

import os
import queue
import shutil
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.artifact import read_container, write_container
from repro.core.rlz import RLZCodec, decode_ids, rlz_nbytes
from repro.obs import REGISTRY
from repro.store.drift import DriftMonitor

#: container header ``kind`` of a cold-segment file
COLD_KIND = "rlz_segment"


def cold_file_name(seg_index: int) -> str:
    return f"cold-{seg_index:04d}.rlz"


@dataclass
class ColdSegment:
    """Bookkeeping for one demoted segment (all arrays are memmap views)."""

    index: int
    base_id: int
    n_strings: int
    path: str
    arrays: dict = field(repr=False)          # RLZ factor arrays (mmap)
    rlz_bytes: int = 0                        # encoded factor-array size
    payload_bytes: int = 0                    # original OnPair payload size


class TierManager:
    """Hot/cold tier control for one store's sealed segments.

    Created via :meth:`repro.store.store.CompressedStringStore.enable_tiering`;
    all mutation of ``self.cold`` (and of segment payloads) happens under the
    store's lock, so the read path can consult it without extra locking.
    """

    def __init__(self, store, *, demote_below: float = 0.05,
                 promote_above: float = 1.0, halflife_s: float = 30.0,
                 min_match: int = 8, workdir: str | None = None):
        self.store = store
        self.demote_below = float(demote_below)
        self.promote_above = float(promote_above)
        self.halflife_s = float(halflife_s)
        self.min_match = int(min_match)
        #: segment index -> ColdSegment for every currently-cold segment
        self.cold: dict[int, ColdSegment] = {}
        self.demotions = 0
        self.promotions = 0
        self._workdir = workdir
        # temperature signal: the writable store's DriftMonitor when it has
        # one, a private monitor for read-only stores
        drift = getattr(store, "drift", None)
        self._drift: DriftMonitor = drift if drift is not None \
            else DriftMonitor()
        self._drift.read_halflife_s = self.halflife_s
        # per-generation RLZ codec + reference CRC caches
        self._codec: RLZCodec | None = None
        self._codec_version = -1
        self._crc: tuple[int, int] | None = None
        # off-thread demotion worker (started lazily, one at a time)
        self._jobs: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._gauge_hot = REGISTRY.gauge("repro_store_tier_bytes", tier="hot")
        self._gauge_cold = REGISTRY.gauge("repro_store_tier_bytes",
                                          tier="cold")
        self._cold_lat = REGISTRY.histogram("repro_store_cold_get_latency_us")
        self._update_gauges_locked()

    # ------------------------------------------------------------ temperature
    def note_reads_locked(self, ids) -> None:
        """Update per-segment read rates from one multiget's ids (called
        under the store lock) and promote any cold segment whose rate just
        crossed ``promote_above`` — the read-burst promotion path."""
        segs = self.store.segments
        n_sealed = segs.n_strings
        sealed = [i for i in ids if i < n_sealed]
        if not sealed:
            return
        ks = np.searchsorted(np.asarray(segs._base_ids, dtype=np.int64),
                             np.asarray(sealed, dtype=np.int64),
                             side="right") - 1
        uk, uc = np.unique(ks, return_counts=True)
        now = time.perf_counter()
        counts = {segs.segments[int(k)].index: int(c)
                  for k, c in zip(uk, uc)}
        self._drift.note_reads(counts, now=now)
        for si in counts:
            if si in self.cold and \
                    self._drift.read_rate(si, now=now) >= self.promote_above:
                self._promote_locked(si)

    def tick(self, now: float | None = None) -> list[int]:
        """Scan sealed segments; schedule off-thread demotion for every hot
        segment whose read rate is at or below ``demote_below``. Returns the
        scheduled segment indexes (demotions complete asynchronously; call
        :meth:`join` to wait)."""
        now = time.perf_counter() if now is None else now
        cands = []
        with self.store._lock:
            for seg in self.store.segments.segments:
                if seg.n_strings == 0 or seg.index in self.cold:
                    continue
                if self._drift.read_rate(seg.index, now=now) \
                        <= self.demote_below:
                    cands.append(seg.index)
        for si in cands:
            self.schedule_demote(si)
        return cands

    def schedule_demote(self, seg_index: int) -> None:
        """Queue one segment for off-thread demotion."""
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, daemon=True, name="repro-tier")
            self._worker.start()
        self._jobs.put(int(seg_index))

    def join(self) -> None:
        """Block until every queued demotion has been processed."""
        self._jobs.join()

    def _worker_loop(self) -> None:
        while True:
            si = self._jobs.get()
            try:
                self.demote(si)
            except Exception:  # pragma: no cover - demotion is best-effort
                pass
            finally:
                self._jobs.task_done()

    # --------------------------------------------------------- demote/promote
    def demote(self, seg_index: int) -> dict | None:
        """Re-encode one sealed segment as RLZ and swap its arrays to mmap
        views. Factorization and the container write run outside the store
        lock; the final adoption re-checks that no compaction swapped the
        generation meanwhile. Returns a report dict, or None when the
        segment is absent, empty, or already cold."""
        store = self.store
        with store._lock:
            segs = store.segments.segments
            if not 0 <= seg_index < len(segs):
                return None
            seg = segs[seg_index]
            if seg.index in self.cold or seg.n_strings == 0:
                return None
            version = getattr(store, "version_id", 0)
            raw = store._scan_locked(seg.base_id, seg.base_id + seg.n_strings)
            payload = np.asarray(seg.payload, dtype=np.uint8)
            offsets = np.asarray(seg.offsets, dtype=np.int64)
            dictionary = store.dictionary
            codec = self._codec_for_locked(dictionary, version)
            ref_crc = self._ref_crc_locked(dictionary, version)
        arrays = codec.factorize(raw)
        encoded = rlz_nbytes(arrays)
        arrays["payload"] = payload
        arrays["offsets"] = offsets
        header = {"kind": COLD_KIND, "segment": int(seg.index),
                  "base_id": int(seg.base_id),
                  "n_strings": int(seg.n_strings),
                  "raw_bytes": int(sum(len(s) for s in raw)),
                  "min_match": codec.min_match, "ref_crc": ref_crc,
                  "payload_bytes": int(payload.size)}
        path = os.path.join(self._ensure_workdir(),
                            cold_file_name(seg.index))
        write_container(path, header, arrays)
        with store._lock:
            current = store.segments.segments
            if getattr(store, "version_id", 0) != version \
                    or seg_index >= len(current) \
                    or current[seg_index] is not seg \
                    or seg.index in self.cold:
                return None  # generation swapped mid-encode: abandon
            self._adopt_locked(seg, path)
            self.demotions += 1
            return {"segment": seg.index,
                    "payload_bytes": header["payload_bytes"],
                    "rlz_bytes": encoded,
                    "raw_bytes": header["raw_bytes"]}

    def _adopt_locked(self, seg, path: str,
                      opened: tuple[dict, dict] | None = None) -> None:
        """Point ``seg`` at the cold container's mmap arrays and register
        the ColdSegment. ``opened`` passes an already-read container."""
        header, arrays = opened if opened is not None \
            else read_container(path, mmap=True)
        rlz = {k: arrays[k] for k in ("starts", "offs", "lens", "literals")}
        seg.payload = arrays["payload"]
        seg.offsets = arrays["offsets"]
        self.cold[seg.index] = ColdSegment(
            index=seg.index, base_id=seg.base_id, n_strings=seg.n_strings,
            path=path, arrays=rlz,
            rlz_bytes=int(sum(np.asarray(a).nbytes for a in rlz.values())),
            payload_bytes=int(header.get("payload_bytes", seg.payload.size)))
        self._update_gauges_locked()

    def promote(self, seg_index: int) -> bool:
        """Copy a cold segment's OnPair arrays back onto the heap (byte-
        exact: the mmap'd payload IS the original encoding). The container
        file stays on disk; only segments listed cold at save time are
        re-attached on open."""
        with self.store._lock:
            return self._promote_locked(seg_index)

    def _promote_locked(self, seg_index: int) -> bool:
        cold = self.cold.pop(seg_index, None)
        if cold is None:
            return False
        seg = self.store.segments.segments[seg_index]
        seg.payload = np.array(seg.payload, dtype=np.uint8, copy=True)
        seg.offsets = np.array(seg.offsets, dtype=np.int64, copy=True)
        self.promotions += 1
        self._update_gauges_locked()
        return True

    # -------------------------------------------------------------- cold read
    def split_misses_locked(self, misses: list[int]
                            ) -> tuple[list[int], dict[int, list[tuple]]]:
        """Partition multiget misses into hot ids and
        ``{segment: [(gid, local), ...]}`` cold groups."""
        hot: list[int] = []
        cold: dict[int, list[tuple]] = {}
        segs = self.store.segments
        n_sealed = segs.n_strings
        for i in misses:
            if i < n_sealed:
                seg, local = segs.route(i)
                if seg.index in self.cold:
                    cold.setdefault(seg.index, []).append((i, local))
                    continue
            hot.append(i)
        return hot, cold

    def decode_misses_locked(self, cold: dict[int, list[tuple]],
                             results: dict[int, bytes]) -> int:
        """Decode cold misses from their RLZ factor arrays; fills
        ``results`` and records the cold-get latency histogram."""
        t0 = time.perf_counter()
        ref = self._reference()
        n = 0
        for si, pairs in cold.items():
            cs = self.cold[si]
            vals = decode_ids(ref, cs.arrays, [loc for _, loc in pairs])
            for (gid, _), v in zip(pairs, vals):
                results[gid] = v
            n += len(pairs)
        self._cold_lat.record_seconds(time.perf_counter() - t0)
        stats = getattr(self.store, "stats", None)
        if stats is not None:
            stats.cold_lookups += n
        return n

    def decode_range_locked(self, seg_index: int,
                            lo: int, hi: int) -> list[bytes]:
        """Scan path: decode a cold segment's local range from RLZ."""
        cs = self.cold[seg_index]
        return decode_ids(self._reference(), cs.arrays,
                          np.arange(lo, hi, dtype=np.int64))

    def _reference(self) -> np.ndarray:
        return np.asarray(self.store.dictionary.blob, dtype=np.uint8)

    # ------------------------------------------------------------ persistence
    def params(self) -> dict:
        return {"demote_below": self.demote_below,
                "promote_above": self.promote_above,
                "halflife_s": self.halflife_s,
                "min_match": self.min_match}

    def cold_items_locked(self) -> list[dict]:
        """Snapshot of the cold set for a save (call under the store lock):
        the container files are immutable once written, so copying them
        after the lock drops is safe."""
        return [{"segment": cs.index, "file": cold_file_name(cs.index),
                 "base_id": cs.base_id, "n_strings": cs.n_strings,
                 "path": cs.path}
                for cs in self.cold.values()]

    def copy_cold_files(self, items: list[dict], dir_path: str) -> None:
        """Materialise a save snapshot's cold containers in ``dir_path``."""
        for it in items:
            dst = os.path.join(dir_path, it["file"])
            if os.path.abspath(it["path"]) != os.path.abspath(dst):
                shutil.copyfile(it["path"], dst)

    def attach(self, dir_path: str, cold_meta: list[dict]) -> int:
        """Re-adopt persisted cold segments on open. Every entry is
        validated against the live segmentation (position, base id, count)
        and the dictionary generation (reference CRC); mismatches are left
        hot — same silently-rebuild contract as the index sidecar. Future
        demotions write next to the attached files."""
        store = self.store
        self._workdir = dir_path
        adopted = 0
        with store._lock:
            version = getattr(store, "version_id", 0)
            ref_crc = self._ref_crc_locked(store.dictionary, version)
            segs = store.segments.segments
            for item in cold_meta:
                si = int(item["segment"])
                path = os.path.join(dir_path, item["file"])
                if si >= len(segs) or si in self.cold \
                        or not os.path.exists(path):
                    continue
                seg = segs[si]
                if seg.n_strings == 0 \
                        or seg.base_id != int(item.get("base_id", -1)) \
                        or seg.n_strings != int(item.get("n_strings", -1)):
                    continue
                try:
                    header, arrays = read_container(path, mmap=True)
                except Exception:
                    continue
                if header.get("kind") != COLD_KIND \
                        or header.get("ref_crc") != ref_crc \
                        or header.get("n_strings") != seg.n_strings:
                    continue
                self._adopt_locked(seg, path, opened=(header, arrays))
                adopted += 1
        return adopted

    def clear_locked(self) -> None:
        """Drop all tier state (compaction swapped the segments out from
        under it; the rewrite folded cold data back into hot segments)."""
        self.cold.clear()
        self._codec = None
        self._codec_version = -1
        self._crc = None
        self._drift._read_ewma.clear()
        self._update_gauges_locked()

    # -------------------------------------------------------------- reporting
    def hot_bytes_locked(self) -> int:
        return sum(s.payload_bytes + s.offsets.nbytes
                   for s in self.store.segments.segments
                   if s.index not in self.cold)

    def cold_bytes_locked(self) -> int:
        return sum(s.payload_bytes + s.offsets.nbytes
                   for s in self.store.segments.segments
                   if s.index in self.cold)

    def snapshot(self) -> dict:
        now = time.perf_counter()
        return {"cold_segments": sorted(self.cold),
                "n_cold": len(self.cold),
                "n_segments": self.store.segments.n_segments,
                "demotions": self.demotions,
                "promotions": self.promotions,
                "cold_payload_bytes": sum(cs.payload_bytes
                                          for cs in self.cold.values()),
                "rlz_bytes": sum(cs.rlz_bytes for cs in self.cold.values()),
                "read_rates": {int(k): round(v, 4) for k, v in
                               self._drift.read_rates(now=now).items()},
                "params": self.params(),
                "cold_latency": self._cold_lat.summary()}

    # --------------------------------------------------------------- internal
    def _ensure_workdir(self) -> str:
        if self._workdir is None:
            self._workdir = tempfile.mkdtemp(prefix="repro-tier-")
        else:
            os.makedirs(self._workdir, exist_ok=True)
        return self._workdir

    def _codec_for_locked(self, dictionary, version: int) -> RLZCodec:
        if self._codec is None or self._codec_version != version:
            self._codec = RLZCodec(
                np.asarray(dictionary.blob, dtype=np.uint8),
                min_match=self.min_match)
            self._codec_version = version
        return self._codec

    def _ref_crc_locked(self, dictionary, version: int) -> int:
        if self._crc is None or self._crc[0] != version:
            blob = np.ascontiguousarray(
                np.asarray(dictionary.blob, dtype=np.uint8))
            self._crc = (version, int(zlib.crc32(blob.tobytes())))
        return self._crc[1]

    def _update_gauges_locked(self) -> None:
        self._gauge_hot.set(float(self.hot_bytes_locked()))
        self._gauge_cold.set(float(self.cold_bytes_locked()))


def tier_op(store, action: str = "stats", segment: int | None = None,
            params: dict | None = None) -> dict:
    """One tier control operation against a single store — the shared
    server-side implementation of the ``OP_TIER`` RPC and the in-process
    router's tier methods.

    ``stats`` never enables tiering (``{"enabled": False}`` when off);
    ``demote``/``promote`` enable it on first use, act on one segment, or —
    with ``segment=None`` — on every eligible segment (demote: every hot
    sealed segment; promote: every cold one).
    """
    if action == "stats":
        tier = getattr(store, "tier", None)
        if tier is None:
            return {"enabled": False}
        return {"enabled": True, **tier.snapshot()}
    if action not in ("demote", "promote"):
        raise ValueError(f"unknown tier action {action!r} "
                         "(one of 'stats', 'demote', 'promote')")
    tier = store.enable_tiering(**(params or {}))
    if action == "demote":
        if segment is None:
            idxs = [s.index for s in store.segments.segments if s.n_strings]
        else:
            idxs = [int(segment)]
        done = [r["segment"] for r in map(tier.demote, idxs)
                if r is not None]
        return {"enabled": True, "demoted": done, "n_cold": len(tier.cold)}
    idxs = sorted(tier.cold) if segment is None else [int(segment)]
    done = [si for si in idxs if tier.promote(si)]
    return {"enabled": True, "promoted": done, "n_cold": len(tier.cold)}
