"""Multi-segment layout for a compressed corpus.

A :class:`SegmentedCorpus` splits one :class:`~repro.core.api.CompressedCorpus`
into fixed-size segments of consecutive strings. Each segment carries a
zero-copy payload view plus *segment-local* byte offsets, and global string
ids route as ``gid -> (segment, local)``. Segments are the store's unit of
scan decoding, the unit of sharding (``repro.distributed.shard_store``), and
the unit the writable store seals appended tails into
(``repro.store.mutable``): sealed segments may therefore have heterogeneous
sizes (the seed corpus's last segment can be partial before the first sealed
tail lands behind it), so routing bisects the segments' base ids instead of
dividing by a fixed width.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

import numpy as np

from repro.core.api import CompressedCorpus


@dataclass
class Segment:
    """A contiguous run of compressed strings with local offsets."""

    index: int
    base_id: int              # global id of local string 0
    payload: np.ndarray       # u8 view into the corpus payload
    offsets: np.ndarray       # i64[n_local + 1], local byte offsets

    @property
    def n_strings(self) -> int:
        return len(self.offsets) - 1

    @property
    def payload_bytes(self) -> int:
        return int(self.payload.size)

    def string_tokens(self, local: int) -> np.ndarray:
        """u16 token IDs of local string ``local`` (zero-copy view)."""
        o0, o1 = int(self.offsets[local]), int(self.offsets[local + 1])
        return self.payload[o0:o1].view("<u2")

    def tokens(self, lo: int = 0, hi: int | None = None) -> np.ndarray:
        """One u16 token stream covering local strings [lo, hi)."""
        if hi is None:
            hi = self.n_strings
        o0, o1 = int(self.offsets[lo]), int(self.offsets[hi])
        return self.payload[o0:o1].view("<u2")

    def token_counts(self) -> np.ndarray:
        return ((self.offsets[1:] - self.offsets[:-1]) // 2).astype(np.int64)


@dataclass
class SegmentedCorpus:
    """Fixed-size segmentation of a compressed corpus + global routing."""

    segments: list[Segment]
    strings_per_segment: int
    n_strings: int
    raw_bytes: int
    _base_ids: list[int] = field(default_factory=list, repr=False)

    def __post_init__(self) -> None:
        self._base_ids = [s.base_id for s in self.segments]

    @classmethod
    def from_corpus(cls, corpus: CompressedCorpus,
                    strings_per_segment: int = 4096) -> "SegmentedCorpus":
        if strings_per_segment < 1:
            raise ValueError("strings_per_segment must be >= 1")
        n = corpus.n_strings
        segments: list[Segment] = []
        for base in range(0, max(n, 1), strings_per_segment):
            hi = min(base + strings_per_segment, n)
            if hi <= base:
                break
            b0, b1 = int(corpus.offsets[base]), int(corpus.offsets[hi])
            segments.append(Segment(
                index=len(segments), base_id=base,
                payload=corpus.payload[b0:b1],
                offsets=(corpus.offsets[base : hi + 1] - b0).astype(np.int64)))
        if not segments:  # empty corpus still routes scans/len() sanely
            segments = [Segment(index=0, base_id=0,
                                payload=corpus.payload[:0],
                                offsets=np.zeros(1, dtype=np.int64))]
        return cls(segments=segments, strings_per_segment=strings_per_segment,
                   n_strings=n, raw_bytes=corpus.raw_bytes)

    # ------------------------------------------------------------- mutation
    def append_segment(self, payload: np.ndarray, offsets: np.ndarray,
                       raw_bytes: int = 0) -> Segment:
        """Seal a new segment of compressed strings behind the existing ones.

        ``payload``/``offsets`` use the same layout as :class:`Segment`
        (local byte offsets into a u8 payload). The new segment's strings
        take the next ``offsets.size - 1`` global ids. Caller synchronises
        (the store mutates under its own lock).
        """
        if self.n_strings == 0 and self.segments and \
                self.segments[0].n_strings == 0:
            # drop the empty-corpus placeholder segment
            self.segments = []
            self._base_ids = []
        seg = Segment(index=len(self.segments), base_id=self.n_strings,
                      payload=np.asarray(payload, dtype=np.uint8),
                      offsets=np.asarray(offsets, dtype=np.int64))
        self.segments.append(seg)
        self._base_ids.append(seg.base_id)
        self.n_strings += seg.n_strings
        self.raw_bytes += int(raw_bytes)
        return seg

    # --------------------------------------------------------------- routing
    def route(self, gid: int) -> tuple[Segment, int]:
        """Global string id -> (segment, local id). Raises IndexError when
        out of range (negative ids included — the store is an id-addressed
        service, not a Python sequence)."""
        if not 0 <= gid < self.n_strings:
            raise IndexError(
                f"string id {gid} out of range [0, {self.n_strings})")
        seg = self.segments[bisect.bisect_right(self._base_ids, gid) - 1]
        return seg, gid - seg.base_id

    def overlapping(self, lo: int, hi: int):
        """Segments covering any id in [lo, hi), found by bisect — scans of
        a narrow range touch O(covered) segments, not all of them."""
        if lo >= hi:
            return
        k = max(0, bisect.bisect_right(self._base_ids, lo) - 1)
        for seg in self.segments[k:]:
            if seg.base_id >= hi:
                break
            yield seg

    def string_tokens(self, gid: int) -> np.ndarray:
        seg, local = self.route(gid)
        return seg.string_tokens(local)

    def token_counts(self) -> np.ndarray:
        """Tokens per string over the whole corpus, in global id order."""
        if self.n_strings == 0:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate([s.token_counts() for s in self.segments])

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def payload_bytes(self) -> int:
        return sum(s.payload_bytes for s in self.segments)
