"""Micro-batching request service over a CompressedStringStore.

High-volume point-lookup traffic arrives one id at a time; decoding one
string per kernel launch wastes the batch axis the Pallas decoder
parallelises over. :class:`StoreService` coalesces concurrent lookups: a
single worker thread drains the request queue, waits up to ``max_wait_s``
for the batch to fill (classic micro-batching latency/throughput knob), and
answers the whole batch with ONE ``store.multiget`` — one padded kernel
invocation per touched length bucket.

Writes ride the same queue: against a
:class:`~repro.store.mutable.MutableStringStore`, ``submit_append(s)``
enqueues a string and the worker folds every append in the drained batch
into ONE ``store.extend`` (one Encoder parse pass) before answering the
batch's reads — appends and reads interleave without torn state because the
store itself serialises both under its lock.

The bulk entry points ``submit_multiget(ids)`` / ``submit_extend(strings)``
are the batch-drain hooks the RPC front-end (``repro.net.shard_server``)
rides on: one network request becomes one queue item and one future, and
the worker still folds every read in the drained batch into one
``store.multiget`` and every write into one ``store.extend`` — micro-batching
composes across connections.

The worker blocks on the queue (no idle polling): ``close()`` wakes it with
a sentinel. ``wakeups`` counts worker wakeups and therefore stays 0 while
the service is idle — tests assert on it to keep the no-busy-wait property.

``max_wait_s`` — the micro-batching window — is either a fixed knob (the
pre-v3 behaviour) or, when ``target_p99_s`` is set, the output of a small
feedback controller: the worker keeps a window of recent request latencies
and, every ``adapt_window`` requests, halves the wait when the observed p99
overshoots the target and doubles it (up to ``max_wait_cap_s``) when p99
sits below half the target — trading latency headroom for larger coalesced
batches only when the target allows it. The current wait, the target and
the adjustment count are all visible in :meth:`stats`.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future

from repro.obs import REGISTRY, TRACER, Counter, Histogram
from repro.store.store import CompressedStringStore


class StoreService:
    """Thread-safe coalescing front-end: ``submit(i) -> Future[bytes]``."""

    #: adaptive-controller floor: below this the wait snaps to 0 (drain-only)
    _MIN_WAIT_S = 5e-5

    def __init__(self, store: CompressedStringStore, max_batch: int = 256,
                 max_wait_s: float = 0.0005, target_p99_s: float | None = None,
                 adapt_window: int = 64, max_wait_cap_s: float = 0.01):
        self.store = store
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.target_p99_s = (None if target_p99_s is None
                             else float(target_p99_s))
        self.adapt_window = max(8, int(adapt_window))
        self.max_wait_cap_s = float(max_wait_cap_s)
        self.wait_adjustments = 0   # times the controller moved max_wait_s
        self._adapt_win: list[float] = []  # latencies since the last adapt
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._stop = threading.Event()
        self._submit_lock = threading.Lock()  # orders submit() vs close()
        # per-service histogram (stats() stays instance-scoped), registered
        # into the process registry so /metrics merges every service in the
        # process into one repro_service_request_latency_us series
        self._lat = REGISTRY.register(
            Histogram("repro_service_request_latency_us"))
        self._requests_total = REGISTRY.register(
            Counter("repro_service_requests_total"))
        self.requests = 0
        self.batches = 0
        self.coalesced = 0          # requests answered in a batch of > 1
        self.max_batch_seen = 0
        self.appends = 0
        self.append_batches = 0     # store.extend calls (coalesced writes)
        self.wakeups = 0            # worker wakeups; 0 while idle (no polling)
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="store-service")
        self._worker.start()

    # ----------------------------------------------------------------- client
    def submit(self, i: int) -> "Future[bytes]":
        """Enqueue a point lookup; resolves to the decoded string.

        Out-of-range ids fail their own future immediately instead of
        poisoning the coalesced batch they would have joined.
        """
        fut: Future = Future()
        i = int(i)
        if not 0 <= i < self.store.n_strings:
            fut.set_exception(IndexError(
                f"string id {i} out of range [0, {self.store.n_strings})"))
            return fut
        self._enqueue(("get", i, fut, time.perf_counter(),
                       TRACER.current()), fut, 1)
        return fut

    def submit_multiget(self, ids) -> "Future[list[bytes]]":
        """Enqueue one batched lookup; resolves to the decoded strings in
        request order.

        The whole request rides the queue as ONE item — the drain hook an
        RPC front-end uses so each network request costs one future while
        the worker still folds all concurrently drained reads into a single
        ``store.multiget``.
        """
        fut: Future = Future()
        ids = [int(i) for i in ids]
        n = self.store.n_strings
        for i in ids:
            if not 0 <= i < n:
                fut.set_exception(IndexError(
                    f"string id {i} out of range [0, {n})"))
                return fut
        self._enqueue(("multiget", ids, fut, time.perf_counter(),
                       TRACER.current()), fut, len(ids))
        return fut

    def submit_append(self, s: bytes) -> "Future[int]":
        """Enqueue an append; resolves to the new string's global id.

        Requires the store to be writable (``MutableStringStore.extend``);
        otherwise the future fails with TypeError. All appends drained into
        one batch are folded into a single ``store.extend`` call.
        """
        fut: Future = Future()
        if not hasattr(self.store, "extend"):
            fut.set_exception(TypeError(
                "store is read-only (open a MutableStringStore to append)"))
            return fut
        self._enqueue(("append", bytes(s), fut, time.perf_counter(),
                       TRACER.current()), fut, 1)
        return fut

    def submit_extend(self, strings) -> "Future[list[int]]":
        """Enqueue one batched append; resolves to the new global ids.

        The write-side bulk drain hook: one queue item per request, folded
        with every other append/extend in the drained batch into ONE
        ``store.extend`` (one Encoder parse pass).
        """
        fut: Future = Future()
        if not hasattr(self.store, "extend"):
            fut.set_exception(TypeError(
                "store is read-only (open a MutableStringStore to append)"))
            return fut
        strings = [bytes(s) for s in strings]
        self._enqueue(("extend", strings, fut, time.perf_counter(),
                       TRACER.current()), fut, len(strings))
        return fut

    def _enqueue(self, item, fut: Future, n_requests: int) -> None:
        # atomic vs close(): either we enqueue before the shutdown sentinel,
        # or we observe _stop and fail fast — never an unresolved Future
        with self._submit_lock:
            if self._stop.is_set():
                fut.set_exception(RuntimeError("service is closed"))
                return
            self.requests += n_requests
            self._requests_total.inc(n_requests)
            self._q.put(item)

    def get(self, i: int, timeout: float | None = 30.0) -> bytes:
        return self.submit(i).result(timeout)

    def append(self, s: bytes, timeout: float | None = 30.0) -> int:
        return self.submit_append(s).result(timeout)

    def multiget(self, ids, timeout: float | None = 30.0) -> list[bytes]:
        futures = [self.submit(i) for i in ids]
        return [f.result(timeout) for f in futures]

    def close(self) -> None:
        with self._submit_lock:
            self._stop.set()
            self._q.put(None)  # wake the worker; nothing enqueues after this
        self._worker.join(timeout=5.0)

    def __enter__(self) -> "StoreService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        lat = self._lat.summary()
        return {"requests": self.requests, "batches": self.batches,
                "coalesced": self.coalesced,
                "avg_batch": round(self.requests / self.batches, 2)
                if self.batches else 0.0,
                "max_batch_seen": self.max_batch_seen,
                "appends": self.appends,
                "append_batches": self.append_batches,
                "wakeups": self.wakeups,
                "max_wait_s": self.max_wait_s,
                "target_p99_s": self.target_p99_s,
                "wait_adjustments": self.wait_adjustments,
                "request_latency": lat,
                "request_latency_hist": self._lat.state()}

    # ----------------------------------------------------------------- worker
    def _collect_batch(self, first) -> list:
        """Wait up to max_wait_s for the batch to fill, then drain whatever
        is immediately available."""
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                item = (self._q.get(timeout=remaining) if remaining > 0
                        else self._q.get_nowait())
            except queue.Empty:
                break
            if item is None:
                self._stop.set()
                break
            batch.append(item)
        return batch

    def _drain_and_fail(self) -> None:
        """Fail any request that raced past submit()'s closed check and landed
        behind the shutdown sentinel — never leave a Future unresolved."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                return
            if item is not None and item[2].set_running_or_notify_cancel():
                item[2].set_exception(RuntimeError("service is closed"))

    def _run(self) -> None:
        while True:
            # block until traffic or the close() sentinel arrives — an idle
            # service burns zero wakeups (asserted by tests via `wakeups`)
            item = self._q.get()
            if item is None:
                self._drain_and_fail()
                return
            self.wakeups += 1
            raw = self._collect_batch(item)
            # cancelled futures drop out here; surviving ones flip to RUNNING
            # so a late cancel() cannot race set_result below
            batch = [b for b in raw if b[2].set_running_or_notify_cancel()]
            # writes first: a client holding an id from a resolved append can
            # immediately read it back through the next batch
            writes = [b for b in batch if b[0] in ("append", "extend")]
            reads = [b for b in batch if b[0] in ("get", "multiget")]
            if writes:
                self._serve_writes(writes)
            if reads:
                self._serve_reads(reads)
            done = time.perf_counter()
            lats = [done - t for _, _, _, t, _ in batch]
            for dt in lats:
                self._lat.record(dt * 1e6)
            # one coalesce-wait span per traced request: the enqueue→answer
            # window a trace shows as the price of micro-batching
            for _, _, _, t0, ctx in batch:
                if ctx is not None:
                    TRACER.record_child("service.coalesce", ctx, t0,
                                        done - t0, batch=len(batch))
            if self.target_p99_s is not None:
                self._adapt_wait(lats)
            if len(batch) > 1:
                self.coalesced += len(batch)
            self.batches += 1
            self.max_batch_seen = max(self.max_batch_seen, len(batch))
            if self._stop.is_set():
                # _collect_batch consumed the close() sentinel mid-batch:
                # looping back to the blocking get would hang forever
                self._drain_and_fail()
                return

    def _adapt_wait(self, lats: list[float]) -> None:
        """Latency-aware controller: every ``adapt_window`` answered requests,
        move ``max_wait_s`` toward the largest batching window that still
        meets ``target_p99_s`` (ROADMAP: drive the knob from the service's
        own latency counters). Multiplicative so it converges in a handful of
        windows; bounded by ``max_wait_cap_s``; snaps to 0 below _MIN_WAIT_S
        (a sub-50us window buys no coalescing but still costs a timed get)."""
        self._adapt_win.extend(lats)
        if len(self._adapt_win) < self.adapt_window:
            return
        win = sorted(self._adapt_win)
        self._adapt_win.clear()
        p99 = win[min(len(win) - 1, int(0.99 * len(win)))]
        old = self.max_wait_s
        if p99 > self.target_p99_s:
            new = self.max_wait_s / 2
            self.max_wait_s = new if new >= self._MIN_WAIT_S else 0.0
        elif p99 < self.target_p99_s / 2:
            self.max_wait_s = min(max(self.max_wait_s * 2, self._MIN_WAIT_S),
                                  self.max_wait_cap_s)
        if self.max_wait_s != old:
            self.wait_adjustments += 1

    def _serve_writes(self, writes: list) -> None:
        """Fold every append/extend in the drained batch into ONE
        store.extend, then split the contiguous ids back per request."""
        strings: list[bytes] = []
        spans: list[tuple[int, int]] = []  # [lo, hi) into `strings` per item
        for kind, payload, _, _, _ in writes:
            lo = len(strings)
            strings.extend([payload] if kind == "append" else payload)
            spans.append((lo, len(strings)))
        try:
            new_ids = self.store.extend(strings)
        except Exception as exc:
            for _, _, fut, _, _ in writes:
                fut.set_exception(exc)
            return
        self.appends += len(strings)
        self.append_batches += 1
        for (kind, _, fut, _, _), (lo, hi) in zip(writes, spans):
            fut.set_result(new_ids[lo] if kind == "append"
                           else new_ids[lo:hi])

    def _serve_reads(self, reads: list) -> None:
        """Fold every get/multiget in the drained batch into ONE
        store.multiget, then slice the answers back per request."""
        ids: list[int] = []
        spans: list[tuple[int, int]] = []
        for kind, payload, _, _, _ in reads:
            lo = len(ids)
            ids.extend([payload] if kind == "get" else payload)
            spans.append((lo, len(ids)))
        # the fused multiget serves every read in the batch, but a span needs
        # ONE parent — attach store-side spans to the first traced request
        ctx = next((c for _, _, _, _, c in reads if c is not None), None)
        prev = TRACER.activate(ctx) if ctx is not None else None
        try:
            values = self.store.multiget(ids)
        except Exception as exc:  # fail the whole batch, keep serving
            for _, _, fut, _, _ in reads:
                fut.set_exception(exc)
            return
        finally:
            if ctx is not None:
                TRACER.restore(prev)
        for (kind, _, fut, _, _), (lo, hi) in zip(reads, spans):
            fut.set_result(values[lo] if kind == "get" else values[lo:hi])
