"""Micro-batching request service over a CompressedStringStore.

High-volume point-lookup traffic arrives one id at a time; decoding one
string per kernel launch wastes the batch axis the Pallas decoder
parallelises over. :class:`StoreService` coalesces concurrent lookups: a
single worker thread drains the request queue, waits up to ``max_wait_s``
for the batch to fill (classic micro-batching latency/throughput knob), and
answers the whole batch with ONE ``store.multiget`` — one padded kernel
invocation per touched length bucket.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future

from repro.core.metrics import LatencyReservoir
from repro.store.store import CompressedStringStore

_POLL_S = 0.05  # idle wakeup so close() is prompt even with no traffic


class StoreService:
    """Thread-safe coalescing front-end: ``submit(i) -> Future[bytes]``."""

    def __init__(self, store: CompressedStringStore, max_batch: int = 256,
                 max_wait_s: float = 0.0005):
        self.store = store
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._stop = threading.Event()
        self._submit_lock = threading.Lock()  # orders submit() vs close()
        self._lat_lock = threading.Lock()
        self._lat = LatencyReservoir()
        self.requests = 0
        self.batches = 0
        self.coalesced = 0          # requests answered in a batch of > 1
        self.max_batch_seen = 0
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="store-service")
        self._worker.start()

    # ----------------------------------------------------------------- client
    def submit(self, i: int) -> "Future[bytes]":
        """Enqueue a point lookup; resolves to the decoded string.

        Out-of-range ids fail their own future immediately instead of
        poisoning the coalesced batch they would have joined.
        """
        fut: Future = Future()
        i = int(i)
        if not 0 <= i < self.store.n_strings:
            fut.set_exception(IndexError(
                f"string id {i} out of range [0, {self.store.n_strings})"))
            return fut
        # atomic vs close(): either we enqueue before the shutdown sentinel,
        # or we observe _stop and fail fast — never an unresolved Future
        with self._submit_lock:
            if self._stop.is_set():
                fut.set_exception(RuntimeError("service is closed"))
                return fut
            self.requests += 1
            self._q.put((i, fut, time.perf_counter()))
        return fut

    def get(self, i: int, timeout: float | None = 30.0) -> bytes:
        return self.submit(i).result(timeout)

    def multiget(self, ids, timeout: float | None = 30.0) -> list[bytes]:
        futures = [self.submit(i) for i in ids]
        return [f.result(timeout) for f in futures]

    def close(self) -> None:
        with self._submit_lock:
            self._stop.set()
            self._q.put(None)  # wake the worker; nothing enqueues after this
        self._worker.join(timeout=5.0)

    def __enter__(self) -> "StoreService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        with self._lat_lock:
            lat = self._lat.summary()
        return {"requests": self.requests, "batches": self.batches,
                "coalesced": self.coalesced,
                "avg_batch": round(self.requests / self.batches, 2)
                if self.batches else 0.0,
                "max_batch_seen": self.max_batch_seen,
                "request_latency": lat}

    # ----------------------------------------------------------------- worker
    def _collect_batch(self, first) -> list:
        """Wait up to max_wait_s for the batch to fill, then drain whatever
        is immediately available."""
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            try:
                item = (self._q.get(timeout=remaining) if remaining > 0
                        else self._q.get_nowait())
            except queue.Empty:
                break
            if item is None:
                self._stop.set()
                break
            batch.append(item)
        return batch

    def _drain_and_fail(self) -> None:
        """Fail any request that raced past submit()'s closed check and landed
        behind the shutdown sentinel — never leave a Future unresolved."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                return
            if item is not None:
                item[1].set_exception(RuntimeError("service is closed"))

    def _run(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=_POLL_S)
            except queue.Empty:
                if self._stop.is_set():
                    self._drain_and_fail()
                    return
                continue
            if item is None:
                if self._stop.is_set():
                    self._drain_and_fail()
                    return
                continue
            batch = self._collect_batch(item)
            ids = [i for i, _, _ in batch]
            try:
                values = self.store.multiget(ids)
            except Exception as exc:  # fail the whole batch, keep serving
                for _, fut, _ in batch:
                    fut.set_exception(exc)
            else:
                done = time.perf_counter()
                with self._lat_lock:
                    for _, _, t in batch:
                        self._lat.record(done - t)
                if len(batch) > 1:
                    self.coalesced += len(batch)
                self.batches += 1
                self.max_batch_seen = max(self.max_batch_seen, len(batch))
                for (_, fut, _), val in zip(batch, values):
                    fut.set_result(val)
