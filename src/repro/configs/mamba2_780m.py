"""mamba2-780m [ssm]: attention-free SSD (state-space duality). 48L
d_model=1536 vocab=50280 ssm_state=128 [arXiv:2405.21060; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
)
