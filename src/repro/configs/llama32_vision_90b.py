"""llama-3.2-vision-90b [vlm]: 100L = 20 blocks of [1 gated cross-attn +
4 self-attn]; vision frontend is a STUB — input_specs() provides precomputed
(B, 1601, d_model) patch embeddings. d_model=8192 64H (kv=8) d_ff=28672
vocab=128256 [hf:meta-llama/Llama-3.2-11B-Vision scaled; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    cross_attn_period=5, n_vision_tokens=1601, rope_theta=500_000.0,
)
