"""whisper-medium [audio]: encoder-decoder; conv frontend is a STUB —
input_specs() provides precomputed (B, 1500, d_model) frame embeddings.
24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]. Positional encoding stubbed with RoPE
(DESIGN.md §Arch-applicability)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    enc_layers=24, enc_seq=1500, rope_theta=10_000.0,
)
