"""Architecture registry: one module per assigned architecture (exact
published configs) plus the paper's own benchmark configuration."""

from repro.configs import (gemma2_2b, h2o_danube_1p8b, jamba_1p5_large,
                           llama32_vision_90b, mamba2_780m, mixtral_8x22b,
                           qwen1p5_4b, qwen3_moe_30b_a3b, whisper_medium,
                           yi_9b)
from repro.models.config import SHAPES, ArchConfig, ShapeConfig

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (yi_9b, h2o_danube_1p8b, gemma2_2b, qwen1p5_4b, whisper_medium,
              llama32_vision_90b, qwen3_moe_30b_a3b, mixtral_8x22b,
              jamba_1p5_large, mamba2_780m)
}


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells minus documented long_500k skips (DESIGN §5)."""
    cells = []
    for name, cfg in REGISTRY.items():
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.supports_long_decode:
                continue
            cells.append((name, sname))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for name, cfg in REGISTRY.items():
        if not cfg.supports_long_decode:
            out.append((name, "long_500k",
                        "pure full-attention: unbounded per-token cost"))
    return out
