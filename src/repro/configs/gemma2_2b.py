"""gemma2-2b [dense]: alternating local(4k SWA)/global attention, logit
softcaps, tied embeddings, 256k vocab. 26L d_model=2304 8H (kv=4) d_ff=9216
[arXiv:2408.00118; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    local_global_period=2, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True, rope_theta=10_000.0,
)
