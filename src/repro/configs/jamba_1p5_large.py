"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave (1 attn per
8-layer block), MoE 16 experts top-2 on every 2nd layer. 72L d_model=8192
64H (kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf]. Mamba layers use
the SSD formulation (state=16, expand=2, head_dim=64)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2,
    attn_period=8, ssm_state=16, ssm_expand=2, ssm_head_dim=64,
    rope_theta=10_000.0,
)
