"""Pallas TPU kernels for OnPair16 decompression (paper §3.5, Algorithm 3).

TPU adaptation (DESIGN.md §3): the whole OnPair16 dictionary — (65536, 16)
byte matrix + length table, ~4.25 MiB as int32 — fits in VMEM (16 MiB/core),
so decode is a *VMEM-resident gather*. Two kernels:

* ``decode_gather``  — throughput variant: grid over token tiles; each tile
  gathers its fixed 16-byte rows + lengths. The ragged compaction (exclusive
  prefix-sum + masked scatter) happens outside in jnp, mirroring the paper's
  two-stage "copy 16 unconditionally, fix up after" split.
* ``decode_compact`` — latency variant (random access): grid over strings;
  a sequential loop performs Algorithm 3 verbatim — unconditional fixed-size
  16-byte store at the output cursor, advance by the token's true length.

Both are validated in interpret mode against repro.kernels.ref oracles and
the Python reference decoder (tests/test_kernels.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU container: interpret mode executes the kernel body.


# ------------------------------------------------------------- gather kernel
def _gather_kernel(tok_ref, mat_ref, lent_ref, rows_ref, lens_ref):
    toks = tok_ref[...]                    # (TB,)  token ids in this tile
    mat = mat_ref[...]                     # (N, 16) VMEM-resident dictionary
    lent = lent_ref[...]                   # (N,)
    rows_ref[...] = jnp.take(mat, toks, axis=0)
    lens_ref[...] = jnp.take(lent, toks, axis=0)


@partial(jax.jit, static_argnames=("tile",))
def decode_gather(tokens: jnp.ndarray, mat16: jnp.ndarray, lens: jnp.ndarray,
                  tile: int = 1024):
    """Phase-1 decode: tokens int32[T] -> (rows int32[T,16], lens int32[T]).

    T must be a multiple of ``tile`` (pad tokens with 0; the padding rows are
    masked out by the caller's prefix-sum phase).
    """
    T = tokens.shape[0]
    assert T % tile == 0, "pad the token stream to a tile multiple"
    N = mat16.shape[0]
    grid = (T // tile,)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((N, 16), lambda i: (0, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, 16), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
        ],
        interpret=INTERPRET,
    )(tokens, mat16, lens)


@partial(jax.jit, static_argnames=("max_out", "tile"))
def decode_tokens_pallas(tokens: jnp.ndarray, n_tokens: jnp.ndarray,
                         mat16: jnp.ndarray, lens: jnp.ndarray,
                         max_out: int, tile: int = 1024):
    """Full two-phase decode of one padded token stream.

    Phase 1 = Pallas gather kernel; phase 2 = prefix-sum + masked scatter
    (pure jnp — XLA fuses it; on TPU this is the vector-unit-friendly
    replacement for sequential output appends).
    """
    T = tokens.shape[0]
    rows, tl = decode_gather(tokens, mat16, lens, tile=tile)
    valid = jnp.arange(T, dtype=jnp.int32) < n_tokens
    tl = jnp.where(valid, tl, 0)
    ends = jnp.cumsum(tl)
    starts = ends - tl
    out_len = ends[-1] if T > 0 else jnp.int32(0)
    j = jnp.arange(16, dtype=jnp.int32)
    idx = starts[:, None] + j[None, :]
    mask = (j[None, :] < tl[:, None]) & valid[:, None]
    idx_safe = jnp.where(mask, idx, max_out)
    out = jnp.zeros(max_out + 1, dtype=jnp.int32)
    out = out.at[idx_safe.reshape(-1)].set(rows.reshape(-1), mode="drop")
    return out[:max_out], out_len


# ------------------------------------------------------------ compact kernel
def _compact_kernel(tok_ref, n_ref, mat_ref, lent_ref, out_ref, olen_ref):
    """Algorithm 3 per string: fixed 16-byte store, advance by true length."""
    out_ref[...] = jnp.zeros_like(out_ref)
    n = n_ref[0]

    def body(state):
        t, pos = state
        tok = tok_ref[0, t]
        row = mat_ref[tok, pl.dslice(0, 16)]                  # one dict row
        out_ref[0, pl.dslice(pos, 16)] = row                  # SIMD-style copy
        return t + 1, pos + lent_ref[tok]

    _, total = jax.lax.while_loop(lambda s: s[0] < n, body,
                                  (jnp.int32(0), jnp.int32(0)))
    olen_ref[0] = total


@partial(jax.jit, static_argnames=("max_out",))
def decode_compact(tokens: jnp.ndarray, n_tokens: jnp.ndarray,
                   mat16: jnp.ndarray, lens: jnp.ndarray, max_out: int):
    """Per-string sequential decode: tokens int32[B,T] -> (out int32[B,max_out+16],
    out_len int32[B]). Grid = strings (each string decodes independently —
    the paper's random-access property is the parallelism axis)."""
    B, T = tokens.shape
    N = mat16.shape[0]
    out, olen = pl.pallas_call(
        _compact_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((N, 16), lambda i: (0, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, max_out + 16), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, max_out + 16), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=INTERPRET,
    )(tokens, n_tokens, mat16, lens)
    return out[:, :max_out], olen
