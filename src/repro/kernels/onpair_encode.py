"""Pallas TPU kernel for OnPair16 parsing/compression (paper §3.3-3.4).

The static LPM structures (short-pattern hash table, prefix table, suffix
buckets — repro.core.packed) total well under VMEM capacity, so the whole
matcher state is VMEM-resident: the kernel loads every table once and runs
the greedy longest-prefix-match loop per string. Strings are independent
(the paper's random-access property), so the grid is the batch dimension.

The in-kernel search is literally repro.kernels.ref._lpm_search_ref — the
oracle and the kernel share one implementation of Algorithm 1/2, so the only
thing the kernel adds is the VMEM staging + grid decomposition.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import DeviceDict, _lpm_search_ref

INTERPRET = True  # CPU container: interpret mode executes the kernel body.


def _encode_kernel(s_probe_max, p_probe_max, max_bucket,
                   data_ref, len_ref,
                   s_lo_ref, s_hi_ref, s_len_ref, s_tok_ref,
                   p_lo_ref, p_hi_ref, p_len_ref, p_bucket_ref,
                   bstart_ref, bsize_ref,
                   suf_lo_ref, suf_hi_ref, suf_len_ref, suf_tok_ref,
                   toks_ref, ntok_ref):
    toks_ref[...] = jnp.zeros_like(toks_ref)
    # Stage the full matcher state out of the refs (VMEM residency).
    dd = DeviceDict(
        mat16=jnp.zeros((1, 16), jnp.int32), lens=jnp.zeros((1,), jnp.int32),
        s_lo=s_lo_ref[...], s_hi=s_hi_ref[...],
        s_len=s_len_ref[...], s_tok=s_tok_ref[...],
        p_lo=p_lo_ref[...], p_hi=p_hi_ref[...],
        p_len=p_len_ref[...], p_bucket=p_bucket_ref[...],
        bucket_start=bstart_ref[...], bucket_size=bsize_ref[...],
        suf_lo=suf_lo_ref[...], suf_hi=suf_hi_ref[...],
        suf_len=suf_len_ref[...], suf_tok=suf_tok_ref[...],
        s_probe_max=s_probe_max, p_probe_max=p_probe_max,
        max_bucket=max_bucket,
    )
    data_row = data_ref[0, :]
    str_len = len_ref[0]
    max_tokens = toks_ref.shape[1]

    def cond(state):
        pos, count = state
        return (pos < str_len) & (count < max_tokens)

    def body(state):
        pos, count = state
        tok, mlen = _lpm_search_ref(data_row, pos, str_len, dd)
        toks_ref[0, pl.dslice(count, 1)] = tok[None]
        return pos + mlen, count + 1

    _, n = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0)))
    ntok_ref[0] = n


@partial(jax.jit, static_argnames=("max_tokens",))
def encode_batch_pallas(data: jnp.ndarray, str_lens: jnp.ndarray,
                        dd: DeviceDict, max_tokens: int):
    """Compress a padded batch: data int32[B, L+16] (zero-padded byte values).

    Returns (tokens int32[B, max_tokens], n_tokens int32[B]).
    """
    B, Lp = data.shape
    S = dd.s_lo.shape[0]
    P = dd.p_lo.shape[0]
    NB = dd.bucket_start.shape[0]
    M = dd.suf_lo.shape[0]

    def full(shape):
        rank = len(shape)
        return pl.BlockSpec(shape, lambda i, _r=rank: (0,) * _r)

    kernel = partial(_encode_kernel, dd.s_probe_max, dd.p_probe_max,
                     dd.max_bucket)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Lp), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            full((S,)), full((S,)), full((S,)), full((S,)),
            full((P,)), full((P,)), full((P,)), full((P,)),
            full((NB,)), full((NB,)),
            full((M,)), full((M,)), full((M,)), full((M,)),
        ],
        out_specs=[
            pl.BlockSpec((1, max_tokens), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, max_tokens), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=INTERPRET,
    )(data, str_lens,
      dd.s_lo, dd.s_hi, dd.s_len, dd.s_tok,
      dd.p_lo, dd.p_hi, dd.p_len, dd.p_bucket,
      dd.bucket_start, dd.bucket_size,
      dd.suf_lo, dd.suf_hi, dd.suf_len, dd.suf_tok)
