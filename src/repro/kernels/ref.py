"""Pure-jnp oracles for the OnPair kernels (DESIGN.md §3).

These are the reference semantics the Pallas kernels are validated against,
and double as the jittable batch encode/decode used on the host/CPU path.

Byte convention: JAX-side "bytes" are int32 arrays of values 0..255 (default
JAX has no u64 and TPU u8 compute is awkward; packing happens in u32 pairs,
exactly mirroring repro.core.packed). All hashes are bit-identical to
repro.core.packed.mix32 / hash_key.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import PackedDictionary



def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3-style finaliser; must match repro.core.packed.mix32."""
    x = x.astype(jnp.uint32)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_key(lo: jnp.ndarray, hi: jnp.ndarray, length: jnp.ndarray) -> jnp.ndarray:
    return mix32(lo ^ mix32(hi ^ mix32(length.astype(jnp.uint32))))


def ctz32(x: jnp.ndarray) -> jnp.ndarray:
    """Count trailing zeros (32 for x == 0) via popcount((x & -x) - 1)."""
    x = x.astype(jnp.uint32)
    low = x & (jnp.uint32(0) - x)          # isolate lowest set bit
    return jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)


def shared_prefix_bytes(lo1, hi1, lo2, hi2) -> jnp.ndarray:
    """Algorithm 2 on (lo, hi) u32 pairs: # of matching low-order bytes."""
    dlo = (lo1 ^ lo2).astype(jnp.uint32)
    dhi = (hi1 ^ hi2).astype(jnp.uint32)
    tz_lo = ctz32(dlo) >> 3          # 0..4 (4 if dlo == 0)
    tz_hi = ctz32(dhi) >> 3          # 0..4
    return jnp.where(dlo != 0, jnp.minimum(tz_lo, 4),
                     4 + jnp.minimum(tz_hi, 4)).astype(jnp.int32)


@dataclass(frozen=True)
class DeviceDict:
    """PackedDictionary uploaded as device arrays (static LPM + decode)."""

    # decode
    mat16: jnp.ndarray       # int32[N, 16]   byte values
    lens: jnp.ndarray        # int32[N]
    # short tier
    s_lo: jnp.ndarray        # uint32[S]
    s_hi: jnp.ndarray
    s_len: jnp.ndarray       # int32[S] (0 = empty)
    s_tok: jnp.ndarray       # int32[S]
    # long tier
    p_lo: jnp.ndarray        # uint32[P]
    p_hi: jnp.ndarray
    p_len: jnp.ndarray       # int32[P] (0 = empty, 8 = occupied)
    p_bucket: jnp.ndarray    # int32[P]
    bucket_start: jnp.ndarray
    bucket_size: jnp.ndarray
    suf_lo: jnp.ndarray      # uint32[M]
    suf_hi: jnp.ndarray
    suf_len: jnp.ndarray     # int32[M]
    suf_tok: jnp.ndarray     # int32[M]
    # static probe bounds / sizes (python ints -> static under jit)
    s_probe_max: int
    p_probe_max: int
    max_bucket: int

    @staticmethod
    def build(d: PackedDictionary) -> "DeviceDict":
        return DeviceDict(
            mat16=jnp.asarray(d.mat16.astype(np.int32)),
            lens=jnp.asarray(d.lens.astype(np.int32)),
            s_lo=jnp.asarray(d.s_lo), s_hi=jnp.asarray(d.s_hi),
            s_len=jnp.asarray(d.s_len), s_tok=jnp.asarray(d.s_tok),
            p_lo=jnp.asarray(d.p_lo), p_hi=jnp.asarray(d.p_hi),
            p_len=jnp.asarray(d.p_len), p_bucket=jnp.asarray(d.p_bucket),
            bucket_start=jnp.asarray(d.bucket_start),
            bucket_size=jnp.asarray(d.bucket_size),
            suf_lo=jnp.asarray(d.suf_lo), suf_hi=jnp.asarray(d.suf_hi),
            suf_len=jnp.asarray(d.suf_len), suf_tok=jnp.asarray(d.suf_tok),
            s_probe_max=int(d.s_probe_max), p_probe_max=int(d.p_probe_max),
            max_bucket=int(max(1, d.max_bucket_size)),
        )


jax.tree_util.register_pytree_node(
    DeviceDict,
    lambda d: ((d.mat16, d.lens, d.s_lo, d.s_hi, d.s_len, d.s_tok,
                d.p_lo, d.p_hi, d.p_len, d.p_bucket, d.bucket_start,
                d.bucket_size, d.suf_lo, d.suf_hi, d.suf_len, d.suf_tok),
               (d.s_probe_max, d.p_probe_max, d.max_bucket)),
    lambda aux, ch: DeviceDict(*ch, s_probe_max=aux[0], p_probe_max=aux[1],
                               max_bucket=aux[2]),
)


# ============================================================ decode oracle
def decode_ref(tokens: jnp.ndarray, n_tokens: jnp.ndarray,
               mat16: jnp.ndarray, lens: jnp.ndarray,
               max_out: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two-phase TPU-native decode of one token stream.

    Phase 1: gather fixed 16-byte rows + lengths (the paper's fixed-size-copy
    insight as a dense gather). Phase 2: exclusive prefix-sum of lengths and
    a masked scatter to compact the ragged rows into a byte stream.

    Returns (out bytes int32[max_out], out_len int32).
    """
    T = tokens.shape[0]
    valid = jnp.arange(T, dtype=jnp.int32) < n_tokens
    tl = jnp.where(valid, lens[tokens], 0).astype(jnp.int32)
    ends = jnp.cumsum(tl)
    starts = ends - tl
    out_len = ends[-1] if T > 0 else jnp.int32(0)
    rows = mat16[tokens]                                   # (T, 16)
    j = jnp.arange(16, dtype=jnp.int32)
    idx = starts[:, None] + j[None, :]
    mask = (j[None, :] < tl[:, None]) & valid[:, None]
    idx_safe = jnp.where(mask, idx, max_out)               # dump lane
    out = jnp.zeros(max_out + 1, dtype=jnp.int32)
    out = out.at[idx_safe.reshape(-1)].set(rows.reshape(-1), mode="drop")
    return out[:max_out], out_len


def decode_batch_ref(tokens: jnp.ndarray, n_tokens: jnp.ndarray,
                     mat16: jnp.ndarray, lens: jnp.ndarray,
                     max_out: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """vmap of decode_ref over a batch: tokens int32[B, T]."""
    return jax.vmap(decode_ref, in_axes=(0, 0, None, None, None))(
        tokens, n_tokens, mat16, lens, max_out)


# ============================================================ encode oracle
def _pack_window(window: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack 8 byte-values (int32[8]) little-endian into (lo, hi) u32."""
    w = window.astype(jnp.uint32)
    lo = w[0] | (w[1] << 8) | (w[2] << 16) | (w[3] << 24)
    hi = w[4] | (w[5] << 8) | (w[6] << 16) | (w[7] << 24)
    return lo, hi


def _probe_table(lo, hi, length, t_lo, t_hi, t_len, t_payload, probe_max: int):
    """Linear-probe an open-addressing table; returns payload or -1.

    Probing stops at the first empty slot (len == 0) — matching insertion —
    and is bounded by the build-time max probe count, so the loop is static.
    """
    size = t_lo.shape[0]
    mask = jnp.uint32(size - 1)
    slot0 = hash_key(lo, hi, length) & mask

    def body(i, carry):
        found, done = carry
        slot = (slot0 + i.astype(jnp.uint32)) & mask
        sl = t_len[slot]
        hit = (sl == length) & (t_lo[slot] == lo) & (t_hi[slot] == hi)
        empty = sl == 0
        found = jnp.where(~done & hit, t_payload[slot], found)
        done = done | hit | empty
        return found, done

    found, _ = jax.lax.fori_loop(
        0, probe_max, lambda i, c: body(i, c),
        (jnp.int32(-1), jnp.bool_(False)))
    return found


def _lpm_search_ref(data_row: jnp.ndarray, pos: jnp.ndarray, str_len: jnp.ndarray,
                    dd: DeviceDict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 1 at one position; data_row is int32[L+16] zero-padded.

    Returns (token_id, match_len). Requires all 256 single bytes present.
    """
    rem = str_len - pos
    w1 = jax.lax.dynamic_slice(data_row, (pos,), (8,))
    lo1, hi1 = _pack_window(w1)

    # ---- long tier ----
    w2 = jax.lax.dynamic_slice(data_row, (pos + 8,), (8,))
    lo2, hi2 = _pack_window(w2)
    bucket = _probe_table(lo1, hi1, jnp.int32(8), dd.p_lo, dd.p_hi, dd.p_len,
                          dd.p_bucket, dd.p_probe_max)
    use_long = (rem > 8) & (bucket >= 0)
    b = jnp.maximum(bucket, 0)
    start = dd.bucket_start[b]
    size = jnp.where(use_long, dd.bucket_size[b], 0)

    def bucket_body(k, carry):
        tok, mlen, done = carry
        i = start + k
        in_range = k < size
        s_len = dd.suf_len[i]
        fits = s_len <= (rem - 8)
        shared = shared_prefix_bytes(lo2, hi2, dd.suf_lo[i], dd.suf_hi[i])
        # OnPair16: suffixes are <= 8 B so the packed compare is exact.
        hit = in_range & fits & (shared >= s_len) & ~done
        tok = jnp.where(hit, dd.suf_tok[i], tok)
        mlen = jnp.where(hit, 8 + s_len, mlen)
        done = done | hit | ~in_range
        return tok, mlen, done

    ltok, lmlen, _ = jax.lax.fori_loop(
        0, dd.max_bucket, bucket_body,
        (jnp.int32(-1), jnp.int32(0), jnp.bool_(False)))
    long_found = use_long & (ltok >= 0)

    # ---- short tier: lengths min(rem, 8) .. 1 ----
    max_len = jnp.minimum(rem, 8).astype(jnp.int32)

    def byte_mask(nbytes):
        """uint32 mask covering the low min(nbytes, 4) bytes (0 if <= 0)."""
        nb = jnp.clip(nbytes, 0, 4).astype(jnp.uint32)
        return jnp.where(nb >= 4, jnp.uint32(0xFFFFFFFF),
                         (jnp.uint32(1) << (nb * 8)) - jnp.uint32(1))

    def short_body(i, carry):
        tok, mlen, done = carry
        length = max_len - i
        ok = length >= 1
        lo = lo1 & byte_mask(length)
        hi = hi1 & byte_mask(length - 4)
        cand = _probe_table(lo, hi, length, dd.s_lo, dd.s_hi, dd.s_len,
                            dd.s_tok, dd.s_probe_max)
        hit = ok & (cand >= 0) & ~done
        tok = jnp.where(hit, cand, tok)
        mlen = jnp.where(hit, length, mlen)
        done = done | hit
        return tok, mlen, done

    stok, smlen, _ = jax.lax.fori_loop(
        0, 8, short_body, (jnp.int32(0), jnp.int32(1), jnp.bool_(False)))

    tok = jnp.where(long_found, ltok, stok)
    mlen = jnp.where(long_found, lmlen, smlen)
    return tok.astype(jnp.int32), mlen.astype(jnp.int32)


def encode_ref(data_row: jnp.ndarray, str_len: jnp.ndarray,
               dd: DeviceDict, max_tokens: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy LPM parse of one string (paper §3.3) as a lax.while_loop.

    data_row: int32[L+16] zero-padded byte values. Returns
    (tokens int32[max_tokens], n_tokens int32).
    """
    tokens0 = jnp.zeros(max_tokens, dtype=jnp.int32)

    def cond(state):
        pos, count, _ = state
        return (pos < str_len) & (count < max_tokens)

    def body(state):
        pos, count, toks = state
        tok, mlen = _lpm_search_ref(data_row, pos, str_len, dd)
        toks = toks.at[count].set(tok)
        return pos + mlen, count + 1, toks

    _, n, toks = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0), tokens0))
    return toks, n


def encode_batch_ref(data: jnp.ndarray, str_lens: jnp.ndarray,
                     dd: DeviceDict, max_tokens: int):
    """vmap of encode_ref: data int32[B, L+16]."""
    return jax.vmap(encode_ref, in_axes=(0, 0, None, None))(
        data, str_lens, dd, max_tokens)


# ============================================================ jit wrappers
@partial(jax.jit, static_argnames=("max_out",))
def decode_batch_ref_jit(tokens, n_tokens, mat16, lens, max_out: int):
    return decode_batch_ref(tokens, n_tokens, mat16, lens, max_out)


@partial(jax.jit, static_argnames=("max_tokens",))
def encode_batch_ref_jit(data, str_lens, dd: DeviceDict, max_tokens: int):
    return encode_batch_ref(data, str_lens, dd, max_tokens)
