"""Pallas TPU kernels for the paper's compute hot-spots: OnPair16 parsing
(longest prefix matching) and decompression — with ops.py jit wrappers and
ref.py pure-jnp oracles. Validated in interpret mode on CPU."""
