"""Public jit'd entry points over the OnPair kernels.

Bridges host-side types (PackedDictionary, list[bytes]) to the padded device
layouts the kernels consume. Used by the serving path (on-device
detokenisation) and by the benchmark harness; tests validate every path
against repro.kernels.ref and the Python reference implementations.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.packed import PackedDictionary
from repro.kernels import onpair_decode, onpair_encode
from repro.kernels.ref import (DeviceDict, decode_batch_ref_jit,
                               encode_batch_ref_jit)
from repro.obs import REGISTRY, TRACER, Counter

#: device decode invocations by kernel path — pallas vs the jitted reference
_DECODE_BATCHES = {
    path: REGISTRY.register(Counter("repro_kernel_decode_batches_total",
                                    labels={"path": path}))
    for path in ("pallas", "ref")
}


def _pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


#: geometric byte-length bucket capacities seeding the bucketed encode path;
#: grown by doubling when a longer string arrives, so the set of compiled
#: encode shapes stays bounded no matter the batch mix
_ENCODE_LEN_BUCKETS = (32, 128, 512)
#: static batch dimension of every bucketed encode launch
_ENCODE_PAD_BATCH = 64


def pack_strings(strings: list[bytes], pad_len: int | None = None,
                 pad_extra: int = 16) -> tuple[np.ndarray, np.ndarray]:
    """list[bytes] -> (data int32[B, L+pad_extra], lens int32[B])."""
    L = pad_len if pad_len is not None else max((len(s) for s in strings), default=1)
    data = np.zeros((len(strings), L + pad_extra), dtype=np.int32)
    lens = np.zeros(len(strings), dtype=np.int32)
    for i, s in enumerate(strings):
        b = np.frombuffer(s, dtype=np.uint8)
        data[i, : len(b)] = b
        lens[i] = len(b)
    return data, lens


def pack_token_matrix(token_lists: list[np.ndarray], pad_tokens: int | None = None,
                      pad_batch: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Ragged token streams -> padded (tokens int32[B, T], n_tokens int32[B]).

    The multiget assembly step: ``pad_tokens``/``pad_batch`` pin T and B so a
    serving layer can keep the set of jit-compiled decode shapes small and
    static (length-bucketed batches). Padding rows/tails are zeros with
    n_tokens masking them out.
    """
    B = pad_batch if pad_batch is not None else len(token_lists)
    if B < len(token_lists):
        raise ValueError(f"pad_batch={B} < batch of {len(token_lists)}")
    T = pad_tokens if pad_tokens is not None else max(
        (len(t) for t in token_lists), default=1)
    T = max(T, 1)
    tokens = np.zeros((B, T), dtype=np.int32)
    n_tokens = np.zeros(B, dtype=np.int32)
    for i, t in enumerate(token_lists):
        if len(t) > T:
            raise ValueError(f"stream {i} has {len(t)} tokens > pad_tokens={T}")
        tokens[i, : len(t)] = t
        n_tokens[i] = len(t)
    return tokens, n_tokens


class OnPairDevice:
    """Device-side OnPair16 codec over a trained PackedDictionary."""

    def __init__(self, dictionary: PackedDictionary):
        if not dictionary.variant16:
            raise ValueError("device kernels target OnPair16 (<=16B entries); "
                             "unbounded OnPair stays on the host path")
        self.dictionary = dictionary
        self.dd = DeviceDict.build(dictionary)
        # Bucketed-encode state: every launch uses a static
        # (encode_pad_batch, cap + 16) shape drawn from encode_len_caps, so
        # the number of compiled encode traces is bounded by the bucket set
        # rather than by the batch mix (mirrors the multiget_decode buckets).
        self.encode_len_caps: list[int] = list(_ENCODE_LEN_BUCKETS)
        self.encode_pad_batch: int = _ENCODE_PAD_BATCH
        #: every (B, L) data shape handed to the encode kernels — tests assert
        #: this stays bounded under mixed-length workloads
        self.encode_shapes: set[tuple[int, int]] = set()

    @classmethod
    def from_artifact(cls, artifact) -> "OnPairDevice":
        """Open the device codec straight from a serialized DictArtifact —
        the shipping path: train on one host, save, decode on another."""
        from repro.core import registry
        if not registry.capabilities(artifact.codec).device_decodable:
            raise ValueError(
                f"codec {artifact.codec!r} is not device-decodable "
                "(registry capability); only bounded-entry token-stream "
                "dictionaries run on the kernels")
        return cls(PackedDictionary.build(artifact.entries))

    # ----------------------------------------------------------- encode
    def encode_batch(self, strings: list[bytes], use_pallas: bool = True,
                     max_tokens: int | None = None,
                     pad_len: int | None = None):
        """Compress a batch; returns (tokens int32[B,T], n_tokens int32[B]).

        With no ``pad_len``/``max_tokens`` the data width (and hence the jit
        trace) follows the longest string in the batch — fine for one-off
        calls, unbounded retraces under a mixed workload. Serving paths go
        through :meth:`encode_bucketed`, which pins both.
        """
        data, lens = pack_strings(strings, pad_len=pad_len)
        if max_tokens is None:
            max_tokens = data.shape[1] - 16 or 1
        self.encode_shapes.add((data.shape[0], data.shape[1]))
        fn = (onpair_encode.encode_batch_pallas if use_pallas
              else encode_batch_ref_jit)
        toks, n = fn(jnp.asarray(data), jnp.asarray(lens), self.dd, max_tokens)
        return np.asarray(toks), np.asarray(n)

    def _encode_cap(self, n: int) -> int:
        """Smallest bucket capacity >= n bytes, growing the set by doubling."""
        for cap in self.encode_len_caps:
            if n <= cap:
                return cap
        cap = self.encode_len_caps[-1]
        while cap < n:
            cap *= 2
            self.encode_len_caps.append(cap)
        return cap

    def encode_bucketed(self, strings: list[bytes],
                        use_pallas: bool = True) -> list[np.ndarray]:
        """Batch encode with a bounded set of compiled shapes.

        Strings are grouped into geometric byte-length buckets; each group is
        padded (with empty rows) to ``encode_pad_batch`` and encoded at the
        static shape (pad_batch, cap + 16) with ``max_tokens = cap`` (one
        token per byte is the worst case). Returns the per-string int32 token
        arrays in input order.
        """
        out: list[np.ndarray] = [None] * len(strings)  # type: ignore[list-item]
        pb = self.encode_pad_batch
        groups: dict[int, list[int]] = {}
        for i, s in enumerate(strings):
            groups.setdefault(self._encode_cap(max(len(s), 1)), []).append(i)
        for cap, idxs in sorted(groups.items()):
            for k in range(0, len(idxs), pb):
                sel = idxs[k : k + pb]
                chunk = [strings[i] for i in sel] + [b""] * (pb - len(sel))
                toks, n = self.encode_batch(chunk, use_pallas=use_pallas,
                                            max_tokens=cap, pad_len=cap)
                for j, i in enumerate(sel):
                    out[i] = toks[j, : n[j]]
        return out

    def warm_encode(self, use_pallas: bool = True) -> None:
        """AOT-compile every current encode bucket shape (store open time)."""
        for cap in list(self.encode_len_caps):
            self.encode_batch([b""] * self.encode_pad_batch,
                              use_pallas=use_pallas,
                              max_tokens=cap, pad_len=cap)

    def encode_to_bytes(self, strings: list[bytes], use_pallas: bool = True) -> list[bytes]:
        return [t.astype("<u2").tobytes()
                for t in self.encode_bucketed(strings, use_pallas=use_pallas)]

    # ----------------------------------------------------------- decode
    def decode_stream(self, tokens: np.ndarray, use_pallas: bool = True,
                      tile: int = 1024) -> bytes:
        """Decode one token stream (any concatenation of compressed strings)."""
        tokens = np.asarray(tokens, dtype=np.int32)
        n = tokens.size
        max_out = int(self.dictionary.lens[tokens].sum()) if n else 0
        if n == 0:
            return b""
        T = _pad_to(n, tile)
        padded = np.zeros(T, dtype=np.int32)
        padded[:n] = tokens
        if use_pallas:
            out, out_len = onpair_decode.decode_tokens_pallas(
                jnp.asarray(padded), jnp.int32(n), self.dd.mat16, self.dd.lens,
                max_out, tile=tile)
        else:
            from repro.kernels.ref import decode_ref
            import jax
            out, out_len = jax.jit(decode_ref, static_argnames=("max_out",))(
                jnp.asarray(padded), jnp.int32(n), self.dd.mat16, self.dd.lens,
                max_out=max_out)
        out = np.asarray(out[: int(out_len)])
        return out.astype(np.uint8).tobytes()

    def decode_batch(self, tokens: np.ndarray, n_tokens: np.ndarray,
                     max_out: int, use_pallas: bool = True):
        """Batched random-access decode: tokens int32[B,T] -> list[bytes]."""
        tokens = np.asarray(tokens, dtype=np.int32)
        n_tokens = np.asarray(n_tokens, dtype=np.int32)
        path = "pallas" if use_pallas else "ref"
        _DECODE_BATCHES[path].inc()
        with TRACER.span("kernel.decode_batch", path=path,
                         shape=list(tokens.shape)):
            if use_pallas:
                out, olen = onpair_decode.decode_compact(
                    jnp.asarray(tokens), jnp.asarray(n_tokens),
                    self.dd.mat16, self.dd.lens, max_out)
            else:
                out, olen = decode_batch_ref_jit(
                    jnp.asarray(tokens), jnp.asarray(n_tokens),
                    self.dd.mat16, self.dd.lens, max_out)
        out = np.asarray(out)
        olen = np.asarray(olen)
        return [out[i, : olen[i]].astype(np.uint8).tobytes()
                for i in range(out.shape[0])]

    def multiget_decode(self, token_lists: list[np.ndarray],
                        pad_tokens: int | None = None,
                        pad_batch: int | None = None,
                        use_pallas: bool = True) -> list[bytes]:
        """Batched random-access decode of ragged token streams.

        Assembles the padded (B, T) matrix (see :func:`pack_token_matrix`)
        and runs the per-string decode kernel once; max_out = 16 * T is exact
        for OnPair16 (every entry <= 16 B). Returns only the real rows.
        """
        tokens, n_tokens = pack_token_matrix(token_lists, pad_tokens, pad_batch)
        max_out = 16 * tokens.shape[1]
        out = self.decode_batch(tokens, n_tokens, max_out, use_pallas=use_pallas)
        return out[: len(token_lists)]

    def roundtrip(self, strings: list[bytes], use_pallas: bool = True) -> list[bytes]:
        toks, n = self.encode_batch(strings, use_pallas=use_pallas)
        max_out = max((len(s) for s in strings), default=1)
        return self.decode_batch(toks, n, max_out, use_pallas=use_pallas)
