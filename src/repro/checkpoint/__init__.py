"""repro subpackage."""
