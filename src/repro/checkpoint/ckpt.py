"""Fault-tolerant sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             shard_<host>.npz     flattened leaves (this process's shards)
             MANIFEST.json        step, tree paths, shapes, dtypes, commit bit

Guarantees:
* **Atomic commit** — data is written into a `.tmp` directory and renamed
  only after every array is on disk; the MANIFEST is written last. Readers
  only trust renamed directories containing a manifest: a preempted writer
  can never corrupt the latest checkpoint.
* **Async save** — `save_async` snapshots to host memory synchronously
  (cheap) and writes in a background thread, keeping the training loop off
  the critical path of disk I/O.
* **Elastic restore** — arrays are restored by *path*, then device_put with
  the *target* mesh's shardings: a checkpoint taken on (16,16) restores onto
  (2,16,16) or a single CPU transparently (resharding happens at placement).
  Missing/extra paths raise with the offending key names.
* **Retention** — `gc(keep=N)` prunes old steps, never the newest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _to_storable(a: np.ndarray) -> np.ndarray:
    """numpy can't serialise ml_dtypes (bfloat16 etc.) — store as bit-views."""
    if a.dtype.kind not in "biufc":
        return a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,)) \
            if a.dtype.itemsize != 2 else a.view(np.uint16)
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if a.dtype.kind in "biufc" and np.dtype(a.dtype).name == dtype_name:
        return a
    import ml_dtypes
    target = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
    if a.dtype == np.uint16:
        return a.view(target)
    return a.reshape(a.shape[:-1] + (-1,)).view(target).reshape(a.shape[:-1])


def save(state, step: int, directory: str, host_id: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    storable = {k: _to_storable(a) for k, a in arrays.items()}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **storable)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; one writer at a time."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, state, step: int) -> None:
        snapshot = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(snapshot, step), daemon=True)
        self._thread.start()

    def _write(self, snapshot, step):
        self.last_path = save(snapshot, step, self.directory)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, abstract_state, step: int | None = None,
            shardings=None):
    """Load a checkpoint and place it onto the current device topology.

    `shardings`: optional pytree of NamedSharding matching abstract_state —
    this is the elastic-resharding hook (any mesh shape works).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    arrays: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(path)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    arrays[k] = z[k]
    want = set(_flatten_with_paths(abstract_state))
    have = set(arrays)
    if want != have:
        raise ValueError(f"checkpoint/tree mismatch: missing={sorted(want - have)[:5]} "
                         f"extra={sorted(have - want)[:5]}")
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}

    def build(path_nodes, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_nodes)
        arr = _from_storable(arrays[key], manifest["dtypes"][key])
        arr = arr.astype(leaf.dtype) if arr.dtype != leaf.dtype else arr
        if key in flat_sh:
            return jax.device_put(arr, flat_sh[key])
        return jax.numpy.asarray(arr)

    return (jax.tree_util.tree_map_with_path(build, abstract_state),
            manifest["step"])


def gc(directory: str, keep: int = 3) -> list[str]:
    if not os.path.isdir(directory):
        return []
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(directory, n, "MANIFEST.json")))
    removed = []
    for s in steps[:-keep] if keep else steps:
        p = os.path.join(directory, f"step_{s:08d}")
        shutil.rmtree(p)
        removed.append(p)
    return removed
