"""Deterministic, host-sharded token batch pipeline.

Design (1000+ node posture):

* **Global shuffle, random access** — documents are sampled by a seeded
  permutation over the compressed store (OnPair's per-string independence is
  what makes random-access sampling free; block-compressed corpora would pay
  a block decode per draw).
* **Host sharding** — host ``h`` of ``H`` owns rows ``[h*B/H, (h+1)*B/H)`` of
  every global batch; no host ever materialises another host's shard.
* **Deterministic resume** — batch ``k`` is a pure function of
  (seed, k, host): after a restart the loop continues from the checkpointed
  step with identical data order. No iterator state needs checkpointing.
* **Sequence packing** — documents are concatenated (EOS-separated) into a
  per-row stream and sliced into fixed (seq_len + 1) windows; targets are the
  usual one-token shift. A per-row document cursor derived from the step
  index keeps packing deterministic without global coordination.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tokenizer import EOS_ID, PAD_ID
from repro.data.corpus import CompressedCorpusStore


@dataclass
class BatchSpec:
    global_batch: int
    seq_len: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class TokenPipeline:
    """Maps (step, row) -> token window, deterministically."""

    def __init__(self, store: CompressedCorpusStore, spec: BatchSpec):
        self.store = store
        self.spec = spec
        # Document order: one global permutation per epoch, derived from seed.
        self._n_docs = store.n_docs
        self._doc_lens = store.doc_lengths_tokens() + 1  # +1 for EOS

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.spec.seed, epoch))
        return rng.permutation(self._n_docs)

    def _row_stream(self, row: int, need: int, start_doc: int, epoch: int) -> np.ndarray:
        """Concatenate EOS-separated docs from the permuted order until
        ``need`` tokens are available, starting at document ``start_doc``."""
        perm = self._epoch_perm(epoch)
        out = np.empty(need + 4096, dtype=np.int32)
        n = 0
        d = start_doc
        while n < need:
            doc = self.store.doc_tokens(int(perm[d % self._n_docs]))
            take = doc.size + 1
            if n + take > out.size:
                out = np.concatenate([out, np.empty(need + take, np.int32)])
            out[n : n + doc.size] = doc
            out[n + doc.size] = EOS_ID
            n += take
            d += 1
        return out[:need]

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Host-local slice of global batch ``step``.

        Returns {"tokens": (host_batch, seq_len) int32,
                 "targets": (host_batch, seq_len) int32}.
        """
        spec = self.spec
        need = spec.seq_len + 1
        hb = spec.host_batch
        tokens = np.empty((hb, need), dtype=np.int32)
        # Row r of the global batch advances through its own document lane:
        # lane = global_row, cursor = step * docs_per_step_estimate. Using a
        # per-(step,row) seeded draw keeps rows independent and resumable.
        avg_len = max(8.0, float(self._doc_lens.mean()))
        docs_per_window = int(np.ceil(need / avg_len)) + 1
        for r in range(hb):
            grow = spec.host_id * hb + r
            lane_offset = grow * 1_000_003  # de-correlate lanes
            start_doc = lane_offset + step * docs_per_window
            epoch = (step * docs_per_window * spec.global_batch) // max(1, self._n_docs)
            tokens[r] = self._row_stream(grow, need, start_doc, epoch)
        return {"tokens": tokens[:, :-1].copy(),
                "targets": tokens[:, 1:].copy()}

    def padded_eval_batch(self, texts: list[bytes], seq_len: int) -> dict[str, np.ndarray]:
        """Tokenize + pad raw strings (serving/eval path)."""
        ids = self.store.tokenizer.encode_batch(texts, bos=True)
        out = np.full((len(texts), seq_len), PAD_ID, dtype=np.int32)
        for i, seq in enumerate(ids):
            n = min(seq.size, seq_len)
            out[i, :n] = seq[:n]
        return {"tokens": out,
                "lengths": np.array([min(len(s), seq_len) for s in ids],
                                    dtype=np.int32)}
