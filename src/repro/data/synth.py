"""Synthetic analogues of the paper's five datasets (Table 2).

The paper's corpora (Amazon Book Reviews/Titles, ABC News Headlines, Tweets,
OpenWebText URLs) are not available offline, so we generate seeded synthetic
corpora engineered to match their *structural* statistics — average string
length, token redundancy profile, shared-prefix skew (URLs), and vocabulary
shape — the properties the algorithms actually interact with. All generators
are deterministic in (seed, size).

| name           | analogue       | avg len | character                        |
|----------------|----------------|---------|----------------------------------|
| book_titles    | Book Titles    |  ~52 B  | Zipfian word mix, catalog noise  |
| book_reviews   | Book Reviews   | ~420 B  | long natural-ish sentences       |
| news_headlines | News Headlines |  ~41 B  | short Zipfian word strings       |
| tweets         | Tweets         |  ~74 B  | words + handles + hashtags + urls|
| urls           | URLs           |  ~84 B  | few domains, deep shared prefixes|
"""

from __future__ import annotations

import numpy as np

_CONSONANTS = np.frombuffer(b"bcdfghjklmnpqrstvwz", dtype=np.uint8)
_VOWELS = np.frombuffer(b"aeiou", dtype=np.uint8)


def _word_vocab(rng: np.random.Generator, n: int, min_syl=1, max_syl=4) -> list[bytes]:
    """Pronounceable pseudo-words: CV(C) syllables — realistic byte bigrams."""
    words = []
    for _ in range(n):
        syl = rng.integers(min_syl, max_syl + 1)
        w = bytearray()
        for _ in range(syl):
            w.append(int(rng.choice(_CONSONANTS)))
            w.append(int(rng.choice(_VOWELS)))
            if rng.random() < 0.3:
                w.append(int(rng.choice(_CONSONANTS)))
        words.append(bytes(w))
    return words


def _zipf_indices(rng: np.random.Generator, n_vocab: int, size: int, a: float = 1.15) -> np.ndarray:
    """Zipf-distributed indices clipped into [0, n_vocab)."""
    idx = rng.zipf(a, size=size) - 1
    return np.minimum(idx, n_vocab - 1)


def gen_book_titles(target_bytes: int, seed: int = 0) -> list[bytes]:
    rng = np.random.default_rng(seed)
    vocab = _word_vocab(rng, 4000, 1, 4)
    series = [b"The " + w.capitalize() for w in _word_vocab(rng, 50, 2, 3)]
    out: list[bytes] = []
    total = 0
    while total < target_bytes:
        nw = int(rng.integers(3, 10))
        words = [vocab[i] for i in _zipf_indices(rng, len(vocab), nw)]
        title = b" ".join(w.capitalize() if rng.random() < 0.7 else w for w in words)
        r = rng.random()
        if r < 0.15:
            title = series[int(rng.integers(len(series)))] + b": " + title
        elif r < 0.25:
            title += b" (Vol. %d)" % int(rng.integers(1, 30))
        elif r < 0.32:
            title += b" - Special Edition"
        out.append(title)
        total += len(title)
    return out


def gen_book_reviews(target_bytes: int, seed: int = 1) -> list[bytes]:
    rng = np.random.default_rng(seed)
    vocab = _word_vocab(rng, 8000, 1, 4)
    stock = [b"I really enjoyed this book", b"would recommend to anyone",
             b"the author writes", b"could not put it down",
             b"a bit slow in the middle", b"five stars", b"not worth the price",
             b"the characters are", b"great read for the summer"]
    out: list[bytes] = []
    total = 0
    while total < target_bytes:
        sentences = []
        for _ in range(int(rng.integers(3, 9))):
            if rng.random() < 0.35:
                sentences.append(stock[int(rng.integers(len(stock)))])
            nw = int(rng.integers(5, 15))
            words = [vocab[i] for i in _zipf_indices(rng, len(vocab), nw)]
            sentences.append(b" ".join(words) + b".")
        review = b" ".join(sentences)
        out.append(review)
        total += len(review)
    return out


def gen_news_headlines(target_bytes: int, seed: int = 2) -> list[bytes]:
    rng = np.random.default_rng(seed)
    vocab = _word_vocab(rng, 3000, 1, 3)
    out: list[bytes] = []
    total = 0
    while total < target_bytes:
        nw = int(rng.integers(4, 9))
        words = [vocab[i] for i in _zipf_indices(rng, len(vocab), nw)]
        h = b" ".join(words)
        out.append(h)
        total += len(h)
    return out


def gen_tweets(target_bytes: int, seed: int = 3) -> list[bytes]:
    rng = np.random.default_rng(seed)
    vocab = _word_vocab(rng, 5000, 1, 3)
    handles = [b"@" + w for w in _word_vocab(rng, 300, 2, 3)]
    tags = [b"#" + w for w in _word_vocab(rng, 200, 1, 3)]
    out: list[bytes] = []
    total = 0
    while total < target_bytes:
        parts: list[bytes] = []
        if rng.random() < 0.3:
            parts.append(handles[int(rng.integers(len(handles)))])
        nw = int(rng.integers(7, 19))
        parts += [vocab[i] for i in _zipf_indices(rng, len(vocab), nw)]
        if rng.random() < 0.4:
            parts.append(tags[int(rng.integers(len(tags)))])
        if rng.random() < 0.15:
            parts.append(b"http://t.co/%08x" % int(rng.integers(1 << 31)))
        t = b" ".join(parts)
        out.append(t)
        total += len(t)
    return out


def gen_urls(target_bytes: int, seed: int = 4) -> list[bytes]:
    """Heavy shared-prefix skew: few domains, deep paths, id-suffix variants —
    the adversarial case for unbounded LPM buckets (paper §3.4.4, §4.7)."""
    rng = np.random.default_rng(seed)
    domains = [b"https://www." + w + bytes(tld) for w, tld in
               zip(_word_vocab(rng, 120, 2, 4),
                   rng.choice([b".com", b".org", b".net", b".io"], 120))]
    segs = _word_vocab(rng, 600, 2, 4)
    out: list[bytes] = []
    total = 0
    while total < target_bytes:
        d = domains[int(_zipf_indices(rng, len(domains), 1)[0])]
        depth = int(rng.integers(2, 7))
        path = b"/".join(segs[i] for i in _zipf_indices(rng, len(segs), depth))
        url = d + b"/" + path
        r = rng.random()
        if r < 0.35:
            url += b"/item_id_%06d" % int(rng.integers(1000000))
        elif r < 0.5:
            url += b"?page=%d&ref=%s" % (int(rng.integers(50)),
                                         segs[int(rng.integers(len(segs)))])
        out.append(url)
        total += len(url)
    return out


DATASETS = {
    "book_titles": gen_book_titles,
    "book_reviews": gen_book_reviews,
    "news_headlines": gen_news_headlines,
    "tweets": gen_tweets,
    "urls": gen_urls,
}


def load_dataset(name: str, target_bytes: int = 8 << 20, seed: int | None = None) -> list[bytes]:
    gen = DATASETS[name]
    if seed is None:
        return gen(target_bytes)
    return gen(target_bytes, seed=seed)


def dataset_stats(strings: list[bytes]) -> dict:
    lens = np.array([len(s) for s in strings])
    return {"rows": len(strings), "bytes": int(lens.sum()),
            "avg_len": float(lens.mean()), "mib": float(lens.sum() / (1 << 20))}
