"""OnPair-compressed in-memory corpus store — the paper's workload as the
framework's data plane.

The training corpus lives in host DRAM *compressed* (one CompressedCorpus:
payload blob + per-string offsets). Because OnPair compresses every string
independently, the global-shuffle sampler random-accesses single documents
exactly like the paper's 1M-point-query benchmark — no block decompression,
no order constraints. And because the compression dictionary doubles as the
tokenizer vocabulary (repro.core.tokenizer), a stored compressed document's
u16 payload IS its LM token sequence: sampling a document costs a slice, not
a decode.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.api import CompressedCorpus
from repro.core.tokenizer import OnPairTokenizer


@dataclass
class CompressedCorpusStore:
    tokenizer: OnPairTokenizer
    corpus: CompressedCorpus

    @classmethod
    def build(cls, strings: list[bytes], sample_bytes: int = 8 << 20,
              seed: int = 0) -> "CompressedCorpusStore":
        tok = OnPairTokenizer.train(strings, sample_bytes=sample_bytes, seed=seed)
        corpus = tok.compressor.compress(strings)
        return cls(tokenizer=tok, corpus=corpus)

    @property
    def n_docs(self) -> int:
        return self.corpus.n_strings

    @property
    def compression_ratio(self) -> float:
        return self.corpus.ratio

    @property
    def memory_bytes(self) -> int:
        return (self.corpus.compressed_bytes + self.corpus.offsets.nbytes
                + self.tokenizer.dictionary.total_bytes)

    def doc_tokens(self, i: int) -> np.ndarray:
        """Token IDs of document ``i`` — a pure slice of the stored payload."""
        return np.asarray(self.corpus.string_tokens(i), dtype=np.int32)

    def doc_bytes(self, i: int) -> bytes:
        """Random-access decode of document ``i`` (the paper's point query)."""
        comp = self.tokenizer.compressor
        return comp.access(self.corpus, i)

    def doc_lengths_tokens(self) -> np.ndarray:
        return ((self.corpus.offsets[1:] - self.corpus.offsets[:-1]) // 2).astype(np.int64)
