"""Store URL parsing — one connect string per deployment shape.

The client layer resolves every backend from a URL::

    file://<dir>                      read-only CompressedStringStore
    mut://<dir>                       writable MutableStringStore
    shard://<dir>                     in-process ShardedStringStore router
    tcp://host:port[,host:port...]    DistributedStringStore over RPC servers

Options ride the query string (``tcp://h:9100?read_preference=replica``)
and merge under any keyword arguments passed to ``connect()`` — explicit
kwargs win. Values are parsed leniently: ``true``/``false`` become bools,
ints and floats become numbers, everything else stays a string.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from urllib.parse import parse_qsl, unquote

SCHEMES = ("file", "mut", "shard", "tcp")


@dataclass(frozen=True)
class StoreURL:
    """A parsed connect string."""

    scheme: str
    #: directory path (file/mut/shard) — None for tcp
    path: str | None = None
    #: [(host, port), ...] in shard order — None for directory schemes
    addresses: list[tuple[str, int]] | None = None
    #: query-string options (already type-coerced)
    options: dict = field(default_factory=dict)


def _coerce(value: str):
    low = value.lower()
    if low in ("true", "yes", "on"):
        return True
    if low in ("false", "no", "off"):
        return False
    for cast in (int, float):
        try:
            return cast(value)
        except ValueError:
            pass
    return value


def _parse_address(part: str) -> tuple[str, int]:
    host, sep, port = part.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"bad address {part!r} (expected host:port)")
    return (host or "127.0.0.1", int(port))


def parse_url(url: str) -> StoreURL:
    """Parse a store connect string; raises ValueError on unknown schemes."""
    scheme, sep, rest = url.partition("://")
    if not sep or scheme not in SCHEMES:
        known = ", ".join(f"{s}://" for s in SCHEMES)
        raise ValueError(f"unsupported store url {url!r} (known: {known})")
    rest, _, query = rest.partition("?")
    options = {k: _coerce(v) for k, v in parse_qsl(query)}
    if scheme == "tcp":
        parts = [p for p in rest.split(",") if p]
        if not parts:
            raise ValueError(f"tcp url {url!r} names no host:port")
        return StoreURL(scheme, addresses=[_parse_address(p) for p in parts],
                        options=options)
    if not rest:
        raise ValueError(f"{scheme} url {url!r} names no directory")
    return StoreURL(scheme, path=unquote(rest), options=options)


def format_tcp_url(addresses) -> str:
    """The inverse of parse_url for address lists (spawners print this)."""
    return "tcp://" + ",".join(f"{h}:{p}" for h, p in addresses)
