"""repro.client — Client API v3: one session layer over every store backend.

The serving stack grew four frontends with drifting surfaces — the local
:class:`~repro.store.store.CompressedStringStore` /
:class:`~repro.store.mutable.MutableStringStore`, the in-process
:class:`~repro.distributed.shard_store.ShardedStringStore` router, and the
multi-process :class:`~repro.net.router.DistributedStringStore`. This
package is the one entry point over all of them::

    from repro.client import connect

    client = connect("tcp://host0:9100,host1:9101",
                     read_preference="replica", timeout=10.0)
    client.multiget([3, 99_000, 41])            # sync, order-preserving
    fut = client.multiget_async([7, 8, 9])      # pipelined future
    for doc in client.scan_iter(0, 1_000_000):  # streamed, frame-bounded
        ...
    client.stats()                              # one schema, every backend

  url        — connect-string parsing (file:// mut:// shard:// tcp://)
  session    — StoreClient: the frozen sync/async surface + unified stats
"""

from repro.client.session import StoreClient, connect, wrap
from repro.client.url import SCHEMES, StoreURL, format_tcp_url, parse_url

__all__ = [
    "SCHEMES",
    "StoreClient",
    "StoreURL",
    "connect",
    "format_tcp_url",
    "parse_url",
    "wrap",
]
