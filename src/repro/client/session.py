"""StoreClient — one session object over every store deployment shape.

``connect(url)`` resolves a store URL (:mod:`repro.client.url`) into a
backend — a local :class:`CompressedStringStore` / `MutableStringStore`, an
in-process :class:`ShardedStringStore`, or a multi-process
:class:`DistributedStringStore` — and wraps it in a :class:`StoreClient`
with a *frozen* surface: the same sync calls
(``get/multiget/scan/locate/scan_prefix/append/extend/stats/compact/save/
close``), the same async counterparts returning
``concurrent.futures.Future``
(``get_async/multiget_async/locate_async/append_async/extend_async``), the
same streaming ``scan_iter`` / ``scan_prefix_iter``, and the same
per-call options (``timeout=``,
``read_preference="primary"|"replica"|"any"``) no matter which deployment
shape sits behind it. New backends land behind this surface once, not once
per call site.

How the async path pipelines, per backend:

* **local stores** (``file://`` / ``mut://``) — every data call rides the
  store's micro-batching :class:`~repro.store.service.StoreService` bulk
  hooks (``submit_multiget`` / ``submit_extend``): one queue item + one
  future per call, and concurrent calls — sync callers on other threads
  included — coalesce into single batched decodes / Encoder passes. The
  service is created by the client (``max_wait_s`` defaults to 0 here:
  drain-what's-there, no standing latency tax) and the ``target_p99_ms``
  connect option arms its adaptive window controller.
* **routers** (``shard://`` / ``tcp://``) — sync calls go straight at the
  router (its own per-shard fan-out pool and the
  :class:`RemoteShardClient` connection pools do the pipelining; a
  per-call ``timeout=`` opts into the future path to get the bound);
  async calls overlap on a small client executor.

Every call is recorded client-side (op counts, latency reservoir, bytes
moved), so :meth:`stats` reports one schema — ``latency_summary`` /
``throughput_mib_s`` / ``wakeups`` — with identical keys across all four
backends; the raw backend snapshot rides along under ``"backend"``.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

from repro.client.url import StoreURL, parse_url
from repro.core.metrics import throughput_mib_s
from repro.obs import (
    REGISTRY,
    TRACER,
    Histogram,
    merge_hist_states,
    summarize_hist_state,
)
from repro.distributed.shard_store import (
    ShardedStringStore,
    ShardRouter,
    check_read_preference,
)
from repro.store.mutable import MutableStringStore
from repro.store.service import StoreService
from repro.store.store import CompressedStringStore


class _GetBatcher:
    """Client-side coalescer for router point lookups.

    A router backend pays one RPC round-trip per ``get`` — the 297 lookups/s
    tail the ISSUE calls out. This batcher gives single gets the same bulk
    pipeline multiget already rides: pending gets accumulate while one
    batched RPC is in flight and drain as ONE ``backend.multiget`` per
    ``read_preference`` group. A lone get drains immediately (batch of one,
    no added latency); pipelined gets coalesce into server-sized batches
    automatically — Nagle without the timer.

    Futures flip to RUNNING only at drain time, so a future cancelled while
    still pending (a hedged read whose first attempt won) never reaches the
    wire at all — the cancellation the hedging tests assert via server-side
    op counters.
    """

    def __init__(self, backend, submit, max_batch: int = 512):
        self._backend = backend
        self._submit = submit  # client executor hand-off (trace-preserving)
        self.max_batch = int(max_batch)
        self._lock = threading.Lock()
        self._pending: list[tuple] = []  # (id, pref, Future, TraceContext)
        self._in_flight = False
        self.batches = 0
        self.coalesced = 0  # gets answered in a client-side batch of > 1

    def submit_get(self, i: int, pref: str) -> Future:
        fut: Future = Future()
        with self._lock:
            self._pending.append((int(i), pref, fut, TRACER.current()))
            launch = not self._in_flight
            if launch:
                self._in_flight = True
        if launch:
            self._submit(self._drain)
        return fut

    def _drain(self) -> None:
        while True:
            with self._lock:
                take = self._pending[: self.max_batch]
                self._pending = self._pending[self.max_batch:]
                if not take:
                    self._in_flight = False
                    return
            # cancelled-while-pending futures drop out before the wire;
            # survivors flip to RUNNING so a late cancel cannot race
            live = [item for item in take
                    if item[2].set_running_or_notify_cancel()]
            if not live:
                continue
            self.batches += 1
            if len(live) > 1:
                self.coalesced += len(live)
            groups: dict[str, list[tuple]] = {}
            for item in live:
                groups.setdefault(item[1], []).append(item)
            for pref, items in groups.items():
                self._serve_group(pref, items)

    def _serve_group(self, pref: str, items: list[tuple]) -> None:
        """One backend.multiget for every get in the group; the first traced
        caller's context parents the fused rpc spans (same convention as the
        service's coalesced decode)."""
        ids = [i for i, _, _, _ in items]
        ctx = next((c for _, _, _, c in items if c is not None), None)
        prev = TRACER.activate(ctx) if ctx is not None else None
        try:
            values = self._backend.multiget(ids, read_preference=pref)
        except Exception as exc:
            for _, _, fut, _ in items:
                fut.set_exception(exc)
        else:
            for (_, _, fut, _), v in zip(items, values):
                fut.set_result(v)
        finally:
            if ctx is not None:
                TRACER.restore(prev)


class _ExtendBatcher:
    """Client-side group-commit for router writes (mirror of
    :class:`_GetBatcher`).

    A router backend pays one RPC round-trip per ``extend`` — and one per
    ``append``. Pending writes accumulate while one bulk RPC is in flight
    and drain as ONE ``backend.extend`` over the concatenated strings; the
    id list the backend returns (aligned with input order) is split back
    per caller by span. Single appends ride the same queue as one-string
    extends, so pipelined appends group-commit into the server's batched
    Encoder pass exactly like the service queue does for local stores.

    Futures flip to RUNNING only at drain time: a write cancelled while
    still pending never reaches the wire.
    """

    def __init__(self, backend, submit, max_batch: int = 4096):
        self._backend = backend
        self._submit = submit  # client executor hand-off (trace-preserving)
        self.max_batch = int(max_batch)  # strings per drained RPC, not calls
        self._lock = threading.Lock()
        self._pending: list[tuple] = []  # (strings, Future, TraceContext)
        self._in_flight = False
        self.batches = 0
        self.coalesced = 0  # extend/append calls fused into a batch of > 1

    def submit_extend(self, strings: list[bytes]) -> Future:
        fut: Future = Future()
        with self._lock:
            self._pending.append((strings, fut, TRACER.current()))
            launch = not self._in_flight
            if launch:
                self._in_flight = True
        if launch:
            self._submit(self._drain)
        return fut

    def _drain(self) -> None:
        while True:
            take: list[tuple] = []
            n = 0
            with self._lock:
                # at least one call per round; stop adding once the drained
                # RPC would exceed max_batch strings (an oversized single
                # call still goes out whole — the server chunks internally)
                while self._pending and (not take or
                                         n + len(self._pending[0][0])
                                         <= self.max_batch):
                    item = self._pending.pop(0)
                    take.append(item)
                    n += len(item[0])
                if not take:
                    self._in_flight = False
                    return
            live = [item for item in take
                    if item[1].set_running_or_notify_cancel()]
            if not live:
                continue
            self.batches += 1
            if len(live) > 1:
                self.coalesced += len(live)
            flat: list[bytes] = []
            spans: list[tuple[int, int]] = []
            for strings, _, _ in live:
                spans.append((len(flat), len(flat) + len(strings)))
                flat.extend(strings)
            ctx = next((c for _, _, c in live if c is not None), None)
            prev = TRACER.activate(ctx) if ctx is not None else None
            try:
                ids = self._backend.extend(flat)
            except Exception as exc:
                for _, fut, _ in live:
                    fut.set_exception(exc)
            else:
                for (_, fut, _), (lo, hi) in zip(live, spans):
                    fut.set_result(ids[lo:hi])
            finally:
                if ctx is not None:
                    TRACER.restore(prev)


class StoreClient:
    """Uniform session over one store backend. Use :func:`connect` (URL) or
    :func:`wrap` (already-open backend) instead of constructing directly."""

    def __init__(self, backend, *, url: str = "", scheme: str = "",
                 timeout: float = 30.0, read_preference: str = "primary",
                 scan_chunk: int = 4096, owns_backend: bool = False,
                 service: StoreService | None = None,
                 max_async_workers: int = 8):
        self.backend = backend
        self.url = url
        self.scheme = scheme
        self.timeout = float(timeout)
        self.read_preference = check_read_preference(read_preference)
        self.scan_chunk = int(scan_chunk)
        self._owns_backend = owns_backend
        self._is_router = isinstance(backend, ShardRouter)
        self._service = service
        self._executor = (None if service is not None else
                          ThreadPoolExecutor(max_workers=max_async_workers,
                                             thread_name_prefix="store-client"))
        self._closed = False
        self._lock = threading.Lock()
        # router backends coalesce async point lookups client-side; local
        # stores already coalesce through the service queue
        self._get_batcher = (None if service is not None else
                             _GetBatcher(backend, self._submit))
        # ...and async writes the same way: pipelined extends/appends fuse
        # into one bulk RPC per drain (group-commit at the client edge)
        self._extend_batcher = (None if service is not None else
                                _ExtendBatcher(backend, self._submit))
        # per-client histogram (stats() stays session-scoped), registered so
        # /metrics in a client process exports the same series name
        self._lat = REGISTRY.register(
            Histogram("repro_client_request_latency_us"))
        self._ops: dict[str, int] = {}
        self._bytes_moved = 0
        self._busy_s = 0.0
        self._hedges = 0      # hedge attempts actually sent
        self._hedge_wins = 0  # hedged requests answered by a later attempt

    # ------------------------------------------------------------ bookkeeping
    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("client is closed")

    def _pref(self, read_preference: str | None) -> str:
        if read_preference is None:
            return self.read_preference
        return check_read_preference(read_preference)

    def _record(self, op: str, t0: float, nbytes: int) -> None:
        dt = time.perf_counter() - t0
        self._lat.record(dt * 1e6)
        with self._lock:
            self._ops[op] = self._ops.get(op, 0) + 1
            self._bytes_moved += nbytes
            self._busy_s += dt

    def _tracked(self, fut: Future, op: str, t0: float, nbytes_of,
                 ctx=None, parent_id: int = 0) -> Future:
        """Attach session accounting (and the request's root span, when one
        was minted at submit time) to a backend/service future."""
        def _done(f: Future) -> None:
            nbytes = 0
            if not f.cancelled() and f.exception() is None:
                nbytes = nbytes_of(f.result())
            if ctx is not None:
                TRACER.record(f"client.{op}", ctx, parent_id, t0,
                              time.perf_counter() - t0)
            self._record(op, t0, nbytes)
        fut.add_done_callback(_done)
        return fut

    def _trace_submit(self, submit):
        """Mint this request's root span context, activate it around the
        backend submit (queue items / executor jobs capture it there), and
        return ``(future, ctx, parent_id)`` for :meth:`_tracked`."""
        ctx, parent_id = TRACER.new_context()
        prev = TRACER.activate(ctx)
        try:
            return submit(), ctx, parent_id
        finally:
            TRACER.restore(prev)

    @staticmethod
    def _len_sum(values) -> int:
        return sum(len(v) for v in values)

    def _submit(self, fn, *args, **kw) -> Future:
        """Run ``fn`` on the client executor (router backends only); the
        submitter's trace context rides along onto the executor thread."""
        ctx = TRACER.current()
        if ctx is not None:
            inner = fn

            def fn(*a, **k):  # noqa: F811 — traced wrapper shadows on purpose
                prev = TRACER.activate(ctx)
                try:
                    return inner(*a, **k)
                finally:
                    TRACER.restore(prev)
        try:
            return self._executor.submit(fn, *args, **kw)
        except RuntimeError:  # executor shut down under a racing close()
            raise RuntimeError("client is closed") from None

    def _direct(self, op: str, call, nbytes_of):
        """Sync hot path for router backends: call straight into the
        router's own fan-out — no executor hand-off. A per-call ``timeout=``
        opts back into the future path (that is what buys the bound);
        remote transports stay bounded regardless by the socket timeout."""
        self._check_open()
        t0 = time.perf_counter()
        with TRACER.span(f"client.{op}", root=True):
            out = call()
        self._record(op, t0, nbytes_of(out))
        return out

    # ---------------------------------------------------------------- queries
    @property
    def n_strings(self) -> int:
        return len(self.backend)

    def __len__(self) -> int:
        return self.n_strings

    def get_async(self, i: int, *,
                  read_preference: str | None = None) -> "Future[bytes]":
        self._check_open()
        # validated on EVERY backend — a typo'd preference must fail the
        # same way whether or not this backend can act on it
        pref = self._pref(read_preference)
        t0 = time.perf_counter()
        if self._service is not None:
            fut, ctx, pid = self._trace_submit(
                lambda: self._service.submit(int(i)))
        else:
            # ride the bulk multiget pipeline: pipelined gets coalesce into
            # one RPC per drain instead of one round-trip per string
            fut, ctx, pid = self._trace_submit(
                lambda: self._get_batcher.submit_get(int(i), pref))
        return self._tracked(fut, "get", t0, len, ctx, pid)

    def multiget_async(self, ids, *,
                       read_preference: str | None = None
                       ) -> "Future[list[bytes]]":
        """One batched lookup as a future; many in flight pipeline through
        the service queue (local) or the router fan-out (shard/tcp)."""
        self._check_open()
        pref = self._pref(read_preference)
        t0 = time.perf_counter()
        ids = [int(i) for i in ids]
        if self._service is not None:
            fut, ctx, pid = self._trace_submit(
                lambda: self._service.submit_multiget(ids))
        else:
            fut, ctx, pid = self._trace_submit(
                lambda: self._submit(self.backend.multiget, ids,
                                     read_preference=pref))
        return self._tracked(fut, "multiget", t0, self._len_sum, ctx, pid)

    def get(self, i: int, *, timeout: float | None = None,
            read_preference: str | None = None) -> bytes:
        if self._service is None and timeout is None:
            return self._direct(
                "get",
                lambda: self.backend.get(
                    int(i), read_preference=self._pref(read_preference)),
                len)
        return self.get_async(i, read_preference=read_preference).result(
            self.timeout if timeout is None else timeout)

    def multiget(self, ids, *, timeout: float | None = None,
                 read_preference: str | None = None) -> list[bytes]:
        if self._service is None and timeout is None:
            ids = [int(i) for i in ids]
            return self._direct(
                "multiget",
                lambda: self.backend.multiget(
                    ids, read_preference=self._pref(read_preference)),
                self._len_sum)
        return self.multiget_async(
            ids, read_preference=read_preference).result(
            self.timeout if timeout is None else timeout)

    # ---------------------------------------------------------- hedged reads
    def _hedged_async(self, submit, prefs: tuple[str, ...], hedge_s: float,
                      budget: int) -> Future:
        """Tail-tolerant read: launch attempt 0 with ``prefs[0]``; if it has
        not answered after ``hedge_s``, launch a second attempt with
        ``prefs[1]`` (typically a replica) — first answer wins, the loser is
        cancelled (a still-pending loser never reaches the wire; one already
        in flight is abandoned). A failed attempt retries immediately while
        the total attempt ``budget`` lasts, so one dead/slow server costs
        one hedge window, not the caller's whole timeout.
        """
        out: Future = Future()
        out.set_running_or_notify_cancel()  # resolved by callbacks below
        lock = threading.Lock()
        state = {"attempts": 0, "pending": [], "timer": None, "done": False}

        def finish(result=None, exc=None) -> None:
            with lock:
                if state["done"]:
                    return
                state["done"] = True
                timer, losers = state["timer"], list(state["pending"])
                state["pending"] = []
            if timer is not None:
                timer.cancel()
            for f in losers:
                f.cancel()
            if exc is not None:
                out.set_exception(exc)
            else:
                out.set_result(result)

        def on_done(f: Future) -> None:
            with lock:
                if f in state["pending"]:
                    state["pending"].remove(f)
                pending_left = bool(state["pending"])
            if f.cancelled():
                return
            exc = f.exception()
            if exc is None:
                if getattr(f, "_hedge_attempt", 0) > 0:
                    with self._lock:
                        self._hedge_wins += 1
                finish(result=f.result())
                return
            with lock:
                can_retry = not state["done"] and state["attempts"] < budget
            if can_retry:
                launch()
            elif not pending_left:
                finish(exc=exc)

        def launch() -> None:
            with lock:
                if state["done"] or state["attempts"] >= budget:
                    return
                k = state["attempts"]
                state["attempts"] += 1
            if k > 0:
                with self._lock:
                    self._hedges += 1
            try:
                f = submit(prefs[min(k, len(prefs) - 1)])
            except Exception as exc:
                finish(exc=exc)
                return
            f._hedge_attempt = k
            with lock:
                late = state["done"]
                if not late:
                    state["pending"].append(f)
            if late:
                f.cancel()
            f.add_done_callback(on_done)

        launch()
        if budget > 1 and hedge_s is not None:
            if hedge_s <= 0:
                # an immediate hedge must actually be immediate: going
                # through a zero-delay timer would race thread spawn
                # against the first attempt's answer
                launch()
            else:
                timer = threading.Timer(float(hedge_s), launch)
                timer.daemon = True
                with lock:
                    if not state["done"]:
                        state["timer"] = timer
                        timer.start()
        return out

    def _hedge_prefs(self, read_preference: str | None,
                     hedge_preference: str) -> tuple[str, str]:
        return (self._pref(read_preference),
                check_read_preference(hedge_preference))

    def get_hedged_async(self, i: int, *, hedge_ms: float = 10.0,
                         budget: int = 2, read_preference: str | None = None,
                         hedge_preference: str = "any") -> "Future[bytes]":
        """Point lookup with a hedge: the second attempt (after ``hedge_ms``
        without an answer, while the attempt ``budget`` lasts) targets
        ``hedge_preference`` — against a replica-backed cluster the hedge
        lands on a different server, which is what makes open-loop p999
        honest under one slow shard."""
        self._check_open()
        prefs = self._hedge_prefs(read_preference, hedge_preference)
        t0 = time.perf_counter()
        i = int(i)
        if self._service is not None:
            def submit(_pref: str) -> Future:
                return self._service.submit(i)
        else:
            def submit(pref: str) -> Future:
                return self._get_batcher.submit_get(i, pref)
        fut, ctx, pid = self._trace_submit(
            lambda: self._hedged_async(submit, prefs, hedge_ms / 1e3,
                                       int(budget)))
        return self._tracked(fut, "get", t0, len, ctx, pid)

    def multiget_hedged_async(self, ids, *, hedge_ms: float = 10.0,
                              budget: int = 2,
                              read_preference: str | None = None,
                              hedge_preference: str = "any"
                              ) -> "Future[list[bytes]]":
        self._check_open()
        prefs = self._hedge_prefs(read_preference, hedge_preference)
        t0 = time.perf_counter()
        ids = [int(i) for i in ids]
        if self._service is not None:
            def submit(_pref: str) -> Future:
                return self._service.submit_multiget(ids)
        else:
            def submit(pref: str) -> Future:
                return self._submit(self.backend.multiget, ids,
                                    read_preference=pref)
        fut, ctx, pid = self._trace_submit(
            lambda: self._hedged_async(submit, prefs, hedge_ms / 1e3,
                                       int(budget)))
        return self._tracked(fut, "multiget", t0, self._len_sum, ctx, pid)

    def get_hedged(self, i: int, *, timeout: float | None = None,
                   **kw) -> bytes:
        return self.get_hedged_async(i, **kw).result(
            self.timeout if timeout is None else timeout)

    def multiget_hedged(self, ids, *, timeout: float | None = None,
                        **kw) -> list[bytes]:
        return self.multiget_hedged_async(ids, **kw).result(
            self.timeout if timeout is None else timeout)

    def scan(self, lo: int, hi: int, *,
             read_preference: str | None = None) -> list[bytes]:
        """Decode the contiguous id range [lo, hi) in one call (routers
        already chunk below max_frame internally; use :meth:`scan_iter` to
        stream without materialising the whole range)."""
        self._check_open()
        pref = self._pref(read_preference)
        t0 = time.perf_counter()
        if self._is_router:
            out = self.backend.scan(int(lo), int(hi), read_preference=pref)
        else:
            out = self.backend.scan(int(lo), int(hi))
        self._record("scan", t0, self._len_sum(out))
        return out

    def scan_iter(self, lo: int, hi: int, *, chunk: int | None = None,
                  read_preference: str | None = None):
        """Stream the id range [lo, hi) as an iterator of strings, fetched
        in ``chunk``-sized sub-scans (default: the client's ``scan_chunk``)
        so no response — RPC frame or in-memory list — ever covers more
        than one chunk. Out-of-range bounds raise IndexError from the
        offending chunk, after any earlier chunks have been yielded."""
        self._check_open()
        self._pref(read_preference)  # fail a typo now, not at first chunk
        lo, hi = int(lo), int(hi)
        if lo > hi or lo < 0:
            raise IndexError(f"scan range [{lo}, {hi}) is malformed")
        step = int(chunk) if chunk else self.scan_chunk

        def _gen():
            for c_lo in range(lo, hi, step):
                yield from self.scan(c_lo, min(c_lo + step, hi),
                                     read_preference=read_preference)
        return _gen()

    # --------------------------------------------------------- reverse lookup
    def _inline_future(self, call) -> Future:
        """Complete ``call()`` synchronously behind a Future — the async
        surface for ops with no service/executor pipeline on this backend."""
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        try:
            fut.set_result(call())
        except Exception as exc:
            fut.set_exception(exc)
        return fut

    def _locate_call(self, strings: list[bytes], pref: str):
        # plain stores take no read_preference; routers route on it
        if self._is_router:
            return self.backend.locate_batch(strings, read_preference=pref)
        return self.backend.locate_batch(strings)

    def locate_batch_async(self, strings, *,
                           read_preference: str | None = None
                           ) -> "Future[list[int | None]]":
        self._check_open()
        pref = self._pref(read_preference)
        t0 = time.perf_counter()
        strings = [bytes(s) for s in strings]
        if self._executor is not None:
            fut, ctx, pid = self._trace_submit(
                lambda: self._submit(self._locate_call, strings, pref))
        else:  # local backends: the store call is the whole pipeline
            fut, ctx, pid = self._trace_submit(
                lambda: self._inline_future(
                    lambda: self._locate_call(strings, pref)))
        return self._tracked(fut, "locate", t0, lambda _out: 0, ctx, pid)

    def locate_async(self, s, *, read_preference: str | None = None
                     ) -> "Future[int | None]":
        inner = self.locate_batch_async([s], read_preference=read_preference)
        out: Future = Future()

        def _done(f: Future) -> None:
            if f.cancelled():
                out.cancel()
            elif f.exception() is not None:
                out.set_exception(f.exception())
            else:
                out.set_result(f.result()[0])
        inner.add_done_callback(_done)
        return out

    def locate_batch(self, strings, *, timeout: float | None = None,
                     read_preference: str | None = None
                     ) -> list[int | None]:
        """Exact-match reverse lookup: the id of each stored string, or
        ``None`` for strings not in the store (lowest id wins on
        duplicates)."""
        if timeout is None:
            strings = [bytes(s) for s in strings]
            pref = self._pref(read_preference)
            return self._direct("locate",
                                lambda: self._locate_call(strings, pref),
                                lambda _out: 0)
        return self.locate_batch_async(
            strings, read_preference=read_preference).result(timeout)

    def locate(self, s, *, timeout: float | None = None,
               read_preference: str | None = None) -> int | None:
        return self.locate_batch([s], timeout=timeout,
                                 read_preference=read_preference)[0]

    def scan_prefix(self, prefix, limit: int | None = 100, after=None, *,
                    read_preference: str | None = None
                    ) -> list[tuple[int, bytes]]:
        """All stored strings starting with ``prefix`` as ``(id, string)``
        pairs in (string, id) order, at most ``limit`` of them; pass the
        last hit back as ``after=(string, id)`` to page (or use
        :meth:`scan_prefix_iter`)."""
        self._check_open()
        prefix = bytes(prefix)
        pref = self._pref(read_preference)
        if self._is_router:
            call = (lambda: self.backend.scan_prefix(
                prefix, limit, after, read_preference=pref))
        else:
            call = lambda: self.backend.scan_prefix(prefix, limit, after)
        return self._direct(
            "scan_prefix", call,
            lambda out: sum(len(s) for _gid, s in out))

    def scan_prefix_iter(self, prefix, *, chunk: int | None = None,
                         read_preference: str | None = None):
        """Stream every prefix hit as an iterator of ``(id, string)``
        pairs, fetched ``chunk`` hits at a time (default 256) via the
        ``after=`` cursor — no response ever covers more than one chunk."""
        self._check_open()
        self._pref(read_preference)  # fail a typo now, not at first chunk
        prefix = bytes(prefix)
        step = int(chunk) if chunk else 256

        def _gen():
            after = None
            while True:
                page = self.scan_prefix(prefix, limit=step, after=after,
                                        read_preference=read_preference)
                yield from page
                if len(page) < step:
                    return
                gid, s = page[-1]
                after = (s, gid)
        return _gen()

    # ----------------------------------------------------------------- writes
    def append_async(self, s: bytes) -> "Future[int]":
        self._check_open()
        t0 = time.perf_counter()
        if self._service is not None:
            fut, ctx, pid = self._trace_submit(
                lambda: self._service.submit_append(bytes(s)))
        else:
            fut, ctx, pid = self._trace_submit(
                lambda: self._append_via_batcher(bytes(s)))
        return self._tracked(fut, "append", t0, lambda _i: len(s), ctx, pid)

    def _append_via_batcher(self, s: bytes) -> "Future[int]":
        """A single append rides the extend batcher as a one-string extend,
        so pipelined appends group-commit; the id list unwraps to one id."""
        inner = self._extend_batcher.submit_extend([s])
        out: Future = Future()

        def _done(f: Future) -> None:
            if f.cancelled():
                out.cancel()
            elif f.exception() is not None:
                out.set_exception(f.exception())
            else:
                out.set_result(f.result()[0])
        inner.add_done_callback(_done)
        return out

    def extend_async(self, strings) -> "Future[list[int]]":
        """One batched append as a future; local stores fold concurrent
        extends into single Encoder passes via the service write hook."""
        self._check_open()
        t0 = time.perf_counter()
        strings = [bytes(s) for s in strings]
        nbytes = self._len_sum(strings)
        if self._service is not None:
            fut, ctx, pid = self._trace_submit(
                lambda: self._service.submit_extend(strings))
        else:
            fut, ctx, pid = self._trace_submit(
                lambda: self._extend_batcher.submit_extend(strings))
        return self._tracked(fut, "extend", t0, lambda _ids: nbytes, ctx, pid)

    def append(self, s: bytes, *, timeout: float | None = None) -> int:
        if self._service is None and timeout is None:
            s = bytes(s)
            return self._direct("append", lambda: self.backend.append(s),
                                lambda _i: len(s))
        return self.append_async(s).result(
            self.timeout if timeout is None else timeout)

    def extend(self, strings, *, timeout: float | None = None) -> list[int]:
        if self._service is None and timeout is None:
            strings = [bytes(s) for s in strings]
            nbytes = self._len_sum(strings)
            return self._direct("extend",
                                lambda: self.backend.extend(strings),
                                lambda _ids: nbytes)
        return self.extend_async(strings).result(
            self.timeout if timeout is None else timeout)

    # -------------------------------------------------------------- lifecycle
    def compact(self, shard: int | None = None, **kw):
        """Re-train + rewrite: the whole store (local), one shard, or every
        shard (routers). Read-only backends refuse with TypeError."""
        self._check_open()
        if not hasattr(self.backend, "compact"):
            raise TypeError(f"{self.scheme or 'this'} backend is read-only: "
                            "compact() refused")
        if self._is_router:
            return self.backend.compact(shard, **kw)
        if shard is not None:
            raise TypeError("shard= targeting requires a shard:// or tcp:// "
                            "backend")
        return self.backend.compact(**kw)

    def save(self, dir_path: str | None = None):
        """Persist the backend: local stores into their directory (or
        ``dir_path``), routers via their own save protocol."""
        self._check_open()
        if self._is_router:
            if dir_path is not None:
                raise TypeError("router backends persist in place: "
                                "save() takes no directory")
            return self.backend.save()
        target = dir_path or getattr(self.backend, "_dir", None) or (
            parse_url(self.url).path if self.url else None)
        if target is None:
            raise ValueError("no directory to save into (pass dir_path=)")
        return self.backend.save(target)

    def register_replica(self, shard: int, address, **client_kw):
        """Attach a read-only replica to a shard's replica set (tcp://
        backends only) — the target of replica reads and compaction
        hand-off."""
        self._check_open()
        if not hasattr(self.backend, "register_replica"):
            raise TypeError("register_replica requires a tcp:// backend")
        return self.backend.register_replica(shard, address, **client_kw)

    # ----------------------------------------------------------------- tiering
    def _tier(self, action: str, segment: int | None = None,
              shard: int | None = None, params: dict | None = None):
        """Route one tier-control op to the backend: routers fan it out per
        shard (list of reports), local stores answer directly (one dict)."""
        self._check_open()
        if self._is_router:
            return self.backend.tier(action, segment=segment, shard=shard,
                                     params=params)
        if shard is not None:
            raise TypeError("shard= targeting requires a shard:// or tcp:// "
                            "backend")
        from repro.store.tier import tier_op
        return tier_op(self.backend, action=action, segment=segment,
                       params=params)

    def demote(self, segment: int | None = None, shard: int | None = None,
               **params):
        """Demote sealed segments to the mmap'd RLZ cold tier (all eligible
        segments when ``segment`` is None). ``params`` become TierManager
        thresholds on first use (demote_below/promote_above/halflife_s)."""
        return self._tier("demote", segment=segment, shard=shard,
                          params=params or None)

    def promote(self, segment: int | None = None, shard: int | None = None):
        """Promote cold segments back to hot OnPair heap arrays."""
        return self._tier("promote", segment=segment, shard=shard)

    def tier_stats(self):
        """Tier snapshot(s): cold segment set, demotion/promotion counts,
        per-segment read rates ({"enabled": False} where tiering is off)."""
        return self._tier("stats")

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._service is not None:
            self._service.close()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
        if self._owns_backend and hasattr(self.backend, "close"):
            self.backend.close()

    def __enter__(self) -> "StoreClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        """One stats schema across every backend: client-side op counts and
        ``latency_summary`` / ``throughput_mib_s``, the micro-batching
        service's ``wakeups`` / adaptive-window state where one exists
        (local backends; routers report the aggregate of their servers'),
        and the raw backend snapshot under ``"backend"``."""
        self._check_open()
        backend_snap = (self.backend.stats_snapshot()
                        if hasattr(self.backend, "stats_snapshot")
                        else self.backend.stats())
        wakeups = 0
        max_wait_s = None
        target_p99_s = None
        if self._service is not None:
            svc = self._service.stats()
            backend_snap = {**backend_snap, "service": svc}
            wakeups = svc["wakeups"]
            max_wait_s = svc["max_wait_s"]
            target_p99_s = svc["target_p99_s"]
        else:
            for shard_snap in backend_snap.get("shards", ()):
                svc = shard_snap.get("service")
                if svc:  # tcp:// shard servers export their service counters
                    wakeups += svc.get("wakeups", 0)
        # server-side op counts (tcp:// shard servers report them; other
        # backends have no server so the totals are empty) + cross-shard
        # store-latency aggregation: per-shard histogram states merge
        # losslessly, so the merged p50/p99 equal the pooled-population
        # percentiles no single shard could compute
        op_totals: dict[str, int] = {}
        per_shard_ops: list[dict] = []
        hist_states: list[dict] = []
        shards = backend_snap.get("shards")
        for k, shard_snap in enumerate(shards if shards is not None else ()):
            ops_k = shard_snap.get("ops")
            if ops_k:
                per_shard_ops.append({"shard": k, "ops": dict(ops_k)})
                for op, count in ops_k.items():
                    op_totals[op] = op_totals.get(op, 0) + int(count)
            store_snap = shard_snap.get("store", shard_snap)
            state = store_snap.get("multiget_latency_hist")
            if state:
                hist_states.append(state)
        if shards is None:
            state = backend_snap.get("multiget_latency_hist")
            if state:
                hist_states.append(state)
        merged = merge_hist_states(hist_states)
        lat = self._lat.summary()
        with self._lock:
            ops = dict(self._ops)
            moved, busy = self._bytes_moved, self._busy_s
            hedges, hedge_wins = self._hedges, self._hedge_wins
        batcher = self._get_batcher
        wb = self._extend_batcher
        return {
            "hedges": hedges,
            "hedge_wins": hedge_wins,
            "get_batches": batcher.batches if batcher is not None else 0,
            "coalesced_gets": batcher.coalesced if batcher is not None else 0,
            "extend_batches": wb.batches if wb is not None else 0,
            "coalesced_extends": wb.coalesced if wb is not None else 0,
            "scheme": self.scheme,
            "url": self.url,
            "n_strings": self.n_strings,
            "read_preference": self.read_preference,
            "ops": ops,
            "server_ops": {"total": op_totals, "per_shard": per_shard_ops},
            "store_latency": (summarize_hist_state(merged)
                              if merged is not None else None),
            "latency_summary": lat,
            "throughput_mib_s": round(throughput_mib_s(moved, busy), 2)
            if busy else 0.0,
            "wakeups": wakeups,
            "max_wait_s": max_wait_s,
            "target_p99_s": target_p99_s,
            "backend": backend_snap,
        }


# ------------------------------------------------------------------- factory
#: connect() options consumed by the client itself (everything else is
#: forwarded to the backend opener)
_CLIENT_OPTS = ("timeout", "read_preference", "scan_chunk",
                "max_async_workers")
#: options configuring the client-owned StoreService over local stores
_SERVICE_OPTS = ("max_batch", "max_wait_s", "target_p99_ms", "adapt_window")


def _build_service(store, opts: dict) -> StoreService:
    target_ms = opts.pop("target_p99_ms", None)
    return StoreService(
        store,
        max_batch=opts.pop("max_batch", 256),
        # latency-first default: drain whatever is queued, never hold a lone
        # caller hostage to a batching window (the adaptive controller can
        # re-open the window when target_p99_ms leaves headroom)
        max_wait_s=opts.pop("max_wait_s", 0.0),
        target_p99_s=None if target_ms is None else float(target_ms) / 1e3,
        adapt_window=opts.pop("adapt_window", 64),
    )


def _reject_service_opts(scheme: str, opts: dict) -> None:
    bad = sorted(k for k in _SERVICE_OPTS if k in opts)
    if bad:
        raise TypeError(
            f"{bad} configure the local micro-batching service and do not "
            f"apply to {scheme}:// backends — routers have no client-side "
            "service; set the knobs where the StoreService lives "
            "(shard servers: --max-wait-s / --target-p99-ms)")


def _make_client(backend, parsed: StoreURL, url: str, opts: dict,
                 owns_backend: bool) -> StoreClient:
    service = None
    if not isinstance(backend, ShardRouter):
        service = _build_service(backend, opts)
    else:
        _reject_service_opts(parsed.scheme, opts)
    client_kw = {k: opts.pop(k) for k in _CLIENT_OPTS if k in opts}
    if opts:
        raise TypeError(f"unknown connect() option(s): {sorted(opts)}")
    return StoreClient(backend, url=url, scheme=parsed.scheme,
                       owns_backend=owns_backend, service=service,
                       **client_kw)


def connect(url: str, **opts) -> StoreClient:
    """Resolve a store URL into a ready :class:`StoreClient`.

    ======================  ====================================================
    scheme                  backend
    ======================  ====================================================
    ``file://<dir>``        read-only ``CompressedStringStore.open``
    ``mut://<dir>``         writable ``MutableStringStore.open``
    ``shard://<dir>``       in-process ``ShardedStringStore.open``
                            (``writable=True`` to accept appends)
    ``tcp://h:p[,h:p...]``  ``DistributedStringStore.connect`` (shard order)
    ======================  ====================================================

    Options may ride the URL query string or come as keyword arguments
    (kwargs win): ``timeout=`` (default request timeout, seconds),
    ``read_preference=`` (default read routing), ``scan_chunk=``,
    ``target_p99_ms=`` / ``max_wait_s=`` / ``max_batch=`` (the local
    micro-batching service), plus backend-specific extras (``mmap=``,
    ``backend=``, ``writable=``, router ``client_kw`` …).
    """
    parsed = parse_url(url)
    opts = {**parsed.options, **opts}
    client_opts = {k: opts.pop(k) for k in (*_CLIENT_OPTS, *_SERVICE_OPTS)
                   if k in opts}
    if parsed.scheme in ("shard", "tcp"):
        # fail before any backend opens — a rejected option must not leak
        # half-connected sockets
        _reject_service_opts(parsed.scheme, client_opts)
    if parsed.scheme == "file":
        backend = CompressedStringStore.open(parsed.path, **opts)
    elif parsed.scheme == "mut":
        backend = MutableStringStore.open(parsed.path, **opts)
    elif parsed.scheme == "shard":
        backend = ShardedStringStore.open(parsed.path, **opts)
    else:  # tcp
        from repro.net.router import DistributedStringStore

        if "read_preference" in client_opts:  # the router honours it natively
            opts.setdefault("read_preference",
                            client_opts["read_preference"])
        backend = DistributedStringStore.connect(parsed.addresses, **opts)
    return _make_client(backend, parsed, url, client_opts, owns_backend=True)


def wrap(backend, *, url: str = "", **opts) -> StoreClient:
    """Wrap an already-open backend (store or router) in the same frozen
    client surface ``connect()`` returns — for in-memory stores that never
    touched a URL. The caller keeps ownership: ``close()`` releases the
    client's service/executor but not the backend."""
    if isinstance(backend, ShardRouter):
        scheme = "tcp" if hasattr(backend, "clients") else "shard"
    elif isinstance(backend, MutableStringStore):
        scheme = "mut"
    elif isinstance(backend, CompressedStringStore):
        scheme = "file"
    else:
        raise TypeError(f"cannot wrap {type(backend).__name__}: expected a "
                        "store or a shard router")
    parsed = StoreURL(scheme=scheme)
    return _make_client(backend, parsed, url or f"{scheme}://<wrapped>",
                        opts, owns_backend=False)
