"""AdamW with optional 8-bit block-quantised moments (distributed trick #1).

No optax in this environment — the optimizer is implemented from scratch as
pure pytree transforms. The 8-bit variant stores both Adam moments as int8
with per-block (256-element) f32 scales: 2.06 bytes/param of optimizer state
instead of 8, which is what lets the 398B hybrid fit a 256-chip pod
(EXPERIMENTS.md §Dry-run). Moments follow the params' sharding extended by
the ZeRO-1 'data' axis (repro.distributed.sharding).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_moments: bool = False


# ----------------------------------------------------------- 8-bit moments
def _pad_len(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def quantize_q8(x: jnp.ndarray) -> dict:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = _pad_len(n) - n
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_q8(qs: dict, shape) -> jnp.ndarray:
    blocks = qs["q"].astype(jnp.float32) * qs["scale"]
    return blocks.reshape(-1)[: _deq_size(shape)].reshape(shape)


def _deq_size(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ------------------------------------------------------------------- state
def init_state(params, cfg: AdamWConfig):
    def zeros_like_moment(p):
        if cfg.quantized_moments:
            n = _pad_len(p.size)
            return {"q": jnp.zeros((n // BLOCK, BLOCK), jnp.int8),
                    "scale": jnp.zeros((n // BLOCK, 1), jnp.float32)}
        return jnp.zeros_like(p, dtype=jnp.float32)

    return {
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params, cfg: AdamWConfig):
    return jax.eval_shape(partial(init_state, cfg=cfg), abstract_params)


# ------------------------------------------------------------------ update
def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray | float = 1.0):
    """One AdamW step. Returns (new_params, new_state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.quantized_moments:
            mf = dequantize_q8(m, p.shape)
            vf = dequantize_q8(v, p.shape)
        else:
            mf, vf = m, v
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * jnp.square(g)
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - lr * (step + cfg.weight_decay * p.astype(jnp.float32)))
        if cfg.quantized_moments:
            return newp.astype(p.dtype), quantize_q8(mf), quantize_q8(vf)
        return newp.astype(p.dtype), mf, vf

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    is_q = cfg.quantized_moments
    leafq = (lambda x: isinstance(x, dict) and "q" in x) if is_q else None
    flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=leafq)[0]
    flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=leafq)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


# ---------------------------------------------------------------- schedule
def cosine_schedule(step: jnp.ndarray, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1) -> jnp.ndarray:
    """Relative LR multiplier: linear warmup then cosine to `floor`."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, warmup))
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
