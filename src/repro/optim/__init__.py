"""repro subpackage."""
