"""``python -m repro.net <shard-dir>`` — run one shard server process."""

from repro.net.shard_server import main

if __name__ == "__main__":
    main()
