"""repro.net — the multi-process serving tier.

Turns the shard directories written by ``repro.distributed.shard_store``
into a retrieval *service*: each shard host runs a :class:`ShardServer`
process over its directory, and a :class:`DistributedStringStore` routes
global ids across them with the same contract as the in-process
``ShardedStringStore`` (they share one ``ShardRouter`` base).

  protocol      — compact length-prefixed binary framing over TCP
                  (stdlib + numpy only; no jax, no RPC frameworks)
  shard_server  — ShardServer: one shard directory behind a socket, all
                  connections coalesced through one StoreService worker
  router        — RemoteShardClient (pooled, reconnecting) +
                  DistributedStringStore (concurrent per-shard fan-out,
                  replica-backed compaction hand-off)
"""

from repro.net.protocol import (
    FrameTooLargeError,
    ProtocolError,
    RemoteError,
    TruncatedFrameError,
)
from repro.net.router import DistributedStringStore, RemoteShardClient
from repro.net.shard_server import ShardServer

__all__ = [
    "DistributedStringStore",
    "FrameTooLargeError",
    "ProtocolError",
    "RemoteError",
    "RemoteShardClient",
    "ShardServer",
    "TruncatedFrameError",
]
