"""ShardServer — one process serving one shard directory over TCP.

The process form of the serving story: a host owning ``<dir>/shard-000k``
opens it (shared dictionary artifact + its corpus slice, writable by
default) and answers the :mod:`repro.net.protocol` ops. Every connection is
a thread, but ALL reads funnel through one shared
:class:`~repro.store.service.StoreService` — concurrent connections'
``get``/``multiget`` requests coalesce into single batched store decodes,
and their ``append``/``extend`` requests fold into single Encoder passes,
so the micro-batching that made the in-process service fast survives the
move to sockets unchanged.

Run one per shard::

    python -m repro.net.shard_server /data/corpus/shard-0002 --port 9102
    python -m repro.launch.serve --shard-server /data/corpus/shard-0002

With ``--port 0`` the kernel assigns a free port and the server prints
``SHARD_SERVER_READY port=<p> ...`` on stdout — spawners (the example, the
rpc benchmark, tests) parse that line instead of racing for free ports.
``--read-only`` serves a replica: same directory, current versioned
generation, appends and compaction refused — the hand-off target a router
drains reads to while the primary rewrites itself.

Set ``REPRO_NO_JAX=1`` in the environment to skip the jax import and serve
on the numpy decode path (fast startup; what a CPU-only serving host runs).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import socket
import socketserver
import threading

from repro.net import protocol as P
from repro.obs import REGISTRY, TRACER, Counter, start_metrics_server
from repro.store.mutable import MutableStringStore
from repro.store.service import StoreService
from repro.store.store import CompressedStringStore

_SHARD_DIR_RE = re.compile(r"^shard-(\d{4})$")


def open_serving_store(
    path: str,
    read_only: bool = False,
    **overrides,
) -> CompressedStringStore:
    """Open ``path`` for serving.

    ``<parent>/shard-000k`` directories open through
    :func:`repro.distributed.shard_store.open_shard` (shared dictionary in
    the parent); anything else opens as a plain store directory. Writable
    unless ``read_only`` — a read-only open of a versioned shard serves its
    current generation, which is exactly what a compaction replica needs.
    """
    from repro.distributed.shard_store import MANIFEST, open_shard

    path = os.path.abspath(path)
    m = _SHARD_DIR_RE.match(os.path.basename(path))
    parent = os.path.dirname(path)
    if m and os.path.exists(os.path.join(parent, MANIFEST)):
        return open_shard(parent, int(m.group(1)), writable=not read_only, **overrides)
    if read_only:
        return CompressedStringStore.open(path, **overrides)
    return MutableStringStore.open(path, **overrides)


class _Handler(socketserver.BaseRequestHandler):
    """One connection: read frames until EOF, answer each synchronously.

    Concurrency comes from the threading server (one handler thread per
    connection) plus the shared StoreService batching across handlers —
    within a connection, requests pipeline strictly in order.
    """

    def handle(self) -> None:
        shard: "ShardServer" = self.server.shard_server  # type: ignore[attr-defined]
        sock = self.request
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while True:
            try:
                frame = P.recv_frame_ex(sock, max_frame=shard.max_frame)
            except P.FrameTooLargeError as exc:
                # refuse loudly so the client sees WHY, then close: the
                # payload was never read, the stream cannot resynchronise
                try:
                    P.send_frame(sock, P.ST_ERR, P.pack_error(exc))
                except OSError:
                    pass
                return
            except P.ProtocolError:
                return  # torn/hostile frame: drop the connection
            except OSError:
                return
            if frame is None:
                return  # clean EOF
            kind, payload, trace = frame
            opname = P.OP_NAMES.get(kind, hex(kind))
            # a v2 frame's trace header joins this server's spans to the
            # client's trace; v1 frames dispatch untraced (span() no-ops)
            prev = TRACER.activate(trace) if trace is not None else None
            try:
                with TRACER.span(f"server.{opname}"):
                    resp = shard.dispatch(kind, payload)
                status = P.ST_OK
            except Exception as exc:
                resp = P.pack_error(exc)
                status = P.ST_ERR
            finally:
                if trace is not None:
                    TRACER.restore(prev)
            try:
                P.send_frame(sock, status, resp)
            except OSError:
                return


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ShardServer:
    """TCP front-end over one store: the per-shard serving process."""

    def __init__(
        self,
        store: CompressedStringStore,
        host: str = "127.0.0.1",
        port: int = 0,
        max_batch: int = 256,
        max_wait_s: float = 0.0005,
        max_frame: int = P.DEFAULT_MAX_FRAME,
        target_p99_s: float | None = None,
    ):
        self.store = store
        self.max_frame = int(max_frame)
        self.service = StoreService(
            store,
            max_batch=max_batch,
            max_wait_s=max_wait_s,
            target_p99_s=target_p99_s,
        )
        # per-op request counters, exported via stats() and /metrics — the
        # observability a router-side test (or operator) uses to see WHICH
        # server answered. Counter.inc() is lock-protected: dispatch() runs
        # concurrently on per-connection handler threads, and a lost
        # increment would make replica-routing assertions flake.
        self._op_counters: dict[str, Counter] = {}
        self._op_lock = threading.Lock()  # guards counter *creation* only
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.shard_server = self  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ server
    @classmethod
    def from_dir(
        cls,
        path: str,
        read_only: bool = False,
        host: str = "127.0.0.1",
        port: int = 0,
        **kw,
    ) -> "ShardServer":
        service_kw = {
            k: kw.pop(k)
            for k in ("max_batch", "max_wait_s", "max_frame", "target_p99_s")
            if k in kw
        }
        store = open_serving_store(path, read_only=read_only, **kw)
        return cls(store, host=host, port=port, **service_kw)

    @property
    def address(self) -> tuple[str, int]:
        host, port = self._tcp.server_address[:2]
        return str(host), int(port)

    @property
    def port(self) -> int:
        return self.address[1]

    def start(self) -> "ShardServer":
        """Serve in a background thread (tests / in-process topologies)."""
        self._thread = threading.Thread(
            target=self._tcp.serve_forever,
            kwargs={"poll_interval": 0.1},
            daemon=True,
            name=f"shard-server-{self.port}",
        )
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._tcp.serve_forever(poll_interval=0.2)

    def close(self) -> None:
        self._tcp.shutdown()
        self._tcp.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self.service.close()

    def __enter__(self) -> "ShardServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def op_counts(self) -> dict[str, int]:
        """Per-op request counts as a plain dict (`.get(op, 0)` friendly)."""
        with self._op_lock:
            return {name: c.value for name, c in self._op_counters.items()}

    def _count_op(self, opname: str) -> None:
        with self._op_lock:
            counter = self._op_counters.get(opname)
            if counter is None:
                counter = self._op_counters[opname] = REGISTRY.register(
                    Counter("repro_rpc_requests_total",
                            labels={"op": opname}))
        counter.inc()

    # ---------------------------------------------------------------- dispatch
    def dispatch(self, kind: int, payload: bytes) -> bytes:
        self._count_op(P.OP_NAMES.get(kind, hex(kind)))
        if kind == P.OP_PING:
            if payload == P.CAPS_PROBE:
                # capability negotiation: an old server would echo the probe
                # verbatim; answering with JSON is what marks us trace-aware
                return P.pack_json(P.SERVER_CAPS)
            return payload
        if kind == P.OP_GET:
            (i,) = P.unpack_ids(payload)
            return self.service.submit(i).result()
        if kind == P.OP_MULTIGET:
            ids = P.unpack_ids(payload)
            return P.pack_bytes_list(self.service.submit_multiget(ids).result())
        if kind == P.OP_SCAN:
            lo, hi = P.unpack_ids(payload)
            return P.pack_bytes_list(self.store.scan(lo, hi))
        if kind == P.OP_APPEND:
            return P.pack_ids(self.service.submit_extend([payload]).result())
        if kind == P.OP_EXTEND:
            strings = P.unpack_bytes_list(payload)
            return P.pack_ids(self.service.submit_extend(strings).result())
        if kind == P.OP_STATS:
            opts = P.unpack_json(payload) if payload else {}
            stats = self.stats()
            if opts.get("metrics"):
                # registry snapshot extension: mergeable histogram/counter
                # states for client-side cross-shard aggregation
                stats["metrics"] = REGISTRY.snapshot()
            return P.pack_json(stats)
        if kind == P.OP_LOCATE:
            strings = P.unpack_bytes_list(payload)
            found = self.store.locate_batch(strings)
            # None has no <i8 encoding: misses travel as -1
            return P.pack_ids([-1 if gid is None else gid for gid in found])
        if kind == P.OP_SCAN_PREFIX:
            prefix, limit, after = P.unpack_prefix_query(payload)
            return P.pack_prefix_hits(self.store.scan_prefix(prefix, limit, after))
        if kind == P.OP_TRACE_DUMP:
            n = (P.unpack_json(payload) or {}).get("n", 16) if payload else 16
            return P.pack_json(TRACER.trace_dump(n))
        if kind == P.OP_COMPACT:
            if not hasattr(self.store, "compact"):
                raise TypeError("store is read-only; compact() refused")
            kw = P.unpack_json(payload) if payload else {}
            # runs in this connection's handler thread: other connections
            # keep being served while the store rewrites itself
            return P.pack_json(self.store.compact(**kw))
        if kind == P.OP_TIER:
            from repro.store.tier import tier_op

            req = P.unpack_json(payload) if payload else {}
            return P.pack_json(
                tier_op(
                    self.store,
                    action=req.get("action", "stats"),
                    segment=req.get("segment"),
                    params=req.get("params"),
                )
            )
        if kind == P.OP_SAVE:
            target = getattr(self.store, "_dir", None)
            if not hasattr(self.store, "extend") or target is None:
                raise TypeError(
                    "store is read-only or has no backing directory; save refused"
                )
            self.store.save(target)
            return P.pack_json({"dir": target, "n_strings": self.store.n_strings})
        raise P.ProtocolError(f"unknown op 0x{kind:02x}")

    def stats(self) -> dict:
        ops = self.op_counts
        return {
            "n_strings": self.store.n_strings,
            "writable": hasattr(self.store, "extend"),
            "ops": ops,
            "store": self.store.stats_snapshot(),
            "service": self.service.stats(),
        }


def run(
    path: str,
    host: str = "127.0.0.1",
    port: int = 0,
    read_only: bool = False,
    max_batch: int = 256,
    max_wait_s: float = 0.0005,
    target_p99_s: float | None = None,
    announce: bool = True,
    metrics_port: int | None = None,
    encode_backend: str | None = None,
) -> None:
    """Open the store, print the readiness line, serve until interrupted.

    ``metrics_port`` (0 = kernel-assigned) additionally serves Prometheus
    text on ``http://<host>:<metrics_port>/metrics`` plus the slow-request
    trace dump on ``/traces``; the bound port rides the readiness line as
    ``metrics_port=``.
    """
    # only writable opens understand the knob: a read-only replica never
    # encodes, and CompressedStringStore.open has no such parameter
    write_kw = ({} if read_only or encode_backend is None
                else {"encode_backend": encode_backend})
    server = ShardServer.from_dir(
        path,
        read_only=read_only,
        host=host,
        port=port,
        max_batch=max_batch,
        max_wait_s=max_wait_s,
        target_p99_s=target_p99_s,
        **write_kw,
    )
    metrics = (start_metrics_server(port=metrics_port, host=host)
               if metrics_port is not None else None)
    if announce:
        extra = f" metrics_port={metrics.port}" if metrics is not None else ""
        print(
            f"SHARD_SERVER_READY port={server.port} "
            f"n_strings={server.store.n_strings} "
            f"writable={int(hasattr(server.store, 'extend'))}"
            f"{extra} "
            f"dir={json.dumps(path)}",
            flush=True,
        )
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        if metrics is not None:
            metrics.close()
        server.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dir", help="shard directory (<parent>/shard-000k) or store dir")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0, help="0 = kernel-assigned")
    ap.add_argument(
        "--read-only",
        action="store_true",
        help="serve as a replica: appends and compaction refused",
    )
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-s", type=float, default=0.0005)
    ap.add_argument(
        "--metrics-port",
        type=int,
        default=None,
        help="also serve Prometheus /metrics + /traces on this port "
        "(0 = kernel-assigned; reported as metrics_port= on the READY line)",
    )
    ap.add_argument(
        "--encode-backend",
        choices=("numpy", "pallas"),
        default=None,
        help="tail Encoder backend for writable opens (default: whatever "
        "the store's saved meta says; pallas needs jax on this host)",
    )
    ap.add_argument(
        "--target-p99-ms",
        type=float,
        default=None,
        help="enable the adaptive micro-batching window: the service tunes "
        "max_wait_s toward the largest value whose observed request p99 "
        "stays under this target",
    )
    args = ap.parse_args(argv)
    run(
        args.dir,
        host=args.host,
        port=args.port,
        read_only=args.read_only,
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_s,
        target_p99_s=(
            None if args.target_p99_ms is None else args.target_p99_ms / 1e3
        ),
        metrics_port=args.metrics_port,
        encode_backend=args.encode_backend,
    )


if __name__ == "__main__":
    main()
