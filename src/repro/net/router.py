"""RemoteShardClient + DistributedStringStore — the routing tier.

:class:`RemoteShardClient` speaks :mod:`repro.net.protocol` to one shard
server through a small connection pool (each in-flight request leases one
socket, so a slow ``compact`` on one connection never head-of-line-blocks a
``multiget`` on another) and transparently reconnects with capped
exponential backoff — a shard process that is killed and restarted is
re-found without the caller noticing more than latency.

:class:`DistributedStringStore` is the multi-process form of
:class:`~repro.distributed.shard_store.ShardedStringStore` and shares its
:class:`~repro.distributed.shard_store.ShardRouter` base, so the global
contract (order-preserving multiget, contiguous bounds, tail-owned appends)
is literally the same code — only the data plane swaps from in-process
stores to sockets, with ``multiget`` fanning out per shard concurrently.

Compaction hand-off: ``register_replica(shard, address)`` attaches a
read-only server (same directory, same versioned generation) to a shard.
While ``compact(shard)`` runs, reads covered by the replica drain to it and
appends targeting the shard park in a bounded retry queue; when the primary
returns (its new ``current.json`` generation is published at that point),
queued appends replay in arrival order and their callers get their ids —
acknowledged appends are never lost, and reads never wait on the rewrite.

Replica read load-balancing (ROADMAP): replicas are a *set* per shard, and
``read_preference`` routes reads across it outside compaction windows too —
``"replica"`` round-robins reads over the shard's covering replicas (falling
back to the primary when none is registered or none covers the requested
ids), ``"any"`` round-robins over primary + covering replicas, ``"primary"``
keeps the pre-v3 behaviour. The staleness guard is generational: a replica
serves the generation it opened, so it is only eligible for a read whose
ids it provably holds (its ``n_strings`` at registration / last compact
refresh); anything newer — appends acknowledged after the replica opened —
must come from the primary.
"""

from __future__ import annotations

import json
import os
import queue
import socket
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

from repro.distributed.shard_store import (
    MANIFEST,
    ShardRouter,
    check_read_preference,
)
from repro.net import protocol as P
from repro.obs import TRACER
from repro.store.store import write_json_atomic


#: ops safe to re-send after a transport failure mid-exchange — everything
#: else (append/extend/compact/save) may already have been applied by a
#: slow-but-alive server, so blind resends would duplicate work
_IDEMPOTENT_OPS = frozenset(
    {P.OP_PING, P.OP_GET, P.OP_MULTIGET, P.OP_SCAN, P.OP_STATS,
     P.OP_TRACE_DUMP, P.OP_LOCATE, P.OP_SCAN_PREFIX, P.OP_TIER}
)


class RemoteShardClient:
    """Pooled, reconnecting RPC client for one shard server.

    Reads reconnect and retry transparently. Writes retry only while a
    connection cannot be *established*; once a write has been put on the
    wire, a transport failure surfaces as ConnectionError instead of
    resending — the server may already have applied it, and duplicating
    appends silently is worse than making the caller decide.
    """

    def __init__(
        self,
        address: tuple[str, int],
        timeout: float = 30.0,
        pool_size: int = 4,
        reconnect_attempts: int = 16,
        retry_delay_s: float = 0.05,
        max_retry_delay_s: float = 0.5,
        max_frame: int = P.DEFAULT_MAX_FRAME,
    ):
        self.address = (str(address[0]), int(address[1]))
        self.timeout = float(timeout)
        self.pool_size = int(pool_size)
        self.reconnect_attempts = int(reconnect_attempts)
        self.retry_delay_s = float(retry_delay_s)
        self.max_retry_delay_s = float(max_retry_delay_s)
        self.max_frame = int(max_frame)
        self._pool: queue.LifoQueue = queue.LifoQueue()
        self._closed = False
        self.reconnects = 0
        #: does the server speak trace-header (v2) frames? None = unknown —
        #: resolved lazily by a CAPS_PROBE ping the first time a traced
        #: request goes out, so old servers are never sent v2 frames
        self._traced: bool | None = None
        #: full capability dict from the probe ({} for an old echo-only
        #: server, None until a probe has run)
        self._caps: dict | None = None

    # ------------------------------------------------------------ connections
    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.address, timeout=self.timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _checkout(self) -> socket.socket:
        try:
            return self._pool.get_nowait()
        except queue.Empty:
            return self._connect()

    def _checkin(self, sock: socket.socket) -> None:
        if self._closed or self._pool.qsize() >= self.pool_size:
            sock.close()
        else:
            self._pool.put(sock)

    def close(self) -> None:
        self._closed = True
        while True:
            try:
                self._pool.get_nowait().close()
            except queue.Empty:
                return

    def __enter__(self) -> "RemoteShardClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------------- calls
    def _probe_caps(self) -> bool:
        """Resolve whether the server understands trace-header frames.

        One :data:`~repro.net.protocol.CAPS_PROBE` ping: an old server's
        ping handler echoes the probe verbatim, a trace-aware server answers
        a capability JSON — the difference IS the negotiation, so no new op
        (which an old server would reject) is needed.
        """
        resp = self._exchange(P.OP_PING, P.CAPS_PROBE, -1.0, None)
        caps = None
        if resp != P.CAPS_PROBE:
            try:
                caps = P.unpack_json(resp)
            except Exception:
                caps = None
        self._caps = caps if isinstance(caps, dict) else {}
        self._traced = bool(caps) and bool(caps.get("trace"))
        return self._traced

    @property
    def supports_locate(self) -> bool:
        """Does the server answer OP_LOCATE / OP_SCAN_PREFIX? Resolved by
        the same one-shot CAPS_PROBE as trace support; an old server's echo
        resolves to False and callers fall back to scan-side filtering."""
        if self._caps is None:
            self._probe_caps()
        return bool(self._caps and self._caps.get("locate"))

    @property
    def supports_tier(self) -> bool:
        """Does the server answer OP_TIER (cold-tier control)? Same
        one-shot CAPS_PROBE; an old server's echo resolves to False and
        tier calls report {"enabled": False} instead of erroring."""
        if self._caps is None:
            self._probe_caps()
        return bool(self._caps and self._caps.get("tier"))

    def _call(self, op: int, payload: bytes = b"", timeout: float = -1.0) -> bytes:
        """One request/response exchange, traced when a request trace is
        active: the exchange gets an ``rpc.<op>`` span and — once a caps
        probe has confirmed the server is trace-aware — the span's context
        rides the frame header so server-side spans join the same trace."""
        if TRACER.current() is None:
            return self._exchange(op, payload, timeout, None)
        if self._traced is None and op != P.OP_PING:
            try:
                self._probe_caps()
            except Exception:
                pass  # unreachable/hostile: this call goes untraced on wire
        with TRACER.span(f"rpc.{P.OP_NAMES.get(op, hex(op))}",
                         shard=f"{self.address[0]}:{self.address[1]}") as ctx:
            return self._exchange(op, payload, timeout,
                                  ctx if self._traced else None)

    def _exchange(self, op: int, payload: bytes, timeout: float,
                  trace) -> bytes:
        """The raw exchange; reconnect-and-retry on transport failure (dead
        socket, truncated frame) for idempotent ops, never on application
        errors (those arrive as ST_ERR and re-raise once).

        ``timeout=None`` blocks for as long as the server works (compaction
        can legitimately outlast the default request timeout); the
        ``-1.0`` sentinel means "use the client's configured timeout".
        """
        if self._closed:
            raise RuntimeError("client is closed")
        last: Exception | None = None
        for attempt in range(self.reconnect_attempts + 1):
            if attempt:
                self.reconnects += 1
                time.sleep(
                    min(
                        self.retry_delay_s * (2 ** (attempt - 1)),
                        self.max_retry_delay_s,
                    )
                )
            try:
                sock = self._checkout()
            except OSError as exc:
                last = exc  # nothing was sent: always safe to retry
                continue
            sock.settimeout(self.timeout if timeout == -1.0 else timeout)
            try:
                P.send_frame(sock, op, payload, trace=trace)
                frame = P.recv_frame(sock, max_frame=self.max_frame)
                if frame is None:
                    raise P.TruncatedFrameError("server closed before answering")
            except (OSError, P.TruncatedFrameError) as exc:
                sock.close()
                if op in _IDEMPOTENT_OPS:
                    last = exc
                    continue
                # a write already on the wire may have been applied — do not
                # resend it; surface the uncertainty to the caller instead
                raise ConnectionError(
                    f"{P.OP_NAMES.get(op, hex(op))} to {self.address[0]}:"
                    f"{self.address[1]} failed mid-exchange; the server may "
                    "or may not have applied it"
                ) from exc
            except P.ProtocolError:
                # oversized/garbled response: the stream cannot be reused
                sock.close()
                raise
            status, resp = frame
            sock.settimeout(self.timeout)
            self._checkin(sock)
            if status == P.ST_ERR:
                P.raise_remote(resp)
            if status != P.ST_OK:
                raise P.ProtocolError(f"unexpected response status 0x{status:02x}")
            return resp
        raise ConnectionError(
            f"shard server {self.address[0]}:{self.address[1]} unreachable "
            f"after {self.reconnect_attempts + 1} attempts"
        ) from last

    def ping(self, payload: bytes = b"") -> bytes:
        return self._call(P.OP_PING, payload)

    def get(self, i: int) -> bytes:
        return self._call(P.OP_GET, P.pack_ids([i]))

    def multiget(self, ids) -> list[bytes]:
        return P.unpack_bytes_list(self._call(P.OP_MULTIGET, P.pack_ids(ids)))

    def scan(self, lo: int, hi: int) -> list[bytes]:
        return P.unpack_bytes_list(self._call(P.OP_SCAN, P.pack_ids([lo, hi])))

    def locate_batch(self, strings) -> list[int | None]:
        """Shard-local ids of ``strings``; misses travel as -1 on the wire
        and come back as None."""
        resp = self._call(
            P.OP_LOCATE, P.pack_bytes_list([bytes(s) for s in strings])
        )
        return [None if gid < 0 else gid for gid in P.unpack_ids(resp)]

    def scan_prefix(
        self,
        prefix: bytes,
        limit: int | None = 100,
        after: tuple[bytes, int] | None = None,
    ) -> list[tuple[int, bytes]]:
        resp = self._call(
            P.OP_SCAN_PREFIX, P.pack_prefix_query(bytes(prefix), limit, after)
        )
        return P.unpack_prefix_hits(resp)

    def append(self, s: bytes) -> int:
        return P.unpack_ids(self._call(P.OP_APPEND, bytes(s)))[0]

    def extend(self, strings: list[bytes]) -> list[int]:
        return P.unpack_ids(self._call(P.OP_EXTEND, P.pack_bytes_list(strings)))

    def stats(self, metrics: bool = False) -> dict:
        """Server stats; ``metrics=True`` additionally asks for the server's
        registry snapshot (mergeable histogram/counter states)."""
        payload = P.pack_json({"metrics": True}) if metrics else b""
        return P.unpack_json(self._call(P.OP_STATS, payload))

    def trace_dump(self, n: int = 16) -> list[dict]:
        """The server's slow-request log: its ``n`` slowest recent traces."""
        return P.unpack_json(
            self._call(P.OP_TRACE_DUMP, P.pack_json({"n": int(n)})))

    def tier(
        self,
        action: str = "stats",
        segment: int | None = None,
        params: dict | None = None,
    ) -> dict:
        """Tier control on the shard server: ``stats`` / ``demote`` /
        ``promote`` (``segment=None`` acts on every eligible segment).
        Servers predating OP_TIER report ``{"enabled": False}``."""
        if not self.supports_tier:
            return {"enabled": False}
        req: dict = {"action": action}
        if segment is not None:
            req["segment"] = int(segment)
        if params:
            req["params"] = params
        # demotion re-encodes whole segments: let it outlast the timeout
        return P.unpack_json(
            self._call(P.OP_TIER, P.pack_json(req), timeout=None)
        )

    def compact(self, **kw) -> dict:
        # retrain + rewrite can far outlast the request timeout: block
        return P.unpack_json(
            self._call(P.OP_COMPACT, P.pack_json(kw) if kw else b"", timeout=None)
        )

    def save(self) -> dict:
        return P.unpack_json(self._call(P.OP_SAVE, timeout=None))

    @property
    def n_strings(self) -> int:
        return int(self.stats()["n_strings"])


class DistributedStringStore(ShardRouter):
    """Global-id router over per-shard RPC servers (multi-process form)."""

    def __init__(
        self,
        clients: list[RemoteShardClient],
        bounds: list[tuple[int, int]],
        dir_path: str | None = None,
        max_workers: int | None = None,
        max_pending_appends: int = 1024,
        scan_chunk: int = 4096,
        read_preference: str = "primary",
    ):
        if len(clients) != len(bounds):
            raise ValueError("one client per shard bound required")
        super().__init__(bounds, dir_path=dir_path,
                         read_preference=read_preference)
        self.clients = clients
        self.max_pending_appends = int(max_pending_appends)
        self.scan_chunk = int(scan_chunk)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or min(32, 2 * max(1, len(clients))),
            thread_name_prefix="dstore",
        )
        #: per-shard replica SET: [client, covered_n_strings] pairs. The
        #: covered count is the generational staleness guard — a replica is
        #: only eligible for reads it provably holds.
        self._replicas: dict[int, list[list]] = {}
        self._rr: dict[int, int] = {}  # round-robin cursors (races benign)
        self._draining: dict[int, bool] = {}
        self._pending: dict[int, queue.Queue] = {}
        self._flush_locks: dict[int, threading.Lock] = {}

    @classmethod
    def connect(
        cls,
        addresses,
        bounds: list[tuple[int, int]] | None = None,
        dir_path: str | None = None,
        client_kw: dict | None = None,
        auto_replicas: bool = True,
        **kw,
    ) -> "DistributedStringStore":
        """Connect to shard servers (``[(host, port), ...]``, in shard
        order). Without explicit ``bounds`` each shard is asked its
        ``n_strings`` and the contiguous global bounds are derived — the
        live-cluster equivalent of reading the manifest.

        With ``dir_path`` (and ``auto_replicas`` left on) any replica
        addresses recorded in the cluster manifest
        (:func:`repro.distributed.shard_store.record_replicas`) register
        automatically, so ``read_preference="replica"|"any"`` load-balances
        without manual wiring. A recorded replica that is down or refuses
        (e.g. restarted writable) is skipped — discovery must not fail the
        connect."""
        clients = [RemoteShardClient(a, **(client_kw or {})) for a in addresses]
        try:
            if bounds is None:
                bounds = []
                lo = 0
                for c in clients:
                    n = c.n_strings
                    bounds.append((lo, lo + n))
                    lo += n
            store = cls(clients, bounds, dir_path=dir_path, **kw)
        except BaseException:
            # bounds derivation already opened sockets (n_strings is an
            # RPC); a dead shard or a bad constructor kwarg must not leak
            # the ones that connected
            for c in clients:
                c.close()
            raise
        if auto_replicas and dir_path is not None:
            store.discover_replicas(client_kw=client_kw)
        return store

    def discover_replicas(self, client_kw: dict | None = None) -> int:
        """Register every manifest-recorded replica not already attached;
        returns how many registered. Callable again after a spawner adds
        replicas to a live cluster."""
        if self._dir is None:
            return 0
        from repro.distributed.shard_store import manifest_replicas

        registered = 0
        for shard, addrs in manifest_replicas(self._dir).items():
            if not 0 <= shard < len(self.clients):
                continue
            known = {c.address for c, _ in self._replicas.get(shard, ())}
            for addr in addrs:
                addr = (str(addr[0]), int(addr[1]))
                if addr in known or addr == self.clients[shard].address:
                    continue
                try:
                    self.register_replica(shard, addr, **(client_kw or {}))
                    registered += 1
                except (OSError, ConnectionError, ValueError):
                    continue  # down or not a read-only replica: skip
        return registered

    def close(self) -> None:
        self._pool.shutdown(wait=False)
        for c in self.clients:
            c.close()
        for replicas in self._replicas.values():
            for c, _ in replicas:
                c.close()

    def __enter__(self) -> "DistributedStringStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- data plane
    def _covering_replicas(self, k: int, max_local: int) -> list[RemoteShardClient]:
        """Replicas of shard k whose registered generation holds every
        requested id (the staleness guard: a replica serves the generation
        it opened, so ids at or beyond its covered count must come from the
        primary)."""
        return [c for c, n in self._replicas.get(k, ()) if max_local < n]

    def _round_robin(
        self, k: int, candidates: list[RemoteShardClient]
    ) -> RemoteShardClient:
        cursor = self._rr.get(k, 0)
        self._rr[k] = cursor + 1
        return candidates[cursor % len(candidates)]

    def _read_client(
        self, k: int, max_local: int, read_preference: str | None = None
    ) -> RemoteShardClient:
        """Resolve which server answers a read of shard ``k``.

        While the shard drains (compact in flight) any covering replica wins
        regardless of preference — that is the hand-off. Otherwise
        ``read_preference`` decides: ``replica`` round-robins over covering
        replicas (primary as fallback), ``any`` round-robins over primary +
        covering replicas, ``primary`` (default) always hits the primary.
        """
        pref = check_read_preference(read_preference or self.read_preference)
        covering = self._covering_replicas(k, max_local)
        if covering:
            if self._draining.get(k) or pref == "replica":
                return self._round_robin(k, covering)
            if pref == "any":
                return self._round_robin(k, [self.clients[k]] + covering)
        return self.clients[k]

    def _shard_multiget(
        self, k: int, local_ids: list[int], read_preference: str | None = None
    ) -> list[bytes]:
        client = self._read_client(
            k, max(local_ids) if local_ids else -1, read_preference
        )
        return client.multiget(local_ids)

    def _shard_scan(
        self, k: int, lo: int, hi: int, read_preference: str | None = None
    ) -> list[bytes]:
        """Range decode in bounded-count chunks: one giant scan response
        would trip the protocol's max_frame refusal; N modest RPCs stream
        the same bytes."""
        out: list[bytes] = []
        for c_lo in range(lo, hi, self.scan_chunk):
            c_hi = min(c_lo + self.scan_chunk, hi)
            # re-resolve per chunk so replica round-robin spreads a long
            # scan across the whole replica set
            client = self._read_client(k, c_hi - 1, read_preference)
            out.extend(client.scan(c_lo, c_hi))
        return out

    def _shard_stats(self, k: int) -> dict:
        return self.clients[k].stats()

    def _shard_locate(
        self, k: int, strings: list[bytes], read_preference: str | None = None
    ) -> list[int | None]:
        """Reverse lookup on shard ``k``. A locate can match ANY id in the
        shard, so only replicas covering the whole shard are eligible (the
        generational staleness guard with max_local = shard size - 1).
        Servers predating OP_LOCATE fall back to a scan-side compare."""
        lo, hi = self.bounds[k]
        client = self._read_client(k, hi - lo - 1, read_preference)
        if client.supports_locate:
            return client.locate_batch(strings)
        return self._scan_locate_fallback(k, strings, read_preference)

    def _scan_locate_fallback(
        self, k: int, strings: list[bytes], read_preference: str | None
    ) -> list[int | None]:
        """Old-server interop: stream the shard in scan chunks and compare
        raw strings client-side. First (lowest) local id wins, matching the
        index semantics; stops as soon as every query has resolved."""
        want: dict[bytes, list[int]] = {}
        for pos, s in enumerate(strings):
            want.setdefault(s, []).append(pos)
        out: list[int | None] = [None] * len(strings)
        unresolved = len(want)
        lo, hi = self.bounds[k]
        for c_lo in range(0, hi - lo, self.scan_chunk):
            if not unresolved:
                break
            c_hi = min(c_lo + self.scan_chunk, hi - lo)
            chunk = self._shard_scan(k, c_lo, c_hi, read_preference)
            for off, s in enumerate(chunk):
                positions = want.get(s)
                if positions is None or out[positions[0]] is not None:
                    continue
                for pos in positions:
                    out[pos] = c_lo + off
                unresolved -= 1
        return out

    def _shard_scan_prefix(
        self,
        k: int,
        prefix: bytes,
        limit: int | None,
        after: tuple[bytes, int] | None,
        read_preference: str | None = None,
    ) -> list[tuple[int, bytes]]:
        lo, hi = self.bounds[k]
        client = self._read_client(k, hi - lo - 1, read_preference)
        if client.supports_locate:
            return client.scan_prefix(prefix, limit, after)
        # old-server interop: stream the shard and filter client-side
        hits: list[tuple[bytes, int]] = []
        for c_lo in range(0, hi - lo, self.scan_chunk):
            c_hi = min(c_lo + self.scan_chunk, hi - lo)
            chunk = self._shard_scan(k, c_lo, c_hi, read_preference)
            for off, s in enumerate(chunk):
                local = c_lo + off
                if not s.startswith(prefix):
                    continue
                if after is not None and (s, local) <= after:
                    continue
                hits.append((s, local))
        hits.sort()
        if limit is not None:
            hits = hits[:limit]
        return [(local, s) for s, local in hits]

    def _shard_tier(
        self,
        k: int,
        action: str = "stats",
        segment: int | None = None,
        params: dict | None = None,
    ) -> dict:
        # tier control always targets the primary: demotion state lives
        # with the store that owns the segment files
        return self.clients[k].tier(action, segment=segment, params=params)

    def _fanout_multiget(
        self,
        jobs: list[tuple[int, list[int]]],
        read_preference: str | None = None,
    ) -> list[list[bytes]]:
        """Per-shard fan-out on the pool: one RPC per touched shard, all in
        flight concurrently; reassembly order is the caller's job list."""
        if len(jobs) == 1:  # don't pay executor latency for one shard
            k, local_ids = jobs[0]
            return [self._shard_multiget(k, local_ids, read_preference)]
        # pool threads have no ambient trace — re-activate the caller's so
        # each shard's rpc.multiget span lands in the same request trace
        ctx = TRACER.current()
        futs = [
            self._pool.submit(self._traced_shard_multiget, ctx, k, lids,
                              read_preference)
            for k, lids in jobs
        ]
        return [f.result() for f in futs]

    def _traced_shard_multiget(self, ctx, k, local_ids, read_preference):
        prev = TRACER.activate(ctx)
        try:
            return self._shard_multiget(k, local_ids, read_preference)
        finally:
            TRACER.restore(prev)

    def _tail_extend(self, strings: list[bytes]) -> tuple[list[int], int]:
        local_ids = self.clients[-1].extend(strings)
        if not local_ids:
            return local_ids, self.bounds[-1][1] - self.bounds[-1][0]
        return local_ids, local_ids[-1] + 1

    # ----------------------------------------------------------------- writes
    def extend(self, strings: list[bytes]) -> list[int]:
        """Append via the tail shard's primary; while that shard is
        compacting, park in the bounded retry queue instead and block until
        the post-compact replay acknowledges real ids."""
        k = len(self.clients) - 1
        if self._draining.get(k):
            fut: Future = Future()
            pending = self._pending[k]
            try:
                pending.put(
                    ([bytes(s) for s in strings], fut),
                    timeout=self.clients[k].timeout,
                )
            except queue.Full:
                raise RuntimeError(
                    f"append retry queue full ({self.max_pending_appends} "
                    "batches) while shard compacts — back off and retry"
                ) from None
            if not self._draining.get(k):
                # compact finished between the flag check and the put: the
                # flusher may already have drained past us — flush ourselves
                self._flush_pending(k)
            return fut.result()
        return super().extend(strings)

    def _flush_pending(self, k: int) -> None:
        """Replay parked appends in arrival order against the primary.

        The per-shard flush lock admits ONE drainer at a time: the compact
        thread's post-swap flush and an appender's double-check flush can
        race, and two concurrent drainers could otherwise interleave their
        ``extend`` calls and assign ids out of arrival order.
        """
        pending = self._pending.get(k)
        if pending is None:
            return
        with self._flush_locks[k]:
            while True:
                try:
                    strings, fut = pending.get_nowait()
                except queue.Empty:
                    return
                try:
                    fut.set_result(super().extend(strings))
                except Exception as exc:
                    fut.set_exception(exc)

    # -------------------------------------------------------------- lifecycle
    def register_replica(
        self, shard: int, address: tuple[str, int], **client_kw
    ) -> RemoteShardClient:
        """Attach a read-only replica server to ``shard``'s replica set
        (opened from the same directory's current versioned generation).
        Reads drain to the set during that shard's ``compact()``, and
        ``read_preference="replica"|"any"`` round-robins reads across it at
        any time."""
        client = RemoteShardClient(address, **client_kw)
        stats = client.stats()
        if stats.get("writable"):
            raise ValueError(
                f"replica for shard {shard} at {address} is writable — "
                "replicas must be started with --read-only"
            )
        self._replicas.setdefault(shard, []).append([client, int(stats["n_strings"])])
        return client

    def refresh_replicas(self, shard: int) -> None:
        """Re-read each replica's covered count (the staleness guard) — a
        replica restarted from a newer generation becomes eligible for the
        ids it now holds."""
        for pair in self._replicas.get(shard, ()):
            pair[1] = pair[0].n_strings

    def compact(self, shard: int | None = None, **kw) -> list[dict]:
        """Compact one shard (or all). With a registered replica the shard
        enters hand-off: reads drain to the replica, appends park in the
        retry queue, and both flip back the moment the primary has published
        its new generation."""
        targets = range(len(self.clients)) if shard is None else [shard]
        return [self._compact_one(k, **kw) for k in targets]

    def _compact_one(self, k: int, **kw) -> dict:
        if not self._replicas.get(k):
            return self.clients[k].compact(**kw)
        # refresh coverage: each replica serves ids it had when it opened
        self.refresh_replicas(k)
        self._pending.setdefault(k, queue.Queue(maxsize=self.max_pending_appends))
        self._flush_locks.setdefault(k, threading.Lock())
        self._draining[k] = True
        try:
            # blocking RPC: when it returns, the primary has swapped state
            # and (when directory-backed) published its new current.json
            return self.clients[k].compact(**kw)
        finally:
            self._draining[k] = False
            self._flush_pending(k)

    def save(self) -> list[dict]:
        """Ask every writable shard server to persist its generation, then
        rewrite the local manifest bounds when this router knows the
        directory (single-host topologies; remote routers leave the
        manifest to the operator)."""
        with self._write_lock:
            reports = []
            for k, c in enumerate(self.clients):
                if self._shard_stats(k).get("writable"):
                    reports.append(c.save())
            if self._dir is not None:
                path = os.path.join(self._dir, MANIFEST)
                with open(path) as f:
                    manifest = json.load(f)
                manifest.update(
                    n_strings=self.n_strings,
                    bounds=[list(b) for b in self.bounds],
                )
                write_json_atomic(path, manifest)
        return reports
