"""Length-prefixed binary framing for the shard RPC plane.

One frame per request and one per response, over a plain TCP socket::

    +-------+---------+------+-------------+----------------+
    | magic | version | kind | length (u32)| payload bytes  |
    | "RS"  |   0x01  | u8   | little-end. | length bytes   |
    +-------+---------+------+-------------+----------------+

``kind`` is a request op (``OP_*``) on the way in and a status
(``ST_OK``/``ST_ERR``) on the way out. Payloads are numpy-native packed
arrays — id vectors are raw ``<i8`` buffers and string batches are an
offsets-plus-blob container (:func:`pack_bytes_list`) — so a router or a
server moves ``multiget`` batches without any per-string Python framing.
Stdlib + numpy only: serving hosts need neither jax nor a third-party RPC
stack.

Frames above ``max_frame`` are refused *before* the payload is read
(:class:`FrameTooLargeError` — a malformed or hostile peer cannot make the
receiver allocate unbounded memory), and a socket that dies mid-frame
surfaces :class:`TruncatedFrameError` rather than a silent short read.

**Trace propagation (optional, version 2).** A frame whose ``version`` byte
is :data:`TRACED_VERSION` prefixes its payload with a fixed 24-byte trace
context — 16 hex chars of trace id + a ``u64`` parent span id — so a
request's trace follows it across the socket (``repro.obs``). The header
struct is unchanged and ``length`` covers the prefix, so any receiver that
understands v2 parses both versions; v1-only peers stay tolerated by never
*sending* them v2: a client probes capability once per server with an
:data:`OP_PING` carrying :data:`CAPS_PROBE` (an old server echoes the probe
verbatim — its ping is an echo — while a new server answers a capability
JSON), and only attaches trace headers when the probe came back positive.
"""

from __future__ import annotations

import json
import socket
import struct

import numpy as np

from repro.obs.trace import TraceContext

MAGIC = b"RS"
VERSION = 1
#: frame version whose payload starts with a 24-byte trace context
TRACED_VERSION = 2
_HEADER = struct.Struct("<2sBBI")
HEADER_BYTES = _HEADER.size
_TRACE_CTX = struct.Struct("<16sQ")
#: bytes of trace context prefixing a TRACED_VERSION payload
TRACE_CTX_BYTES = _TRACE_CTX.size

#: OP_PING payload a client sends to discover server capabilities: an old
#: server echoes it back byte-for-byte, a trace-aware server replies with a
#: capability JSON — the difference IS the negotiation.
CAPS_PROBE = b"\x00REPRO-CAPS\x00"
#: capabilities a trace-aware server answers the probe with; ``locate``
#: advertises the reverse-lookup ops (OP_LOCATE / OP_SCAN_PREFIX) so a
#: new client falls back to scan-side filtering against an old server
#: instead of tripping its unknown-op error path on every call
SERVER_CAPS = {
    "trace": True,
    "trace_version": TRACED_VERSION,
    "locate": True,
    "tier": True,
}

#: refuse frames above this size unless the caller raises the limit
DEFAULT_MAX_FRAME = 64 << 20

# request ops
OP_PING = 0x01
OP_GET = 0x02
OP_MULTIGET = 0x03
OP_SCAN = 0x04
OP_APPEND = 0x05
OP_EXTEND = 0x06
OP_STATS = 0x07
OP_COMPACT = 0x08
OP_SAVE = 0x09
OP_TRACE_DUMP = 0x0A
OP_LOCATE = 0x0B
OP_SCAN_PREFIX = 0x0C
OP_TIER = 0x0D

# response statuses
ST_OK = 0x40
ST_ERR = 0x41

OP_NAMES = {
    OP_PING: "ping",
    OP_GET: "get",
    OP_MULTIGET: "multiget",
    OP_SCAN: "scan",
    OP_APPEND: "append",
    OP_EXTEND: "extend",
    OP_STATS: "stats",
    OP_COMPACT: "compact",
    OP_SAVE: "save",
    OP_TRACE_DUMP: "trace_dump",
    OP_LOCATE: "locate",
    OP_SCAN_PREFIX: "scan_prefix",
    OP_TIER: "tier",
}


class ProtocolError(Exception):
    """Malformed frame: bad magic, unknown version, or unknown kind."""


class FrameTooLargeError(ProtocolError):
    """Declared payload length exceeds the receiver's ``max_frame``."""


class TruncatedFrameError(ProtocolError):
    """The stream ended (or the buffer ran out) mid-frame."""


class RemoteError(RuntimeError):
    """A server-side exception type the client does not re-raise natively."""


# --------------------------------------------------------------------- frames
def pack_trace(ctx: TraceContext) -> bytes:
    """Trace context -> the fixed 24-byte wire prefix."""
    return _TRACE_CTX.pack(ctx.trace_id.encode("ascii")[:16].ljust(16, b"0"),
                           ctx.span_id & (2**64 - 1))


def unpack_trace(raw: bytes) -> TraceContext:
    tid, span_id = _TRACE_CTX.unpack(raw)
    return TraceContext(tid.decode("ascii", "replace"), int(span_id))


def encode_frame(kind: int, payload: bytes = b"",
                 trace: TraceContext | None = None) -> bytes:
    """One wire frame: header + payload; with ``trace`` the frame is
    version :data:`TRACED_VERSION` and the payload is prefixed by the
    24-byte trace context (covered by ``length``)."""
    if trace is None:
        return _HEADER.pack(MAGIC, VERSION, kind, len(payload)) + payload
    prefix = pack_trace(trace)
    return (_HEADER.pack(MAGIC, TRACED_VERSION, kind,
                         len(prefix) + len(payload)) + prefix + payload)


def _decode_header_ex(
    header: bytes, max_frame: int = DEFAULT_MAX_FRAME
) -> tuple[int, int, int]:
    """Validate one header; returns ``(kind, payload_length, version)``."""
    if len(header) < HEADER_BYTES:
        raise TruncatedFrameError(
            f"frame header truncated: {len(header)} of {HEADER_BYTES} bytes"
        )
    magic, version, kind, length = _HEADER.unpack(header[:HEADER_BYTES])
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}")
    if version not in (VERSION, TRACED_VERSION):
        raise ProtocolError(f"unsupported protocol version {version}")
    if version == TRACED_VERSION and length < TRACE_CTX_BYTES:
        raise ProtocolError(
            f"traced frame of {length} bytes cannot hold its "
            f"{TRACE_CTX_BYTES}-byte trace context"
        )
    if length > max_frame:
        raise FrameTooLargeError(
            f"frame payload of {length} bytes exceeds max_frame={max_frame}"
        )
    return kind, length, version


def decode_header(header: bytes, max_frame: int = DEFAULT_MAX_FRAME) -> tuple[int, int]:
    """Validate one header; returns ``(kind, payload_length)``."""
    kind, length, _ = _decode_header_ex(header, max_frame=max_frame)
    return kind, length


def _split_trace(payload: bytes, version: int) -> tuple[bytes, TraceContext | None]:
    if version != TRACED_VERSION:
        return payload, None
    return payload[TRACE_CTX_BYTES:], unpack_trace(payload[:TRACE_CTX_BYTES])


def decode_frame_ex(
    buf: bytes, max_frame: int = DEFAULT_MAX_FRAME
) -> tuple[int, bytes, TraceContext | None, int]:
    """Decode one frame from an in-memory buffer.

    Returns ``(kind, payload, trace_context_or_None, bytes_consumed)``;
    raises :class:`TruncatedFrameError` when the buffer holds less than one
    full frame (the streaming equivalent is a peer dying mid-send).
    """
    kind, length, version = _decode_header_ex(buf, max_frame=max_frame)
    end = HEADER_BYTES + length
    if len(buf) < end:
        raise TruncatedFrameError(
            f"frame payload truncated: {len(buf) - HEADER_BYTES} of {length} bytes"
        )
    payload, trace = _split_trace(bytes(buf[HEADER_BYTES:end]), version)
    return kind, payload, trace, end


def decode_frame(
    buf: bytes, max_frame: int = DEFAULT_MAX_FRAME
) -> tuple[int, bytes, int]:
    """Trace-agnostic :func:`decode_frame_ex`: ``(kind, payload, consumed)``
    with any trace context already stripped from the payload."""
    kind, payload, _, end = decode_frame_ex(buf, max_frame=max_frame)
    return kind, payload, end


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes; '' mid-read raises TruncatedFrameError."""
    parts = []
    remaining = n
    while remaining:
        chunk = sock.recv(min(remaining, 1 << 20))
        if not chunk:
            raise TruncatedFrameError(
                f"connection closed mid-frame ({n - remaining} of {n} bytes)"
            )
        parts.append(chunk)
        remaining -= len(chunk)
    return b"".join(parts)


def send_frame(sock: socket.socket, kind: int, payload: bytes = b"",
               trace: TraceContext | None = None) -> None:
    sock.sendall(encode_frame(kind, payload, trace=trace))


def recv_frame_ex(
    sock: socket.socket, max_frame: int = DEFAULT_MAX_FRAME
) -> tuple[int, bytes, TraceContext | None] | None:
    """Read one frame; ``None`` on a clean EOF at a frame boundary.

    Returns ``(kind, payload, trace_context_or_None)``. EOF *inside* a
    frame raises :class:`TruncatedFrameError`; an oversized declared length
    raises :class:`FrameTooLargeError` before any payload byte is read.
    """
    first = sock.recv(1)
    if not first:
        return None
    header = first + recv_exact(sock, HEADER_BYTES - 1)
    kind, length, version = _decode_header_ex(header, max_frame=max_frame)
    payload = recv_exact(sock, length) if length else b""
    payload, trace = _split_trace(payload, version)
    return kind, payload, trace


def recv_frame(
    sock: socket.socket, max_frame: int = DEFAULT_MAX_FRAME
) -> tuple[int, bytes] | None:
    """Trace-agnostic :func:`recv_frame_ex`: ``(kind, payload)`` with any
    trace context already stripped."""
    frame = recv_frame_ex(sock, max_frame=max_frame)
    if frame is None:
        return None
    return frame[0], frame[1]


# ------------------------------------------------------------------- payloads
def pack_ids(ids) -> bytes:
    """Id vector as a raw ``<i8`` buffer (numpy zero-copy on both ends)."""
    return np.asarray(list(ids), dtype="<i8").tobytes()


def unpack_ids(payload: bytes) -> list[int]:
    if len(payload) % 8:
        raise ProtocolError(f"id vector of {len(payload)} bytes is not <i8-aligned")
    return [int(i) for i in np.frombuffer(payload, dtype="<i8")]


def pack_bytes_list(items: list[bytes]) -> bytes:
    """String batch container: ``u32 n | i8 offsets[n+1] | blob``.

    The same offsets-plus-payload shape the store's corpus uses, so a
    ``multiget`` response is two ``np.frombuffer`` views, not n copies.
    """
    offsets = np.zeros(len(items) + 1, dtype="<i8")
    np.cumsum([len(s) for s in items], out=offsets[1:])
    head = struct.pack("<I", len(items))
    return head + offsets.tobytes() + b"".join(items)


def unpack_bytes_list(payload: bytes) -> list[bytes]:
    if len(payload) < 4:
        raise ProtocolError("bytes-list payload shorter than its count header")
    (n,) = struct.unpack_from("<I", payload)
    off_end = 4 + (n + 1) * 8
    if len(payload) < off_end:
        raise ProtocolError(f"bytes-list offsets truncated (n={n})")
    offsets = np.frombuffer(payload, dtype="<i8", count=n + 1, offset=4)
    blob = payload[off_end:]
    if offsets.size and int(offsets[-1]) != len(blob):
        raise ProtocolError(
            f"bytes-list blob holds {len(blob)} bytes, offsets claim {int(offsets[-1])}"
        )
    return [bytes(blob[int(offsets[k]) : int(offsets[k + 1])]) for k in range(n)]


def pack_prefix_query(prefix: bytes, limit: int | None,
                      after: tuple[bytes, int] | None = None) -> bytes:
    """OP_SCAN_PREFIX request: prefix + limit (+ optional resume cursor).

    All pieces ride in one nested bytes-list so arbitrary (non-utf8)
    prefixes and cursor strings survive the wire; ``limit=None`` encodes
    as -1.
    """
    parts = [prefix, pack_ids([-1 if limit is None else int(limit)])]
    if after is not None:
        parts += [after[0], pack_ids([int(after[1])])]
    return pack_bytes_list(parts)


def unpack_prefix_query(
    payload: bytes,
) -> tuple[bytes, int | None, tuple[bytes, int] | None]:
    parts = unpack_bytes_list(payload)
    if len(parts) not in (2, 4):
        raise ProtocolError(
            f"prefix query holds {len(parts)} parts, expected 2 or 4"
        )
    limit = unpack_ids(parts[1])[0]
    after = (parts[2], unpack_ids(parts[3])[0]) if len(parts) == 4 else None
    return parts[0], (None if limit < 0 else limit), after


def pack_prefix_hits(hits: list[tuple[int, bytes]]) -> bytes:
    """OP_SCAN_PREFIX response: parallel id vector + string batch."""
    return pack_bytes_list([
        pack_ids([gid for gid, _ in hits]),
        pack_bytes_list([s for _, s in hits]),
    ])


def unpack_prefix_hits(payload: bytes) -> list[tuple[int, bytes]]:
    ids_raw, strings_raw = unpack_bytes_list(payload)
    ids, strings = unpack_ids(ids_raw), unpack_bytes_list(strings_raw)
    if len(ids) != len(strings):
        raise ProtocolError(
            f"prefix hits hold {len(ids)} ids but {len(strings)} strings"
        )
    return list(zip(ids, strings))


def pack_json(obj) -> bytes:
    return json.dumps(obj).encode()


def unpack_json(payload: bytes):
    return json.loads(payload.decode())


# --------------------------------------------------------------------- errors
#: exception types a client re-raises natively (everything else: RemoteError)
_NATIVE_ERRORS = {
    "FrameTooLargeError": FrameTooLargeError,
    "IndexError": IndexError,
    "KeyError": KeyError,
    "TypeError": TypeError,
    "ValueError": ValueError,
    "RuntimeError": RuntimeError,
}


def pack_error(exc: BaseException) -> bytes:
    return pack_json({"type": type(exc).__name__, "message": str(exc)})


def raise_remote(payload: bytes) -> None:
    """Re-raise a server-side error client-side, preserving builtin types
    (an out-of-range id raises IndexError through the socket, exactly as it
    would in-process)."""
    err = unpack_json(payload)
    cls = _NATIVE_ERRORS.get(err.get("type", ""))
    if cls is not None:
        raise cls(err.get("message", "remote error"))
    raise RemoteError(f"{err.get('type', 'Exception')}: {err.get('message', '')}")
