"""Gradient compression for cross-pod all-reduce (distributed trick #2).

int8-on-the-wire mean-all-reduce with a shared scale and error feedback:

  1. pmax(|g|) over the axis -> one shared f32 scale per tensor (scalar
     collective, negligible bytes);
  2. local int8 quantisation (+ carry-in of last step's residual);
  3. **all_to_all of int8 chunks** — each member receives every peer's int8
     chunk for its slice (this is the reduce-scatter phase, 1 B/element on
     the wire), sums locally in int32 (no overflow: N <= 2^23 peers), and
     re-quantises the partial sum to int8 with a second shared scale;
  4. **all_gather of the int8 partial sums** (1 B/element) and dequantise.

Wire bytes ~= 2 B/element vs 8 B/element for a ring f32 all-reduce (4x) —
measured in benchmarks/grad_compress_bench.py from the compiled HLO. A naive
psum(int8.astype(int32)) would put 4 B/element back on the wire, which is
why the reduce-scatter/all-gather split is explicit. The local quantisation
residual is returned as the error-feedback buffer for the next step
(Karimireddy et al.-style EF).

Exposed as a shard_map'd collective so it can replace the cross-pod ('pod'
axis) hop of gradient synchronisation — the DCN link is the slow one at
multi-pod scale — while in-pod reduction stays native bf16/f32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(g: jnp.ndarray, axis: str):
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _compressed_psum_leaf(g: jnp.ndarray, ef: jnp.ndarray, axis: str,
                          n_devices: int):
    """int8-wire mean over `axis` for one tensor; returns (mean, new_ef)."""
    shape = g.shape
    g = g.astype(jnp.float32) + ef
    q, scale = _quantize(g, axis)
    new_ef = g - q.astype(jnp.float32) * scale          # error feedback

    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n_devices
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n_devices, -1)                # (N, m) int8
    # reduce-scatter phase: int8 on the wire
    recv = jax.lax.all_to_all(chunks, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(n_devices, -1)                  # peers' chunks for me
    part = jnp.sum(recv.astype(jnp.int32), axis=0)      # local int32 sum
    # re-quantise the partial sum so the gather phase is int8 too
    psum_f = part.astype(jnp.float32) * scale
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(psum_f)), axis) / 127.0
    scale2 = jnp.maximum(scale2, 1e-12)
    q2 = jnp.clip(jnp.round(psum_f / scale2), -127, 127).astype(jnp.int8)
    # all-gather phase: int8 on the wire
    gathered = jax.lax.all_gather(q2, axis, tiled=True)  # (N*m,) int8
    total = gathered.astype(jnp.float32) * scale2
    n = jnp.float32(n_devices)
    mean = (total[: g.size] / n).reshape(shape)
    return mean, new_ef


def compressed_pmean(tree, ef_tree, mesh, axis: str = "pod"):
    """Error-feedback int8-wire mean-all-reduce of a pytree over ``axis``.

    Inputs are replicated over the other mesh axes; returns (mean_tree,
    new_error_feedback_tree). Call under `use_mesh(mesh)`.
    """
    from jax.experimental.shard_map import shard_map

    n = int(mesh.shape[axis])
    specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), tree)
    ef_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), ef_tree)

    @partial(shard_map, mesh=mesh,
             in_specs=(specs, ef_specs), out_specs=(specs, ef_specs),
             check_rep=False)
    def run(t, e):
        flat_t, tdef = jax.tree_util.tree_flatten(t)
        flat_e = tdef.flatten_up_to(e)
        out = [_compressed_psum_leaf(g, ef, axis, n)
               for g, ef in zip(flat_t, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return run(tree, ef_tree)


def init_error_feedback(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
