"""Distribution layer: mesh/sharding rules for the production meshes
(``sharding``), compressed cross-pod collectives (``compress``), and
segment-sharded store persistence (``shard_store`` — numpy-only, no jax
needed to write or serve shards).

``shard_store`` is re-exported here; the jax-dependent modules are imported
lazily by their callers so a numpy-only host can still shard and serve.
"""

from repro.distributed.shard_store import (READ_PREFERENCES,
                                           ShardedStringStore, ShardRouter,
                                           check_read_preference, open_shard,
                                           plan_shards, save_sharded)

__all__ = ["READ_PREFERENCES", "ShardRouter", "ShardedStringStore",
           "check_read_preference", "open_shard", "plan_shards",
           "save_sharded"]
