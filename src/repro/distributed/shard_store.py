"""Segment-sharded store persistence — the distribution seam for serving one
compressed corpus from many hosts (ROADMAP: shard segments across hosts).

Built entirely on the v2 persistence pieces: the train-once
:class:`~repro.core.artifact.DictArtifact` is written **once** and shared by
every shard (the paper's dictionary is global state; only payloads shard),
while the corpus is split on *segment* boundaries — the store's existing
unit of scan decoding and routing — into N contiguous shards, each an
independently openable :class:`~repro.store.store.CompressedStringStore`
directory. A host serving shard k opens ``<dir>/shard-000k`` plus the shared
dictionary and answers its id range.

:class:`ShardRouter` holds the routing/bounds arithmetic itself — global id
-> (shard, local id) via contiguous bounds, order-preserving per-shard
``multiget`` partitioning, tail-owned append bounds — and is shared by the
two deployment shapes: :class:`ShardedStringStore` (every shard open
in-process; testing and single-host serving) and
``repro.net.router.DistributedStringStore`` (every shard behind its own
RPC server process).

Pure numpy — no jax required on either the writer or the reader host.
"""

from __future__ import annotations

import heapq
import json
import os
import threading
from itertools import islice

from repro.core import registry
from repro.core.artifact import DictArtifact
from repro.store.mutable import MutableStringStore
from repro.store.store import CompressedStringStore, write_json_atomic

MANIFEST = "shards.json"
DICT_FILE = "dictionary.rpa"

#: the read-routing policies every router (and the client layer) understands
READ_PREFERENCES = ("primary", "replica", "any")


def check_read_preference(pref: str) -> str:
    if pref not in READ_PREFERENCES:
        raise ValueError(f"read_preference must be one of {READ_PREFERENCES},"
                         f" got {pref!r}")
    return pref


def plan_shards(n_strings: int, strings_per_segment: int,
                n_shards: int) -> list[tuple[int, int]]:
    """Contiguous [lo, hi) string-id ranges, split on segment boundaries.

    Segments are never split across shards (they are the routing/decode
    unit); shard sizes differ by at most one segment.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    n_segments = max(1, -(-n_strings // strings_per_segment))
    n_shards = min(n_shards, n_segments)
    bounds: list[tuple[int, int]] = []
    per, extra = divmod(n_segments, n_shards)
    seg = 0
    for k in range(n_shards):
        take = per + (1 if k < extra else 0)
        lo = min(seg * strings_per_segment, n_strings)
        seg += take
        hi = min(seg * strings_per_segment, n_strings)
        bounds.append((lo, hi))
    return bounds


def save_sharded(store: CompressedStringStore, dir_path: str,
                 n_shards: int) -> list[tuple[int, int]]:
    """Write ``store`` as one shared dictionary + N shard corpora.

    Layout::

        <dir>/dictionary.rpa     shared train-once artifact
        <dir>/shards.json        manifest: codec, id ranges, store params
        <dir>/shard-0000/        corpus.rpc + store.json (openable alone)
        ...
    """
    caps = registry.capabilities(store.artifact.codec)
    if not caps.token_stream:
        raise ValueError("sharding slices corpora on string boundaries; "
                         f"codec {store.artifact.codec!r} is not token_stream")
    os.makedirs(dir_path, exist_ok=True)
    store.artifact.save(os.path.join(dir_path, DICT_FILE))
    sps = store.segments.strings_per_segment
    # snapshot the live corpus: a writable store's construction-time corpus
    # does not cover appended strings (sealed-tail segments or open tail)
    corpus = store.snapshot_corpus()
    n = corpus.n_strings
    bounds = plan_shards(n, sps, n_shards)
    for k, (lo, hi) in enumerate(bounds):
        sub = corpus.slice_strings(lo, hi)
        shard_dir = os.path.join(dir_path, f"shard-{k:04d}")
        os.makedirs(shard_dir, exist_ok=True)
        sub.save(os.path.join(shard_dir, CompressedStringStore._CORPUS_FILE))
        write_json_atomic(
            os.path.join(shard_dir, CompressedStringStore._META_FILE),
            store.store_meta(base_id=lo, n_strings=hi - lo))
    write_json_atomic(
        os.path.join(dir_path, MANIFEST),
        {"format_version": 1, "codec": store.artifact.codec,
         "n_shards": len(bounds), "n_strings": n,
         "bounds": [list(b) for b in bounds],
         "strings_per_segment": sps})
    return bounds


def record_replicas(dir_path: str,
                    replicas: dict[int, list[tuple[str, int]]]) -> dict:
    """Publish replica server addresses into the cluster manifest.

    A spawner that starts ``--read-only`` servers (the loadgen cluster
    harness, an operator's init script) records them here so every later
    ``connect("tcp://...", dir_path=dir)`` discovers and registers them
    automatically — read load-balancing without manual
    ``register_replica`` wiring. Addresses replace any prior entry for the
    same shard; an empty list clears it. Returns the full replica map.
    """
    path = os.path.join(dir_path, MANIFEST)
    with open(path) as f:
        manifest = json.load(f)
    current = manifest.get("replicas", {})
    for shard, addrs in replicas.items():
        key = str(int(shard))
        addrs = [[str(h), int(p)] for h, p in addrs]
        if addrs:
            current[key] = addrs
        else:
            current.pop(key, None)
    manifest["replicas"] = current
    write_json_atomic(path, manifest)
    return {int(k): [(h, p) for h, p in v] for k, v in current.items()}


def manifest_replicas(dir_path: str) -> dict[int, list[tuple[str, int]]]:
    """The manifest's replica map: ``{shard: [(host, port), ...]}`` (empty
    when the manifest has none or the directory is not a sharded layout)."""
    path = os.path.join(dir_path, MANIFEST)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        manifest = json.load(f)
    return {int(k): [(str(h), int(p)) for h, p in v]
            for k, v in manifest.get("replicas", {}).items()}


def open_shard(dir_path: str, shard: int, mmap: bool = True,
               source=None, writable: bool = False,
               **overrides) -> CompressedStringStore:
    """What one serving host does: shared dictionary + its shard's corpus.
    Pass ``source`` (a loaded artifact or codec) when opening several
    shards so the dictionary loads — and its decode tables rebuild — once.
    ``writable=True`` opens the shard as a :class:`MutableStringStore` so it
    accepts appends against the shared frozen dictionary; once a writable
    shard has been saved or compacted it owns a *versioned* layout (and its
    own dictionary generation), which takes precedence on reopen."""
    shard_dir = os.path.join(dir_path, f"shard-{shard:04d}")
    if CompressedStringStore._resolve_current(shard_dir) != shard_dir:
        if not writable:  # read-only open of the shard's current generation
            return CompressedStringStore.open(shard_dir, mmap=mmap,
                                              **overrides)
        return MutableStringStore.open(shard_dir, mmap=mmap, **overrides)
    if source is None:
        art = DictArtifact.load(os.path.join(dir_path, DICT_FILE), mmap=mmap)
        source = (art, registry.codec_from_artifact(art))
    store_cls = MutableStringStore if writable else CompressedStringStore
    store = store_cls.open_corpus_dir(shard_dir, source, mmap=mmap,
                                      **overrides)
    if writable:
        store._dir = shard_dir  # compact() rewrites land in the shard dir
    return store


class ShardRouter:
    """Routing/bounds arithmetic over contiguous per-shard id ranges.

    Deployment-agnostic: subclasses provide the per-shard data plane
    (``_shard_multiget`` / ``_shard_scan`` / ``_shard_stats`` /
    ``_tail_extend``) while this base owns the global contract both the
    in-process and the RPC router must honour — order-preserving multiget
    reassembly, segment-respecting scans, and append bounds that only ever
    grow the LAST shard (the owner of the global id space's tail).

    Every read takes a ``read_preference`` (``"primary"`` | ``"replica"`` |
    ``"any"``; None = the router's default) that flows through to the
    per-shard data plane. The base router has no replicas, so every
    preference resolves to the primary — the RPC router overrides the
    resolution with replica-set round-robin (see
    ``repro.net.router.DistributedStringStore``). Accepting the option here
    keeps the client surface identical across deployment shapes.
    """

    def __init__(self, bounds: list[tuple[int, int]],
                 dir_path: str | None = None,
                 read_preference: str = "primary"):
        self.bounds = [tuple(b) for b in bounds]
        self.n_strings = self.bounds[-1][1] if self.bounds else 0
        self.read_preference = check_read_preference(read_preference)
        self._dir = dir_path
        self._write_lock = threading.Lock()  # serialises bound updates

    @property
    def n_shards(self) -> int:
        return len(self.bounds)

    def __len__(self) -> int:
        return self.n_strings

    # ------------------------------------------------------------- data plane
    def _shard_multiget(self, k: int, local_ids: list[int],
                        read_preference: str | None = None) -> list[bytes]:
        raise NotImplementedError

    def _shard_scan(self, k: int, lo: int, hi: int,
                    read_preference: str | None = None) -> list[bytes]:
        raise NotImplementedError

    def _shard_stats(self, k: int) -> dict:
        raise NotImplementedError

    def _shard_locate(self, k: int, strings: list[bytes],
                      read_preference: str | None = None
                      ) -> list[int | None]:
        """Shard-local ids of ``strings`` (None per miss)."""
        raise NotImplementedError

    def _shard_scan_prefix(self, k: int, prefix: bytes, limit: int | None,
                           after: tuple[bytes, int] | None,
                           read_preference: str | None = None
                           ) -> list[tuple[int, bytes]]:
        """Shard-local ``[(local_id, string), ...]`` prefix matches in
        (string, local_id) order; ``after`` is a shard-local cursor."""
        raise NotImplementedError

    def _shard_tier(self, k: int, action: str = "stats",
                    segment: int | None = None,
                    params: dict | None = None) -> dict:
        """One tier-control op against shard ``k`` (see
        ``repro.store.tier.tier_op`` for the action contract)."""
        raise NotImplementedError

    def _tail_extend(self, strings: list[bytes]) -> tuple[list[int], int]:
        """Append to the tail shard; returns (local ids, new local count)."""
        raise NotImplementedError

    def _fanout_multiget(self, jobs: list[tuple[int, list[int]]],
                         read_preference: str | None = None
                         ) -> list[list[bytes]]:
        """Answer one multiget job per shard. Sequential here; the RPC
        router overrides this with a concurrent per-connection fan-out."""
        return [self._shard_multiget(k, local_ids, read_preference)
                for k, local_ids in jobs]

    # ---------------------------------------------------------------- routing
    def route(self, gid: int) -> tuple[int, int]:
        if not 0 <= gid < self.n_strings:
            raise IndexError(f"string id {gid} out of range "
                             f"[0, {self.n_strings})")
        for k, (lo, hi) in enumerate(self.bounds):
            if lo <= gid < hi:
                return k, gid - lo
        raise IndexError(f"string id {gid} not covered by any shard")

    def get(self, gid: int, *, read_preference: str | None = None) -> bytes:
        k, local = self.route(gid)
        return self._shard_multiget(k, [local], read_preference)[0]

    def multiget(self, ids, *,
                 read_preference: str | None = None) -> list[bytes]:
        """Order-preserving batched lookup: ids partition per shard, each
        shard answers with ONE batched decode, answers reassemble into
        request order."""
        routed = [self.route(int(i)) for i in ids]
        per_shard: dict[int, list[int]] = {}
        for pos, (k, _) in enumerate(routed):
            per_shard.setdefault(k, []).append(pos)
        jobs = [(k, [routed[p][1] for p in positions])
                for k, positions in per_shard.items()]
        out: list[bytes | None] = [None] * len(routed)
        for (_, positions), got in zip(per_shard.items(),
                                       self._fanout_multiget(
                                           jobs, read_preference)):
            for p, v in zip(positions, got):
                out[p] = v
        return out  # type: ignore[return-value]

    def scan(self, lo: int, hi: int, *,
             read_preference: str | None = None) -> list[bytes]:
        """Decode the contiguous global id range [lo, hi): each shard scans
        its covered sub-range, results concatenate in id order."""
        if not (0 <= lo <= hi <= self.n_strings):
            raise IndexError(
                f"scan range [{lo}, {hi}) not within [0, {self.n_strings}]")
        out: list[bytes] = []
        for k, (s_lo, s_hi) in enumerate(self.bounds):
            a, b = max(lo, s_lo), min(hi, s_hi)
            if a < b:
                out.extend(self._shard_scan(k, a - s_lo, b - s_lo,
                                            read_preference))
        return out

    def locate(self, s: bytes, *,
               read_preference: str | None = None) -> int | None:
        """Exact-match reverse lookup across every shard (lowest id wins)."""
        return self.locate_batch([s], read_preference=read_preference)[0]

    def locate_batch(self, strings, *,
                     read_preference: str | None = None) -> list[int | None]:
        """Batched reverse lookup. Shards are probed in id order and each
        query drops out at its first hit — shard order IS gid order
        (bounds are contiguous), so the first hit is the lowest global id
        and fully-resolved batches skip the remaining shards."""
        strings = [bytes(s) for s in strings]
        out: list[int | None] = [None] * len(strings)
        pending = list(range(len(strings)))
        for k, (lo, hi) in enumerate(self.bounds):
            if not pending:
                break
            if hi <= lo:
                continue
            got = self._shard_locate(k, [strings[p] for p in pending],
                                     read_preference)
            still: list[int] = []
            for p, loc in zip(pending, got):
                if loc is None:
                    still.append(p)
                else:
                    out[p] = lo + loc
            pending = still
        return out

    def scan_prefix(self, prefix: bytes, limit: int | None = 100,
                    after: tuple[bytes, int] | None = None, *,
                    read_preference: str | None = None
                    ) -> list[tuple[int, bytes]]:
        """Prefix enumeration across every shard, order-merged into global
        ``(string, id)`` order. Each shard returns at most ``limit`` hits
        (any more could never survive the merge); the shard-local cursor
        subtracts the shard's base, which preserves the (string, id)
        ordering the per-segment binary search needs."""
        prefix = bytes(prefix)
        runs: list[list[tuple[bytes, int]]] = []
        for k, (lo, hi) in enumerate(self.bounds):
            if hi <= lo:
                continue
            sh_after = ((after[0], after[1] - lo)
                        if after is not None else None)
            hits = self._shard_scan_prefix(k, prefix, limit, sh_after,
                                           read_preference)
            if hits:
                runs.append([(s, lo + local) for local, s in hits])
        merged = heapq.merge(*runs)
        if limit is not None:
            merged = islice(merged, limit)
        return [(gid, s) for s, gid in merged]

    def stats_snapshot(self) -> dict:
        """Aggregate per-shard stats under global routing metadata."""
        shards = [self._shard_stats(k) for k in range(self.n_shards)]
        return {"n_shards": self.n_shards, "n_strings": self.n_strings,
                "bounds": [list(b) for b in self.bounds],
                "shards": shards}

    # ---------------------------------------------------------------- tiering
    def tier(self, action: str = "stats", segment: int | None = None,
             shard: int | None = None,
             params: dict | None = None) -> list[dict]:
        """Tier control across the cluster: one per-shard report list.
        ``shard=None`` fans the op out to every shard; ``segment`` (when
        given) is shard-local and requires an explicit ``shard``."""
        if segment is not None and shard is None:
            raise ValueError("segment is shard-local: pass shard= with it")
        targets = range(self.n_shards) if shard is None else [shard]
        return [self._shard_tier(k, action, segment=segment, params=params)
                for k in targets]

    def demote(self, shard: int | None = None, segment: int | None = None,
               **params) -> list[dict]:
        """Demote segments to the RLZ cold tier (all eligible segments of
        the targeted shards when ``segment`` is None)."""
        return self.tier("demote", segment=segment, shard=shard,
                         params=params or None)

    def promote(self, shard: int | None = None,
                segment: int | None = None) -> list[dict]:
        """Promote cold segments back to hot OnPair arrays."""
        return self.tier("promote", segment=segment, shard=shard)

    def tier_stats(self) -> list[dict]:
        """Per-shard tier snapshots (``{"enabled": False}`` where off)."""
        return self.tier("stats")

    # ----------------------------------------------------------------- writes
    def append(self, s: bytes) -> int:
        return self.extend([s])[0]

    def extend(self, strings: list[bytes]) -> list[int]:
        """Route appends to the owning shard. New ids extend the global id
        space, which is owned by the LAST shard (bounds are contiguous), so
        that is where appended strings land — the same decision on both
        sides of the RPC seam."""
        # read-modify-write of bounds/n_strings must serialise: two racing
        # extends could otherwise publish a count below acknowledged ids
        with self._write_lock:
            lo, _ = self.bounds[-1]
            local_ids, local_n = self._tail_extend(strings)
            self.bounds[-1] = (lo, lo + local_n)
            self.n_strings = self.bounds[-1][1]
        return [lo + i for i in local_ids]


class ShardedStringStore(ShardRouter):
    """Global-id router over per-shard stores (single-process form).

    The same routing arithmetic a multi-host deployment performs at its RPC
    layer (``repro.net.router.DistributedStringStore`` — which shares this
    class's :class:`ShardRouter` base), with every shard store open in this
    process.
    """

    def __init__(self, stores: list[CompressedStringStore],
                 bounds: list[tuple[int, int]],
                 dir_path: str | None = None):
        if len(stores) != len(bounds):
            raise ValueError("one store per shard bound required")
        super().__init__(bounds, dir_path=dir_path)
        self.stores = stores

    @classmethod
    def open(cls, dir_path: str, mmap: bool = True, writable: bool = False,
             **overrides) -> "ShardedStringStore":
        with open(os.path.join(dir_path, MANIFEST)) as f:
            manifest = json.load(f)
        artifact = DictArtifact.load(os.path.join(dir_path, DICT_FILE),
                                     mmap=mmap)
        codec = registry.codec_from_artifact(artifact)  # one table rebuild
        stores = [open_shard(dir_path, k, mmap=mmap,
                             source=(artifact, codec),
                             writable=writable, **overrides)
                  for k in range(manifest["n_shards"])]
        bounds = [tuple(b) for b in manifest["bounds"]]
        # the LAST shard owns the growing end of the global id space: its
        # bound extends to cover appends saved after the manifest was
        # written. Any other shard disagreeing with the manifest would
        # silently renumber every id behind it — refuse instead.
        for k, store in enumerate(stores):
            lo, hi = bounds[k]
            if store.n_strings != hi - lo:
                if k < len(stores) - 1:
                    raise ValueError(
                        f"shard {k} holds {store.n_strings} strings but the "
                        f"manifest bounds say {hi - lo}: only the last shard "
                        "may grow — appends must route through "
                        "ShardedStringStore.extend, not a non-tail shard")
                bounds[k] = (lo, lo + store.n_strings)
        return cls(stores, bounds, dir_path=dir_path)

    # ------------------------------------------------------------- data plane
    # every shard store lives in this process, so there is nothing to prefer:
    # each shard IS its own primary and read_preference resolves to it
    def _shard_multiget(self, k: int, local_ids: list[int],
                        read_preference: str | None = None) -> list[bytes]:
        return self.stores[k].multiget(local_ids)

    def _shard_scan(self, k: int, lo: int, hi: int,
                    read_preference: str | None = None) -> list[bytes]:
        return self.stores[k].scan(lo, hi)

    def _shard_stats(self, k: int) -> dict:
        return self.stores[k].stats_snapshot()

    def _shard_locate(self, k: int, strings: list[bytes],
                      read_preference: str | None = None
                      ) -> list[int | None]:
        return self.stores[k].locate_batch(strings)

    def _shard_scan_prefix(self, k: int, prefix: bytes, limit: int | None,
                           after: tuple[bytes, int] | None,
                           read_preference: str | None = None
                           ) -> list[tuple[int, bytes]]:
        # a shard store's global ids ARE shard-local ids
        return self.stores[k].scan_prefix(prefix, limit, after)

    def _shard_tier(self, k: int, action: str = "stats",
                    segment: int | None = None,
                    params: dict | None = None) -> dict:
        from repro.store.tier import tier_op
        return tier_op(self.stores[k], action=action, segment=segment,
                       params=params)

    def _writable_tail_store(self):
        store = self.stores[-1]
        if not hasattr(store, "extend"):
            raise TypeError("shards are read-only; reopen with "
                            "ShardedStringStore.open(dir, writable=True)")
        return store

    def _tail_extend(self, strings: list[bytes]) -> tuple[list[int], int]:
        store = self._writable_tail_store()
        local_ids = store.extend(strings)
        return local_ids, store.n_strings

    # -------------------------------------------------------------- lifecycle
    def save(self) -> None:
        """Persist every writable shard (each as a versioned layout inside
        its shard directory) and atomically rewrite the manifest bounds —
        without this, appends live only in memory. In-place only: the
        sharded layout (shared dictionary + manifest + read-only shards)
        already lives in the directory this router was opened from."""
        target = self._dir
        if target is None:
            raise ValueError("no directory: this router was not opened from "
                             "a sharded store directory (use save_sharded "
                             "to write a new layout)")
        # the write lock freezes bounds for the whole snapshot: a racing
        # extend() must not slip acknowledged ids into the manifest after
        # their shard corpus has already been written
        with self._write_lock:
            for k, store in enumerate(self.stores):
                # only shards with unsaved appends/compactions rewrite their
                # generation — untouched shards keep the shared flat layout
                if getattr(store, "_dirty", False):
                    store.save(os.path.join(target, f"shard-{k:04d}"))
            with open(os.path.join(target, MANIFEST)) as f:
                manifest = json.load(f)
            manifest.update(n_strings=self.n_strings,
                            bounds=[list(b) for b in self.bounds])
            write_json_atomic(os.path.join(target, MANIFEST), manifest)

    def compact(self, shard: int | None = None, **kw) -> list[dict]:
        """Compact one shard (or all of them) in place. Each shard re-trains
        on its own live data — after this the shards no longer share one
        dictionary artifact, exactly as in a rolling per-host rewrite."""
        targets = range(len(self.stores)) if shard is None else [shard]
        reports = []
        for k in targets:
            store = self.stores[k]
            if not hasattr(store, "compact"):
                raise TypeError("shards are read-only; reopen with "
                                "ShardedStringStore.open(dir, writable=True)")
            reports.append(store.compact(**kw))
        return reports
