"""Segment-sharded store persistence — the distribution seam for serving one
compressed corpus from many hosts (ROADMAP: shard segments across hosts).

Built entirely on the v2 persistence pieces: the train-once
:class:`~repro.core.artifact.DictArtifact` is written **once** and shared by
every shard (the paper's dictionary is global state; only payloads shard),
while the corpus is split on *segment* boundaries — the store's existing
unit of scan decoding and routing — into N contiguous shards, each an
independently openable :class:`~repro.store.store.CompressedStringStore`
directory. A host serving shard k opens ``<dir>/shard-000k`` plus the shared
dictionary and answers its id range; :class:`ShardedStringStore` is the
single-process router used for testing and single-host serving.

Pure numpy — no jax required on either the writer or the reader host.
"""

from __future__ import annotations

import json
import os

from repro.core import registry
from repro.core.artifact import DictArtifact
from repro.store.store import CompressedStringStore, write_json_atomic

MANIFEST = "shards.json"
DICT_FILE = "dictionary.rpa"


def plan_shards(n_strings: int, strings_per_segment: int,
                n_shards: int) -> list[tuple[int, int]]:
    """Contiguous [lo, hi) string-id ranges, split on segment boundaries.

    Segments are never split across shards (they are the routing/decode
    unit); shard sizes differ by at most one segment.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    n_segments = max(1, -(-n_strings // strings_per_segment))
    n_shards = min(n_shards, n_segments)
    bounds: list[tuple[int, int]] = []
    per, extra = divmod(n_segments, n_shards)
    seg = 0
    for k in range(n_shards):
        take = per + (1 if k < extra else 0)
        lo = min(seg * strings_per_segment, n_strings)
        seg += take
        hi = min(seg * strings_per_segment, n_strings)
        bounds.append((lo, hi))
    return bounds


def save_sharded(store: CompressedStringStore, dir_path: str,
                 n_shards: int) -> list[tuple[int, int]]:
    """Write ``store`` as one shared dictionary + N shard corpora.

    Layout::

        <dir>/dictionary.rpa     shared train-once artifact
        <dir>/shards.json        manifest: codec, id ranges, store params
        <dir>/shard-0000/        corpus.rpc + store.json (openable alone)
        ...
    """
    caps = registry.capabilities(store.artifact.codec)
    if not caps.token_stream:
        raise ValueError("sharding slices corpora on string boundaries; "
                         f"codec {store.artifact.codec!r} is not token_stream")
    os.makedirs(dir_path, exist_ok=True)
    store.artifact.save(os.path.join(dir_path, DICT_FILE))
    sps = store.segments.strings_per_segment
    bounds = plan_shards(store.n_strings, sps, n_shards)
    for k, (lo, hi) in enumerate(bounds):
        sub = store.corpus.slice_strings(lo, hi)
        shard_dir = os.path.join(dir_path, f"shard-{k:04d}")
        os.makedirs(shard_dir, exist_ok=True)
        sub.save(os.path.join(shard_dir, CompressedStringStore._CORPUS_FILE))
        write_json_atomic(
            os.path.join(shard_dir, CompressedStringStore._META_FILE),
            store.store_meta(base_id=lo, n_strings=hi - lo))
    write_json_atomic(
        os.path.join(dir_path, MANIFEST),
        {"format_version": 1, "codec": store.artifact.codec,
         "n_shards": len(bounds), "n_strings": store.n_strings,
         "bounds": [list(b) for b in bounds],
         "strings_per_segment": sps})
    return bounds


def open_shard(dir_path: str, shard: int, mmap: bool = True,
               source=None, **overrides) -> CompressedStringStore:
    """What one serving host does: shared dictionary + its shard's corpus.
    Pass ``source`` (a loaded artifact or codec) when opening several
    shards so the dictionary loads — and its decode tables rebuild — once."""
    if source is None:
        source = DictArtifact.load(os.path.join(dir_path, DICT_FILE),
                                   mmap=mmap)
    return CompressedStringStore.open_corpus_dir(
        os.path.join(dir_path, f"shard-{shard:04d}"), source,
        mmap=mmap, **overrides)


class ShardedStringStore:
    """Global-id router over per-shard stores (single-process form).

    The same routing arithmetic a multi-host deployment performs at its RPC
    layer: global id -> (shard, local id) via the manifest's contiguous
    bounds; multiget partitions ids per shard, one batched decode each.
    """

    def __init__(self, stores: list[CompressedStringStore],
                 bounds: list[tuple[int, int]]):
        if len(stores) != len(bounds):
            raise ValueError("one store per shard bound required")
        self.stores = stores
        self.bounds = [tuple(b) for b in bounds]
        self.n_strings = bounds[-1][1] if bounds else 0

    @classmethod
    def open(cls, dir_path: str, mmap: bool = True,
             **overrides) -> "ShardedStringStore":
        with open(os.path.join(dir_path, MANIFEST)) as f:
            manifest = json.load(f)
        artifact = DictArtifact.load(os.path.join(dir_path, DICT_FILE),
                                     mmap=mmap)
        codec = registry.codec_from_artifact(artifact)  # one table rebuild
        stores = [open_shard(dir_path, k, mmap=mmap, source=codec,
                             **overrides)
                  for k in range(manifest["n_shards"])]
        return cls(stores, [tuple(b) for b in manifest["bounds"]])

    def route(self, gid: int) -> tuple[int, int]:
        if not 0 <= gid < self.n_strings:
            raise IndexError(f"string id {gid} out of range "
                             f"[0, {self.n_strings})")
        for k, (lo, hi) in enumerate(self.bounds):
            if lo <= gid < hi:
                return k, gid - lo
        raise IndexError(f"string id {gid} not covered by any shard")

    def get(self, gid: int) -> bytes:
        k, local = self.route(gid)
        return self.stores[k].get(local)

    def multiget(self, ids) -> list[bytes]:
        """Order-preserving batched lookup: ids partition per shard, each
        shard answers with ONE batched decode."""
        routed = [self.route(int(i)) for i in ids]
        per_shard: dict[int, list[int]] = {}
        for pos, (k, local) in enumerate(routed):
            per_shard.setdefault(k, []).append(pos)
        out: list[bytes | None] = [None] * len(routed)
        for k, positions in per_shard.items():
            got = self.stores[k].multiget([routed[p][1] for p in positions])
            for p, v in zip(positions, got):
                out[p] = v
        return out  # type: ignore[return-value]
