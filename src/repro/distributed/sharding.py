"""Sharding rules: param/batch/cache PartitionSpecs per (arch x shape x mesh).

Axes (per the production mesh spec):
  pod   — cross-pod data parallelism (multi-pod mesh only)
  data  — in-pod data parallelism; doubles as the FSDP/ZeRO shard axis
  model — tensor/expert parallelism

Rules are name-driven over the param tree (wq/wk/wv column-parallel, wo/w_down
row-parallel, experts over 'model' when divisible (EP) else per-expert TP,
SSM head-parallel, vocab-parallel embeddings when divisible). FSDP extends
large leaves with 'data' on the first free divisible dim; optimizer moments
always get the ZeRO-1 extension. Scan-stacked leaves carry a leading
``n_blocks`` dim that is never sharded (it is the scan axis).
"""

from __future__ import annotations

import contextlib

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import layers as _layers
from repro.models.config import ArchConfig, ShapeConfig


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Enter mesh context + enable model-code sharding constraints."""
    _layers.set_mesh_context(mesh)
    # jax.sharding.set_mesh only exists on newer jax; Mesh itself is a
    # context manager (axis-name scope) on every version we support.
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    ctx = set_mesh(mesh) if set_mesh is not None else mesh
    try:
        with ctx:
            yield mesh
    finally:
        _layers.set_mesh_context(None)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([axis_size(mesh, a) for a in ("pod", "data")]))


# ------------------------------------------------------------- param rules
def _leaf_rule(path: str, shape: tuple[int, ...], mesh: Mesh,
               cfg: ArchConfig) -> P:
    """Base TP rule for one leaf (ignoring the stacked-blocks leading dim)."""
    m = axis_size(mesh, "model")

    def div(i):  # dim i divisible by model axis?
        return shape[i] % m == 0

    name = path.split("/")[-1]
    if "ffn" in path and len(shape) == 3:                 # MoE experts (E,.,.)
        E = shape[0]
        if E % m == 0:
            return P("model", None, None)                 # expert parallel
        if name in ("w_gate", "w_up") and div(2):
            return P(None, None, "model")                 # per-expert TP
        if name == "w_down" and div(1):
            return P(None, "model", None)
        return P(None, None, None)
    if name == "router":
        return P(None, None)
    if name in ("wq", "wk", "wv") and div(1):
        return P(None, "model")                           # column parallel
    if name == "wo" and div(0):
        return P("model", None)                           # row parallel
    if name in ("bq", "bk", "bv") and div(0):
        return P("model")
    if name in ("w_gate", "w_up") and div(1):
        return P(None, "model")
    if name == "w_down" and div(0):
        return P("model", None)
    # --- SSM (head-parallel) ---
    if name in ("w_z", "w_x") and div(1):
        return P(None, "model")
    if name == "w_dt" and div(1):
        return P(None, "model")
    if name == "w_BC":
        return P(None, None)
    if name in ("conv_x",) and div(1):
        return P(None, "model")
    if name in ("conv_bx", "norm") and len(shape) == 1 and div(0) and "ssm" in path:
        return P("model")
    if name in ("A_log", "D", "dt_bias") and div(0):
        return P("model")
    if name == "w_out" and div(0):
        return P("model", None)
    # --- embeddings / head ---
    if name == "embed":
        if shape[0] % m == 0:
            return P("model", None)                       # vocab parallel
        if shape[1] % m == 0:
            return P(None, "model")
        return P(None, None)
    if name == "lm_head":
        if shape[1] % m == 0:
            return P(None, "model")
        return P(None, None)
    return P(*([None] * len(shape)))


def _extend_fsdp(spec: P, shape: tuple[int, ...], mesh: Mesh,
                 axis: str = "data", min_size: int = 1 << 20) -> P:
    """Add the FSDP/ZeRO axis on the first free dim divisible by its size."""
    d = axis_size(mesh, axis)
    if d <= 1 or int(np.prod(shape)) < min_size:
        return spec
    flat = [a for p in spec for a in (p if isinstance(p, tuple) else (p,))]
    if axis in flat:
        return spec  # already sharded on this axis (e.g. params under FSDP)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % d == 0 and dim >= d:
            parts[i] = axis
            return P(*parts)
    return spec


def param_specs_tree(abstract_params, mesh: Mesh, cfg: ArchConfig,
                     fsdp: bool = False):
    """PartitionSpec pytree matching the (possibly scan-stacked) param tree."""

    def rule(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        spath = "/".join(str(k) for k in keys)
        shape = leaf.shape
        stacked = "blocks" in spath and len(shape) >= 1
        inner_shape = shape[1:] if stacked else shape
        spec = _leaf_rule(spath, inner_shape, mesh, cfg)
        if fsdp:
            spec = _extend_fsdp(spec, inner_shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def param_shardings(abstract_params, mesh: Mesh, cfg: ArchConfig,
                    fsdp: bool = False):
    specs = param_specs_tree(abstract_params, mesh, cfg, fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------- batch rules
def batch_specs(input_tree, mesh: Mesh):
    """Shard the leading (global batch) dim over (pod, data) when divisible
    (long_500k has batch 1: replicated input, sequence-parallel caches)."""
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)

    def rule(leaf):
        lead = dp if dp and leaf.shape and leaf.shape[0] % dpn == 0 else None
        spec = [lead] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, input_tree)


# ------------------------------------------------------------- cache rules
def cache_specs_tree(abstract_cache, mesh: Mesh, cfg: ArchConfig,
                     shape: ShapeConfig):
    """Decode-cache shardings.

    KV caches (n_blocks, B, S, K, hd): batch over (pod,data); head_dim over
    'model' (every assigned hd is divisible by 16). long_500k (batch=1) flips
    to sequence parallelism: S over 'data' for full-attention caches. SSM
    states shard heads over 'model', batch over (pod,data).
    """
    dp = dp_axes(mesh)
    m = axis_size(mesh, "model")
    d = axis_size(mesh, "data")
    B = shape.global_batch
    seq_parallel = B < dp_size(mesh)

    def rule(path, leaf):
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        s = leaf.shape
        if keys.endswith("pos"):
            return NamedSharding(mesh, P())
        if "state" in keys and len(s) == 5:      # (nb, B, H, P, N)
            hspec = "model" if s[2] % m == 0 else None
            bspec = dp if not seq_parallel and B % dp_size(mesh) == 0 else None
            return NamedSharding(mesh, P(None, bspec, hspec, None, None))
        if "conv" in keys and len(s) == 4:       # (nb, B, W-1, C)
            cspec = "model" if s[3] % m == 0 else None
            bspec = dp if not seq_parallel and B % dp_size(mesh) == 0 else None
            return NamedSharding(mesh, P(None, bspec, None, cspec))
        if len(s) == 5:                           # (nb, B, S, K, hd) KV
            # head_dim over 'model' (divisible for every assigned arch);
            # decode_attention constrains its per-step q/k/v to the same
            # layout so the cache is never resharded (§Perf decode
            # follow-up). long_500k (batch 1) adds sequence-parallel S/data.
            hd_spec = "model" if s[4] % m == 0 else None
            sspec = ("data" if seq_parallel and s[2] % d == 0 and s[2] >= 4 * d
                     else None)
            bspec = (dp if not seq_parallel and B % dp_size(mesh) == 0
                     else None)
            return NamedSharding(mesh, P(None, bspec, sspec, None, hd_spec))
        return NamedSharding(mesh, P(*([None] * len(s))))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


# ------------------------------------------------------------ outputs
def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
