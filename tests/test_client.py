"""Tests for repro.client — Client API v3.

URL parsing, the acceptance byte-identity criterion (one corpus served via
file:// vs shard:// vs tcp:// answers identical bytes), the unified stats
schema (key-set equality across all four backends), failure semantics
through the async path (cancelled/timed-out futures, IndexError through
scan_iter, replica fallback), replica read-preference routing asserted via
server-side op counters while a live compact() is in flight, the adaptive
max_wait_s controller, and client-level reconnect across a server
kill/restart (the PR 4 subprocess harness).

Stdlib + numpy only — the client layer must work on jax-less serving hosts.
"""

import os
import re
import subprocess
import sys
import threading
import time
from concurrent.futures import TimeoutError as FuturesTimeout

import numpy as np
import pytest

from repro.client import StoreClient, connect, format_tcp_url, parse_url, wrap
from repro.data.synth import load_dataset
from repro.distributed import save_sharded
from repro.net import ShardServer
from repro.net import protocol as P
from repro.store import CompressedStringStore, MutableStringStore, StoreService

SAMPLE = 1 << 18
SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(P.__file__))))
CHILD_ENV = {**os.environ, "PYTHONPATH": SRC_DIR, "REPRO_NO_JAX": "1"}


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""
    strings[7] = b"\x00\xff" * 9
    return strings


@pytest.fixture(scope="module")
def corpus_dirs(titles, tmp_path_factory):
    """One corpus persisted three ways: flat store dir, versioned mutable
    dir, and a 3-shard sharded dir — the backends behind file:// mut://
    shard:// (and, served, tcp://)."""
    store = CompressedStringStore.build(
        titles, sample_bytes=SAMPLE, strings_per_segment=256
    )
    base = tmp_path_factory.mktemp("client")
    flat = str(base / "flat")
    store.save(flat)
    mut = str(base / "mut")
    MutableStringStore.open(flat).save(mut)
    sharded = str(base / "shards")
    save_sharded(store, sharded, 3)
    return {"flat": flat, "mut": mut, "sharded": sharded}


@pytest.fixture(scope="module")
def cluster(corpus_dirs):
    """In-thread shard servers over the sharded dir + a tcp:// client."""
    servers = [
        ShardServer.from_dir(
            os.path.join(corpus_dirs["sharded"], f"shard-{k:04d}")
        ).start()
        for k in range(3)
    ]
    url = format_tcp_url([s.address for s in servers])
    client = connect(url, dir_path=corpus_dirs["sharded"])
    yield client, servers
    client.close()
    for s in servers:
        s.close()


# ------------------------------------------------------------------- parsing
def test_parse_url_schemes():
    u = parse_url("file:///data/store")
    assert (u.scheme, u.path) == ("file", "/data/store")
    u = parse_url("mut://rel/dir?mmap=false")
    assert (u.scheme, u.path, u.options) == ("mut", "rel/dir", {"mmap": False})
    u = parse_url("tcp://h0:9100,h1:9101?read_preference=replica&timeout=5")
    assert u.addresses == [("h0", 9100), ("h1", 9101)]
    assert u.options == {"read_preference": "replica", "timeout": 5}
    with pytest.raises(ValueError, match="unsupported store url"):
        parse_url("bogus://x")
    with pytest.raises(ValueError, match="no host:port"):
        parse_url("tcp://")
    with pytest.raises(ValueError, match="host:port"):
        parse_url("tcp://justahost")
    with pytest.raises(ValueError, match="no directory"):
        parse_url("file://")


def test_connect_rejects_unknown_options(corpus_dirs):
    # unrecognised options forward to the backend opener and fail loudly
    # there (TypeError), never silently vanish
    with pytest.raises(TypeError, match="frobnicate"):
        connect(f"file://{corpus_dirs['flat']}", frobnicate=3)
    # service knobs on a router URL are a loud TypeError too — routers have
    # no client-side StoreService, so accepting the option would be a no-op
    with pytest.raises(TypeError, match="target_p99_ms"):
        connect(f"shard://{corpus_dirs['sharded']}", target_p99_ms=2.0)
    with pytest.raises(TypeError, match="max_wait_s"):
        connect("tcp://127.0.0.1:1?max_wait_s=0.01")  # pre-connect check


# -------------------------------------------------- acceptance: byte identity
def test_byte_identity_across_backends(cluster, corpus_dirs, titles):
    """The same corpus served via connect('file://'), connect('shard://')
    and connect('tcp://') returns identical bytes for get/multiget/scan."""
    tcp_client, _ = cluster
    rng = np.random.default_rng(0)
    ids = rng.integers(0, len(titles), 500).tolist() + [3, 7, len(titles) - 1]
    lo, hi = len(titles) // 3 - 40, len(titles) // 3 + 40  # shard straddle
    with connect(f"file://{corpus_dirs['flat']}") as file_client, \
            connect(f"shard://{corpus_dirs['sharded']}") as shard_client:
        expect = [titles[i] for i in ids]
        for client in (file_client, shard_client, tcp_client):
            assert client.multiget(ids) == expect
            assert client.get(7) == titles[7]
            assert client.scan(lo, hi) == titles[lo:hi]
            assert list(client.scan_iter(lo, hi, chunk=16)) == titles[lo:hi]
            assert len(client) == len(titles)
        # read_preference is part of the frozen surface on every backend
        # (no replicas anywhere here, so every preference hits primaries)
        for pref in ("primary", "replica", "any"):
            assert shard_client.multiget(ids[:5], read_preference=pref) == \
                tcp_client.multiget(ids[:5], read_preference=pref) == expect[:5]


# --------------------------------------------------------- stats unification
def test_stats_schema_identical_across_all_four_frontends(
    cluster, corpus_dirs, titles
):
    tcp_client, _ = cluster
    clients = {
        "file": connect(f"file://{corpus_dirs['flat']}"),
        "mut": connect(f"mut://{corpus_dirs['mut']}"),
        "shard": connect(f"shard://{corpus_dirs['sharded']}"),
        "tcp": tcp_client,
    }
    try:
        key_sets = {}
        for name, client in clients.items():
            client.multiget([0, 1, 2])
            client.scan(0, 4)
            snap = client.stats()
            key_sets[name] = frozenset(snap)
            # the unified schema every frontend must speak
            assert {"latency_summary", "throughput_mib_s", "wakeups",
                    "ops", "n_strings", "backend", "server_ops",
                    "store_latency"} <= key_sets[name]
            assert snap["n_strings"] == len(titles)
            assert snap["ops"]["multiget"] >= 1
            assert snap["latency_summary"]["count"] >= 2
            assert snap["throughput_mib_s"] > 0
            # server_ops is present (key-set equality) on every backend …
            assert set(snap["server_ops"]) == {"total", "per_shard"}
            # … and store_latency reports the pooled decode percentiles
            assert snap["store_latency"]["count"] >= 1
        assert len(set(key_sets.values())) == 1, key_sets
        # backends with a micro-batching service actually count wakeups
        assert clients["file"].stats()["wakeups"] >= 1
        assert clients["tcp"].stats()["wakeups"] >= 1
        # … but only tcp:// has servers to report op counts: the summed
        # totals and the per-shard breakdown both surface what the
        # ShardServers counted (this used to be silently dropped)
        tcp_ops = tcp_client.stats()["server_ops"]
        assert tcp_ops["total"]["multiget"] >= 1
        assert len(tcp_ops["per_shard"]) >= 1
        assert sum(s["ops"].get("multiget", 0)
                   for s in tcp_ops["per_shard"]) == tcp_ops["total"]["multiget"]
        for name in ("file", "mut", "shard"):
            assert clients[name].stats()["server_ops"]["total"] == {}
    finally:
        for name in ("file", "mut", "shard"):
            clients[name].close()


# ------------------------------------------------------- async path & errors
def test_async_pipelining_matches_sync(corpus_dirs, titles):
    with connect(f"file://{corpus_dirs['flat']}") as client:
        batches = [list(range(k, k + 50)) for k in range(0, 500, 50)]
        futs = [client.multiget_async(b) for b in batches]
        got = [v for f in futs for v in f.result(30)]
        assert got == [titles[i] for i in range(500)]
        svc = client.stats()["backend"]["service"]
        assert svc["requests"] == 500


def test_async_failure_semantics(corpus_dirs, titles):
    with connect(f"file://{corpus_dirs['flat']}") as client:
        with pytest.raises(IndexError):
            client.multiget_async([0, len(titles)]).result(30)
        with pytest.raises(IndexError):
            client.get_async(-1).result(30)
        # a read-only backend refuses writes through the same future path
        with pytest.raises(TypeError, match="read-only"):
            client.extend_async([b"x"]).result(30)
        with pytest.raises(TypeError, match="read-only"):
            client.append(b"x")
        with pytest.raises(TypeError, match="read-only"):
            client.compact()
    with pytest.raises(RuntimeError, match="closed"):
        client.multiget([0])


def test_cancelled_future_skipped_and_worker_survives(corpus_dirs, titles):
    with connect(f"file://{corpus_dirs['flat']}") as client:
        store = client.backend
        orig = store.multiget
        started = threading.Event()

        def slow_multiget(ids):
            started.set()
            time.sleep(0.4)
            return orig(ids)

        store.multiget = slow_multiget
        try:
            first = client.multiget_async([0, 1])
            assert started.wait(5), "worker never picked up the first batch"
            victim = client.multiget_async([2, 3])  # queued behind the decode
            assert victim.cancel(), "pending future should be cancellable"
            assert victim.cancelled()
            assert first.result(10) == [titles[0], titles[1]]
        finally:
            store.multiget = orig
        # the worker skipped the cancelled item instead of crashing on it
        assert client.multiget([2, 3]) == [titles[2], titles[3]]


def test_timed_out_future_raises_and_service_completes(corpus_dirs, titles):
    with connect(f"file://{corpus_dirs['flat']}") as client:
        store = client.backend
        orig = store.multiget
        store.multiget = lambda ids: (time.sleep(0.3), orig(ids))[1]
        try:
            fut = client.multiget_async([5])
            with pytest.raises(FuturesTimeout):
                fut.result(0.05)
            with pytest.raises(FuturesTimeout):
                client.multiget([6], timeout=0.05)
            # the work itself was not lost — the future still resolves
            assert fut.result(10) == [titles[5]]
        finally:
            store.multiget = orig


def test_scan_iter_propagates_index_error(cluster, corpus_dirs, titles):
    tcp_client, _ = cluster
    with connect(f"file://{corpus_dirs['flat']}") as file_client:
        for client in (file_client, tcp_client):
            with pytest.raises(IndexError):
                list(client.scan_iter(0, len(titles) + 5, chunk=10**9))
            with pytest.raises(IndexError):
                client.scan_iter(5, 4)
            assert list(client.scan_iter(0, 0)) == []


def test_bad_read_preference_rejected_on_every_backend(
    cluster, corpus_dirs, titles
):
    """A typo'd read_preference fails identically whether or not the
    backend can act on it — the frozen-surface contract."""
    tcp_client, _ = cluster
    with connect(f"file://{corpus_dirs['flat']}") as file_client:
        for client in (file_client, tcp_client):
            with pytest.raises(ValueError, match="read_preference"):
                client.multiget([0], read_preference="replcia")
            with pytest.raises(ValueError, match="read_preference"):
                client.get_async(0, read_preference="nearest")
            with pytest.raises(ValueError, match="read_preference"):
                client.scan(0, 2, read_preference="replicas")
            with pytest.raises(ValueError, match="read_preference"):
                client.scan_iter(0, 2, read_preference="replicas")
    with pytest.raises(ValueError, match="read_preference"):
        connect(f"file://{corpus_dirs['flat']}", read_preference="oops")


def test_tcp_connect_failure_closes_opened_sockets(cluster):
    """A bad constructor kwarg (or a dead shard) during tcp connect must
    close the shard connections it already opened, not leak them."""
    tcp_client, servers = cluster
    url = format_tcp_url([s.address for s in servers])
    import repro.net.router as router_mod

    closed = []
    orig_close = router_mod.RemoteShardClient.close

    def tracking_close(self):
        closed.append(self.address)
        orig_close(self)

    router_mod.RemoteShardClient.close = tracking_close
    try:
        with pytest.raises(TypeError):
            connect(url, scan_chnk=8)  # typo reaches the ctor post-RPC
    finally:
        router_mod.RemoteShardClient.close = orig_close
    assert len(closed) == len(servers)


def test_router_per_call_timeout_routes_through_future(cluster, titles):
    """Sync router calls go direct; an explicit timeout= opts into the
    future path and still answers correctly (and can actually time out)."""
    tcp_client, _ = cluster
    assert tcp_client.multiget([1, 2], timeout=30.0) == titles[1:3]
    assert tcp_client.get(1, timeout=30.0) == titles[1]
    store = tcp_client.backend
    orig = store.multiget

    def slow_multiget(ids, **kw):
        time.sleep(0.3)
        return orig(ids, **kw)

    store.multiget = slow_multiget
    try:
        with pytest.raises(FuturesTimeout):
            tcp_client.multiget([1], timeout=0.02)
    finally:
        store.multiget = orig


def test_replica_preference_falls_back_to_primary(cluster, titles):
    """read_preference='replica' with no replica registered serves from the
    primary (asserted via the servers' op counters)."""
    tcp_client, servers = cluster
    before = [s.op_counts.get("multiget", 0) for s in servers]
    ids = [1, len(titles) // 2, len(titles) - 1]  # touches every shard
    assert tcp_client.multiget(ids, read_preference="replica") == \
        [titles[i] for i in ids]
    after = [s.op_counts.get("multiget", 0) for s in servers]
    assert all(a > b for a, b in zip(after, before))


# ----------------------------------- replica routing + compaction hand-off
def test_replica_reads_via_preference_and_during_live_compact(titles, tmp_path):
    """Acceptance: read_preference='replica' reads are served by the replica
    (server-side op counters) — including while a live compact() is in
    flight — and ids beyond the replica's generation fall back to the
    primary (the staleness guard)."""
    store = CompressedStringStore.build(
        titles[:1500], sample_bytes=SAMPLE, strings_per_segment=256
    )
    d = str(tmp_path / "shards")
    save_sharded(store, d, 2)
    tail_dir = os.path.join(d, "shard-0001")
    servers = [
        ShardServer.from_dir(os.path.join(d, f"shard-{k:04d}")).start()
        for k in range(2)
    ]
    client = connect(format_tcp_url([s.address for s in servers]), dir_path=d)
    replica = None
    try:
        pre_ids = client.extend([b"pre-%d" % i for i in range(20)])
        client.save()  # replica opens the saved (current) generation
        replica = ShardServer.from_dir(tail_dir, read_only=True).start()
        client.register_replica(1, replica.address)

        # --- outside any compaction window: replica takes preference reads
        before = replica.op_counts.get("multiget", 0)
        assert client.multiget(pre_ids[:4], read_preference="replica") == \
            [b"pre-%d" % i for i in range(4)]
        assert replica.op_counts.get("multiget", 0) > before
        # "any" round-robins primary + replica: over several reads both serve
        p_before = servers[1].op_counts.get("multiget", 0)
        r_before = replica.op_counts.get("multiget", 0)
        for _ in range(4):
            client.get(pre_ids[0], read_preference="any")
        assert servers[1].op_counts.get("multiget", 0) > p_before
        assert replica.op_counts.get("multiget", 0) > r_before
        # staleness guard: an id appended AFTER the replica opened must be
        # answered by the primary even under read_preference="replica"
        fresh = client.append(b"past-the-replica-generation")
        r_before = replica.op_counts.get("multiget", 0)
        assert client.get(fresh, read_preference="replica") == \
            b"past-the-replica-generation"
        assert replica.op_counts.get("multiget", 0) == r_before

        # --- while a live compact() is in flight, replica serves the reads
        primary_store = servers[1].store
        orig_compact = primary_store.compact

        def slow_compact(**kw):
            time.sleep(0.6)
            return orig_compact(**kw)

        primary_store.compact = slow_compact
        done = {}
        compacter = threading.Thread(
            target=lambda: done.update(report=client.compact(shard=1))
        )
        compacter.start()
        deadline = time.time() + 5
        while not client.backend._draining.get(1) and time.time() < deadline:
            time.sleep(0.01)
        assert client.backend._draining.get(1), "compact never drained"
        r_before = replica.op_counts.get("multiget", 0)
        t0 = time.time()
        assert client.multiget(pre_ids, read_preference="replica") == \
            [b"pre-%d" % i for i in range(20)]
        assert client.get(pre_ids[3]) == b"pre-3"  # default pref drains too
        assert time.time() - t0 < 0.5, "reads waited on the rewrite"
        assert replica.op_counts.get("multiget", 0) >= r_before + 2
        mid = client.append(b"parked-during-compact")
        compacter.join(timeout=30)
        assert done["report"][0]["n_strings"] > 0
        assert client.get(mid) == b"parked-during-compact"
    finally:
        client.close()
        for s in servers:
            s.close()
        if replica is not None:
            replica.close()


# ----------------------------------------------------- kill/restart reconnect
def _spawn_server(args):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.net", *args],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
        env=CHILD_ENV,
    )
    line = proc.stdout.readline()
    m = re.search(r"SHARD_SERVER_READY port=(\d+)", line)
    if not m:
        proc.terminate()
        raise AssertionError(
            f"server never became ready: {line!r}\n{proc.stderr.read()}"
        )
    return proc, ("127.0.0.1", int(m.group(1)))


def test_client_reconnects_across_server_restart(corpus_dirs, titles):
    proc, addr = _spawn_server([corpus_dirs["mut"]])
    client = None
    try:
        client = connect(f"tcp://{addr[0]}:{addr[1]}")
        assert client.get(1) == titles[1]
        proc.terminate()
        proc.wait()
        proc, _ = _spawn_server([corpus_dirs["mut"], "--port", str(addr[1])])
        # the session re-finds the restarted process transparently
        assert client.multiget([1, 5]) == [titles[1], titles[5]]
        assert client.backend.clients[0].reconnects >= 1
    finally:
        if client is not None:
            client.close()
        proc.terminate()


# ------------------------------------------------- adaptive max_wait_s knob
def test_adaptive_controller_shrinks_window_when_p99_overshoots(titles):
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE)
    with StoreService(store, max_wait_s=0.004, target_p99_s=1e-9,
                      adapt_window=8) as svc:
        for i in range(24):
            assert svc.get(i % 256) == titles[i % 256]
        assert svc.max_wait_s < 0.004
        assert svc.wait_adjustments >= 1
        assert svc.stats()["target_p99_s"] == 1e-9


def test_adaptive_controller_grows_window_under_headroom(titles):
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE)
    with StoreService(store, max_wait_s=0.0, target_p99_s=10.0,
                      adapt_window=8, max_wait_cap_s=0.002) as svc:
        for i in range(64):
            svc.get(i % 256)
        assert 0.0 < svc.max_wait_s <= 0.002
        assert svc.wait_adjustments >= 1


def test_target_p99_surfaced_through_connect(corpus_dirs, titles):
    with connect(f"file://{corpus_dirs['flat']}", target_p99_ms=0.0001,
                 max_wait_s=0.004, adapt_window=8) as client:
        for i in range(24):
            client.get(i)
        snap = client.stats()
        assert snap["target_p99_s"] == pytest.approx(1e-7)
        assert snap["max_wait_s"] < 0.004
        assert snap["backend"]["service"]["wait_adjustments"] >= 1


# ------------------------------------------------------------------ wrapping
def test_wrap_existing_backends(titles):
    store = CompressedStringStore.build(titles[:512], sample_bytes=SAMPLE)
    with wrap(store) as client:
        assert isinstance(client, StoreClient)
        assert client.scheme == "file"
        assert client.multiget([0, 5]) == [titles[0], titles[5]]
    with pytest.raises(TypeError, match="cannot wrap"):
        wrap(object())


def test_mut_client_appends_and_saves(corpus_dirs, titles, tmp_path):
    d = str(tmp_path / "mut2")
    MutableStringStore.open(corpus_dirs["flat"]).save(d)
    with connect(f"mut://{d}") as client:
        n0 = len(client)
        new_id = client.append(b"v3-append")
        ids = client.extend_async([b"v3-a", b"v3-b"]).result(30)
        assert [new_id, *ids] == [n0, n0 + 1, n0 + 2]
        assert client.multiget([new_id, *ids]) == [b"v3-append", b"v3-a", b"v3-b"]
        client.save()
    with connect(f"file://{d}") as reopened:  # durable, readable read-only
        assert reopened.get(new_id) == b"v3-append"
        assert len(reopened) == n0 + 3
