"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + prefill->decode consistency on CPU. Asserts output
shapes and absence of NaNs (the spec's required smoke coverage)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.model import (build_cache, build_params, demo_batch,
                                loss_fn, model_forward, serve_decode,
                                serve_prefill)

ARCHS = sorted(REGISTRY)


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = REGISTRY[name].smoke()
            params = build_params(cfg, seed=0)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(smoke_state, name):
    cfg, params = smoke_state(name)
    batch = demo_batch(cfg, batch=2, seq=32, kind="train")
    logits = model_forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCHS)
def test_train_grad_step(smoke_state, name):
    cfg, params = smoke_state(name)
    batch = demo_batch(cfg, batch=2, seq=32, kind="train")
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves)
    # embeddings must receive gradient
    gnorm = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(smoke_state, name):
    cfg, params = smoke_state(name)
    cache = build_cache(cfg, batch=2, max_seq=64)
    if cfg.family == "encdec":
        # fill cross K/V via prefill
        batch = demo_batch(cfg, batch=2, seq=8, kind="prefill")
        _, cache = serve_prefill(params, batch, cfg, max_seq=64)
    tok = demo_batch(cfg, batch=2, seq=1, kind="decode")
    logits, cache2 = serve_decode(params, cache, tok, cfg)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", ["yi-9b", "gemma2-2b", "mamba2-780m",
                                  "mixtral-8x22b", "jamba-1.5-large-398b"])
def test_prefill_then_decode_matches_forward(smoke_state, name):
    """Greedy next-token from (prefill + decode) == argmax of forward logits
    at the last position — validates cache layout end-to-end."""
    cfg, params = smoke_state(name)
    batch = demo_batch(cfg, batch=2, seq=16, kind="prefill")
    fw_batch = dict(batch)
    logits_full = model_forward(params, fw_batch, cfg, remat=False)
    last = np.asarray(logits_full[:, -1].astype(jnp.float32))

    pf_logits, cache = serve_prefill(params, batch, cfg, max_seq=64)
    pf = np.asarray(pf_logits.astype(jnp.float32))
    np.testing.assert_allclose(pf, last, rtol=2e-2, atol=2e-2)
