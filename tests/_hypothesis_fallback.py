"""Fallback stand-ins for hypothesis when it is not installed.

Tier-1 must collect and run without optional dev deps (ROADMAP). Test modules
do ``from _hypothesis_fallback import given, settings, st`` inside the
``except ImportError`` arm of their hypothesis import; property-based tests
then collect as zero-argument functions that skip with a clear reason, while
every non-property test in the module still runs.
"""

from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed (see requirements-dev.txt)")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _AnyStrategy:
    """Accepts any strategy constructor call; values are never drawn."""

    def __getattr__(self, _name):
        def strategy(*_args, **_kwargs):
            return None

        return strategy


st = _AnyStrategy()
