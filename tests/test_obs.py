"""Tests for repro.obs — metrics registry, tracing, and export surfaces.

Histogram percentile/merge correctness (merged p99 == pooled p99 within one
bucket), registry identity-merge + Prometheus rendering invariants (bucket
counts sum to the op counter), tracer span parentage and the bounded
slow-request ring, protocol trace-header round-trips, version compat in both
directions (old client -> new server, new client -> old server via the caps
probe), and end-to-end span chains across live ``shard://`` and ``tcp://``
multigets including the ``stats`` RPC metrics extension and ``trace_dump``
RPC.

Everything here is stdlib + numpy, so the minimal-numpy CI job runs it.
"""

import json
import math
import os
import re
import socket
import threading
import urllib.error
import urllib.request
from bisect import bisect_left

import numpy as np
import pytest

from repro.client import connect
from repro.data.synth import load_dataset
from repro.distributed import save_sharded
from repro.net import RemoteShardClient, ShardServer
from repro.net import protocol as P
from repro.obs import (
    TRACER,
    Counter,
    Histogram,
    MetricsRegistry,
    TraceContext,
    Tracer,
    merge_hist_states,
    new_trace_id,
    start_metrics_server,
    summarize_hist_state,
)
from repro.store import CompressedStringStore

SAMPLE = 1 << 14


@pytest.fixture(scope="module")
def titles():
    return load_dataset("book_titles", SAMPLE)


@pytest.fixture(scope="module")
def sharded_dir(titles, tmp_path_factory):
    store = CompressedStringStore.build(
        titles, sample_bytes=SAMPLE, strings_per_segment=256
    )
    d = str(tmp_path_factory.mktemp("obs") / "shards")
    save_sharded(store, d, 2)
    return d


@pytest.fixture()
def server(sharded_dir):
    s = ShardServer.from_dir(os.path.join(sharded_dir, "shard-0000")).start()
    yield s
    s.close()


@pytest.fixture()
def tcp_cluster(sharded_dir):
    servers = [
        ShardServer.from_dir(os.path.join(sharded_dir, f"shard-{k:04d}")).start()
        for k in range(2)
    ]
    yield servers
    for s in servers:
        s.close()


def _bucket_interval(bounds, value):
    """The ``(lo, hi]`` histogram bucket a value falls in."""
    i = bisect_left(bounds, value)
    lo = bounds[i - 1] if i else 0.0
    hi = bounds[i] if i < len(bounds) else bounds[-1] * 2
    return lo, hi


def _assert_parentage(trace):
    """Every span is the root or a child of another span in the trace."""
    span_ids = {s["span_id"] for s in trace["spans"]}
    roots = [s for s in trace["spans"] if s["parent_id"] == 0]
    assert len(roots) == 1, f"expected one root span, got {roots}"
    for s in trace["spans"]:
        if s["parent_id"] != 0:
            assert s["parent_id"] in span_ids, f"orphaned span {s}"
        assert s["trace_id"] == trace["trace_id"]


# ------------------------------------------------------------------ histogram
def test_histogram_percentiles_within_one_bucket():
    h = Histogram("t_lat_us")
    values = [3.0, 5.0, 9.0, 17.0, 33.0, 100.0, 1000.0, 5000.0]
    for v in values:
        h.record(v)
    s = h.summary()
    assert s["count"] == len(values)
    assert s["mean_us"] == pytest.approx(sum(values) / len(values))
    for pct, key in ((50.0, "p50_us"), (99.0, "p99_us"), (99.9, "p999_us")):
        rank = max(1, math.ceil(len(values) * pct / 100.0))
        true = sorted(values)[rank - 1]
        lo, hi = _bucket_interval(h.bounds, true)
        assert lo < s[key] <= hi, f"{key}: {s[key]} not in ({lo}, {hi}]"


def test_histogram_overflow_bucket_and_count():
    h = Histogram("t_over_us")
    h.record(1e12)  # way past the last bound
    h.record(0.5)  # below the first bound
    assert h.count == 2
    state = h.state()
    assert state["counts"][-1] == 1  # overflow
    assert state["counts"][0] == 1  # first finite bucket
    assert len(state["counts"]) == len(state["bounds"]) + 1


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(1.0, 1.0, 2.0))


def test_merged_percentiles_equal_pooled_within_one_bucket():
    rng = np.random.default_rng(42)
    a = rng.lognormal(mean=4.0, sigma=1.5, size=500)
    b = rng.lognormal(mean=6.0, sigma=1.0, size=300)
    ha, hb, pooled = Histogram("m"), Histogram("m"), Histogram("m")
    for v in a:
        ha.record(float(v))
    for v in b:
        hb.record(float(v))
    for v in np.concatenate([a, b]):
        pooled.record(float(v))
    merged = merge_hist_states([ha.state(), hb.state()])
    # the merge is exact: merged counts equal a histogram of pooled samples
    assert merged["counts"] == pooled.state()["counts"]
    assert merged["sum"] == pytest.approx(pooled.sum)
    ms, ps = summarize_hist_state(merged), pooled.summary()
    for k in ("p50_us", "p99_us", "p999_us", "count", "mean_us"):
        assert ms[k] == pytest.approx(ps[k]), k
    # and the merged p99 lands in the same bucket as the true sample p99
    samples = np.sort(np.concatenate([a, b]))
    true_p99 = float(samples[math.ceil(0.99 * len(samples)) - 1])
    lo, hi = _bucket_interval(merged["bounds"], true_p99)
    assert lo < ms["p99_us"] <= hi


def test_merge_rejects_mismatched_bounds():
    a = Histogram("a", bounds=(1.0, 2.0)).state()
    b = Histogram("b", bounds=(1.0, 4.0)).state()
    with pytest.raises(ValueError):
        merge_hist_states([a, b])


def test_merge_and_summary_of_nothing():
    assert merge_hist_states([]) is None
    assert merge_hist_states([None, {}]) is None
    empty = summarize_hist_state(None)
    assert empty == {"p50_us": 0.0, "p99_us": 0.0, "p999_us": 0.0,
                     "count": 0, "mean_us": 0.0}


def test_counter_exact_under_concurrency():
    c = Counter("c_total")
    n_threads, per_thread = 8, 10_000

    def work():
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per_thread


# ------------------------------------------------------------------- registry
def test_registry_merges_same_identity_instruments():
    reg = MetricsRegistry()
    h1 = reg.register(Histogram("repro_x_lat_us", labels={"backend": "numpy"}))
    h2 = reg.register(Histogram("repro_x_lat_us", labels={"backend": "numpy"}))
    other = reg.register(Histogram("repro_x_lat_us", labels={"backend": "pallas"}))
    c = reg.counter("repro_x_total", backend="numpy")
    for v in (10.0, 20.0):
        h1.record(v)
        c.inc()
    h2.record(40.0)
    c.inc()
    other.record(7.0)
    series = {
        (m["name"], tuple(sorted(m["labels"].items()))): m
        for m in reg.snapshot()["metrics"]
    }
    merged = series[("repro_x_lat_us", (("backend", "numpy"),))]
    assert sum(merged["counts"]) == 3
    assert merged["sum"] == pytest.approx(70.0)
    # label isolation: the pallas series did not leak into the numpy merge
    assert series[("repro_x_lat_us", (("backend", "pallas"),))]["sum"] == 7.0
    assert series[("repro_x_total", (("backend", "numpy"),))]["value"] == 3


def test_registry_shared_series_and_kind_conflict():
    reg = MetricsRegistry()
    a = reg.counter("repro_shared_total", op="get")
    b = reg.counter("repro_shared_total", op="get")
    assert a is b  # same (name, labels) -> same object
    with pytest.raises(TypeError):
        reg.gauge("repro_shared_total", op="get")


def test_prometheus_bucket_counts_sum_to_op_counter():
    reg = MetricsRegistry()
    h = reg.register(Histogram("repro_y_lat_us", labels={"backend": "numpy"}))
    c = reg.counter("repro_y_requests_total", backend="numpy")
    for v in (1.5, 3.0, 1e9):  # includes one overflow sample
        h.record(v)
        c.inc()
    text = reg.render_prometheus()
    assert "# TYPE repro_y_lat_us histogram" in text
    assert "# TYPE repro_y_requests_total counter" in text
    counter = re.search(r'repro_y_requests_total\{backend="numpy"\} (\d+)', text)
    inf = re.search(r'repro_y_lat_us_bucket\{backend="numpy",le="\+Inf"\} (\d+)', text)
    count = re.search(r'repro_y_lat_us_count\{backend="numpy"\} (\d+)', text)
    assert counter and inf and count
    # the acceptance invariant: bucket counts sum to the op counter
    assert int(inf.group(1)) == int(count.group(1)) == int(counter.group(1)) == 3
    # cumulative buckets are non-decreasing
    cums = [
        int(m.group(1))
        for m in re.finditer(r'repro_y_lat_us_bucket\{[^}]*\} (\d+)', text)
    ]
    assert cums == sorted(cums)


# --------------------------------------------------------------------- tracer
def test_span_is_noop_without_ambient_context():
    tr = Tracer()
    with tr.span("x") as ctx:
        assert ctx is None
        assert tr.current() is None
    assert tr.trace_dump() == []


def test_nested_spans_chain_parentage():
    tr = Tracer()
    with tr.span("outer", root=True) as octx:
        assert tr.current() == octx
        with tr.span("inner", batch=3) as ictx:
            assert ictx.trace_id == octx.trace_id
        assert tr.current() == octx  # inner restored the ambient context
    assert tr.current() is None
    (trace,) = tr.trace_dump()
    spans = {s["name"]: s for s in trace["spans"]}
    assert trace["root"] == "outer"
    assert spans["outer"]["parent_id"] == 0
    assert spans["inner"]["parent_id"] == spans["outer"]["span_id"]
    assert spans["inner"]["annotations"] == {"batch": 3}
    _assert_parentage(trace)


def test_record_child_books_queue_hops():
    tr = Tracer()
    root, _ = tr.new_context(None, inherit=False)
    tr.record("root", root, 0, 0.0, 1.0)
    child = tr.record_child("queue.wait", root, 0.1, 0.2, batch=7)
    assert child.trace_id == root.trace_id
    (trace,) = tr.trace_dump()
    (qspan,) = [s for s in trace["spans"] if s["name"] == "queue.wait"]
    assert qspan["parent_id"] == root.span_id
    assert qspan["annotations"] == {"batch": 7}


def test_trace_dump_slowest_first_and_ring_bounded():
    tr = Tracer(max_spans=8)
    for i in range(12):
        ctx, pid = tr.new_context(None, inherit=False)
        tr.record(f"r{i}", ctx, pid, 0.0, (i + 1) / 1000.0)
    dump = tr.trace_dump(4)
    assert [t["root"] for t in dump] == ["r11", "r10", "r9", "r8"]
    # the ring dropped the oldest spans: only 8 traces remain in total
    assert len(tr.trace_dump(100)) == 8


# ------------------------------------------------------------------- protocol
def test_trace_ctx_pack_roundtrip():
    ctx = TraceContext(new_trace_id(), 1234567890123)
    assert P.unpack_trace(P.pack_trace(ctx)) == ctx


def test_frame_trace_header_roundtrip_and_v1_compat():
    ctx = TraceContext(new_trace_id(), 42)
    payload = b"hello"
    traced = P.encode_frame(P.OP_MULTIGET, payload, trace=ctx)
    kind, got, trace, used = P.decode_frame_ex(traced + b"trailing")
    assert (kind, got, trace, used) == (P.OP_MULTIGET, payload, ctx, len(traced))
    # plain v1 frame -> no trace, and it is byte-identical to pre-trace frames
    plain = P.encode_frame(P.OP_MULTIGET, payload)
    kind, got, trace, used = P.decode_frame_ex(plain)
    assert (kind, got, trace, used) == (P.OP_MULTIGET, payload, None, len(plain))
    # the old-signature decoder sees the same payload with the trace stripped
    kind, got, used = P.decode_frame(traced)
    assert (kind, got, used) == (P.OP_MULTIGET, payload, len(traced))


def test_trace_header_over_socket():
    ctx = TraceContext(new_trace_id(), 99)
    a, b = socket.socketpair()
    try:
        P.send_frame(a, P.OP_PING, b"x", trace=ctx)
        P.send_frame(a, P.OP_PING, b"y")
        assert P.recv_frame_ex(b) == (P.OP_PING, b"x", ctx)
        # an old-API reader consumes the traced frame without seeing it
        P.send_frame(a, P.OP_PING, b"z", trace=ctx)
        assert P.recv_frame(b) == (P.OP_PING, b"y")
        assert P.recv_frame(b) == (P.OP_PING, b"z")
    finally:
        a.close()
        b.close()


# --------------------------------------------------------------------- compat
def test_old_client_v1_frames_against_new_server(server):
    """A pre-trace client speaks plain v1 frames: ping still echoes
    (non-probe payloads), multiget still answers."""
    sock = socket.create_connection(server.address, timeout=5)
    try:
        P.send_frame(sock, P.OP_PING, b"legacy")
        assert P.recv_frame(sock) == (P.ST_OK, b"legacy")
        P.send_frame(sock, P.OP_MULTIGET, P.pack_ids([0, 1]))
        status, resp = P.recv_frame(sock)
        assert status == P.ST_OK
        assert len(P.unpack_bytes_list(resp)) == 2
    finally:
        sock.close()


def test_new_client_probes_and_falls_back_to_v1():
    """Against a server that echoes the caps probe (= an old server), a
    traced client must keep every wire frame at v1 — no trace header."""
    listener = socket.create_server(("127.0.0.1", 0))
    received = []

    def legacy_server():
        conn, _ = listener.accept()
        with conn:
            while True:
                got = P.recv_frame_ex(conn)
                if got is None:
                    return
                kind, payload, trace = got
                received.append((kind, payload, trace))
                if kind == P.OP_PING:
                    P.send_frame(conn, P.ST_OK, payload)  # verbatim echo
                else:
                    P.send_frame(conn, P.ST_OK, P.pack_bytes_list([b"a", b"b"]))

    t = threading.Thread(target=legacy_server, daemon=True)
    t.start()
    client = RemoteShardClient(
        listener.getsockname(), pool_size=1, reconnect_attempts=0
    )
    prev = TRACER.activate(TraceContext(new_trace_id(), 1))
    try:
        out = client.multiget([0, 1])
    finally:
        TRACER.restore(prev)
        client.close()
        listener.close()
    assert out == [b"a", b"b"]
    assert client._traced is False
    # the probe went out first, and nothing ever carried a trace header
    assert received[0][:2] == (P.OP_PING, P.CAPS_PROBE)
    assert all(trace is None for _, _, trace in received)


def test_caps_probe_against_new_server(server):
    client = RemoteShardClient(server.address)
    try:
        assert client.ping(b"abc") == b"abc"  # normal pings still echo
        assert client._probe_caps() is True
        assert client._traced is True
    finally:
        client.close()


# ----------------------------------------------------------------- end-to-end
def test_trace_spans_local_shard_multiget(sharded_dir):
    TRACER.clear()
    client = connect(f"shard://{sharded_dir}")
    try:
        out = client.multiget([0, 1, 2, 5])
        assert len(out) == 4
    finally:
        client.close()
    trace = next(
        t for t in TRACER.trace_dump(8) if t["root"] == "client.multiget"
    )
    names = {s["name"] for s in trace["spans"]}
    assert {"client.multiget", "store.decode"} <= names
    _assert_parentage(trace)


def test_tcp_multiget_trace_has_full_span_chain(tcp_cluster):
    TRACER.clear()
    url = "tcp://" + ",".join(f"{h}:{p}" for h, p in (s.address for s in tcp_cluster))
    client = connect(url)
    try:
        out = client.multiget([0, 1, 2, 3])
        assert len(out) == 4
    finally:
        client.close()
    trace = next(
        t for t in TRACER.trace_dump(8) if t["root"] == "client.multiget"
    )
    names = {s["name"] for s in trace["spans"]}
    # the acceptance chain: client -> socket -> server -> coalesce -> decode
    assert {"client.multiget", "rpc.multiget", "server.multiget",
            "service.coalesce", "store.decode"} <= names
    assert trace["n_spans"] >= 4
    _assert_parentage(trace)
    decode = next(s for s in trace["spans"] if s["name"] == "store.decode")
    assert decode["annotations"]["backend"]  # numpy | pallas | jax ...
    assert decode["annotations"]["batch"] >= 1
    coalesce = next(s for s in trace["spans"] if s["name"] == "service.coalesce")
    assert coalesce["annotations"]["batch"] >= 1


def test_stats_metrics_extension_and_trace_dump_rpc(tcp_cluster):
    TRACER.clear()
    client = RemoteShardClient(tcp_cluster[0].address)
    try:
        plain = client.stats()
        assert "metrics" not in plain  # the extension is opt-in
        ctx, _ = TRACER.new_context(None, inherit=False)
        prev = TRACER.activate(ctx)
        try:
            client.multiget([0, 1])
        finally:
            TRACER.restore(prev)
        stats = client.stats(metrics=True)
        names = {m["name"] for m in stats["metrics"]["metrics"]}
        assert "repro_rpc_requests_total" in names
        assert "repro_store_multiget_latency_us" in names
        assert "repro_service_request_latency_us" in names
        # the server's slow-request log is reachable over RPC and holds the
        # traced multiget with its server-side spans
        dump = client.trace_dump(16)
        trace = next(t for t in dump if t["trace_id"] == ctx.trace_id)
        names = {s["name"] for s in trace["spans"]}
        assert {"server.multiget", "service.coalesce", "store.decode"} <= names
    finally:
        client.close()


# ----------------------------------------------------------------------- http
def test_metrics_http_server_endpoints():
    reg = MetricsRegistry()
    tr = Tracer()
    h = reg.register(Histogram("repro_z_lat_us", labels={"backend": "numpy"}))
    c = reg.counter("repro_z_requests_total")
    for v in (2.0, 8.0, 40.0):
        h.record(v)
        c.inc()
    with tr.span("req", root=True):
        pass
    srv = start_metrics_server(port=0, registry=reg, tracer=tr)
    try:
        base = f"http://127.0.0.1:{srv.port}"
        text = urllib.request.urlopen(base + "/metrics").read().decode()
        count = re.search(r'repro_z_lat_us_count\{backend="numpy"\} (\d+)', text)
        inf = re.search(r'repro_z_lat_us_bucket\{backend="numpy",le="\+Inf"\} (\d+)', text)
        assert count and int(count.group(1)) == 3
        assert inf and int(inf.group(1)) == 3
        assert re.search(r"repro_z_requests_total 3\b", text)
        traces = json.loads(
            urllib.request.urlopen(base + "/traces?n=4").read().decode()
        )
        assert traces and traces[0]["root"] == "req"
        assert urllib.request.urlopen(base + "/healthz").read() == b"ok\n"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope")
    finally:
        srv.close()
