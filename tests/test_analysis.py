"""Tests for the HLO roofline analyzer and synthetic-data generators."""

import pytest

from repro.data.synth import DATASETS, dataset_stats, load_dataset
from repro.launch.hlo_analysis import (analyze_hlo, region_multipliers,
                                       split_regions)

_FAKE_HLO = """
HloModule jit_f, is_scheduled=true

%region_body.1 (arg: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
}

%region_cond.2 (arg.1: (s32[], f32[128,128])) -> pred[] {
  %p.1 = (s32[], f32[128,128]) parameter(0)
  %i.1 = s32[] get-tuple-element(%p.1), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

ENTRY %main.3 (x.1: f32[128,128]) -> f32[128,128] {
  %x.2 = f32[128,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%zero, %x.2)
  %w.5 = (s32[], f32[128,128]{1,0}) while(%t0), condition=%region_cond.2, body=%region_body.1
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w.5), index=1
}
"""


def test_split_regions_finds_all():
    regs = split_regions(_FAKE_HLO)
    assert "__entry__" in regs
    assert "%region_body.1" in regs
    assert "%region_cond.2" in regs


def test_trip_count_multiplier():
    regs = split_regions(_FAKE_HLO)
    mult = region_multipliers(regs)
    assert mult["%region_body.1"] == 7.0
    assert mult[regs["__entry__"].name] == 1.0


def test_dot_flops_scaled_by_trip_count():
    res = analyze_hlo(_FAKE_HLO)
    # one 128^3 matmul per iteration, 7 iterations
    assert res["flops"] == pytest.approx(7 * 2 * 128 ** 3)
    # the all-reduce result is 128*128*4 bytes, 7 times
    assert res["collective_bytes"] == pytest.approx(7 * 128 * 128 * 4)
    assert "all-reduce" in res["collectives"]


def test_analyze_empty():
    res = analyze_hlo("HloModule empty")
    assert res["flops"] == 0.0


# ----------------------------------------------------------------- datasets
@pytest.mark.parametrize("name", sorted(DATASETS))
def test_datasets_deterministic_and_sized(name):
    a = load_dataset(name, 1 << 16)
    b = load_dataset(name, 1 << 16)
    assert a == b
    st = dataset_stats(a)
    assert st["bytes"] >= (1 << 16)
    assert 0 < st["avg_len"] < 2000


def test_dataset_shapes_match_paper_profile():
    """Avg lengths roughly track Table 2 (titles ~52B, reviews ~420B...)."""
    stats = {n: dataset_stats(load_dataset(n, 1 << 18))["avg_len"]
             for n in DATASETS}
    assert stats["news_headlines"] < stats["book_titles"] < 70
    assert stats["book_reviews"] > 250
    assert 45 < stats["urls"] < 140
    assert 45 < stats["tweets"] < 120


def test_roofline_loader_reads_records():
    from repro.launch.roofline import load_records
    recs = load_records("16x16")
    if not recs:  # results/ is generated, not checked in: absent on fresh clones
        pytest.skip("no dryrun records; generate with "
                    "`python -m repro.launch.dryrun --all`")
    assert len(recs) >= 30  # partial/truncated sweeps should fail, not pass
    for r in recs[:5]:
        assert {"t_compute_s", "t_memory_s", "t_collective_s",
                "bottleneck"} <= set(r)
