"""End-to-end system tests: training loop + fault tolerance + checkpoint
elasticity + optimizer + data pipeline + gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import REGISTRY
from repro.data.corpus import CompressedCorpusStore
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.data.synth import load_dataset
from repro.models.model import build_params, demo_batch
from repro.optim.adamw import (AdamWConfig, apply_updates, cosine_schedule,
                               dequantize_q8, init_state, quantize_q8)
from repro.runtime.loop import LoopConfig, TrainLoop
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = REGISTRY["h2o-danube-1.8b"].smoke()
    params = build_params(cfg, seed=0)
    return cfg, params


# ----------------------------------------------------------------- optimizer
def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    q = quantize_q8(x)
    back = dequantize_q8(q, x.shape)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(jnp.max(jnp.abs(x))) / 100)


def test_adamw_reduces_loss(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-2)
    step = jax.jit(make_train_step(cfg, opt, schedule_total=100))
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    batch = demo_batch(cfg, batch=2, seq=32, kind="train")
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_adamw_quantized_close_to_exact(tiny):
    cfg, params = tiny
    batch = demo_batch(cfg, batch=2, seq=16, kind="train")
    from repro.models.model import loss_fn
    grads = jax.grad(loss_fn)(params, batch, cfg)
    outs = {}
    for quant in (False, True):
        opt = AdamWConfig(lr=1e-3, quantized_moments=quant)
        st = init_state(params, opt)
        newp, _ = apply_updates(params, grads, st, opt)
        outs[quant] = newp
    a = jax.tree.leaves(outs[False])[5].astype(jnp.float32)
    b = jax.tree.leaves(outs[True])[5].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(jnp.int32(10), warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_schedule(jnp.int32(100), warmup=10, total=100))
    assert 0.09 < end < 0.11


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_atomic_roundtrip(tiny, tmp_path):
    cfg, params = tiny
    opt = AdamWConfig()
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    ckpt_lib.save(state, 7, d)
    assert ckpt_lib.latest_step(d) == 7
    abstract = jax.eval_shape(lambda: state)
    restored, step = ckpt_lib.restore(d, abstract)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_reshard(tiny, tmp_path):
    """Save unsharded, restore onto a mesh with NamedShardings (the elastic
    path: any checkpoint onto any mesh)."""
    cfg, params = tiny
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state = {"params": params, "step": jnp.int32(3)}
    d = str(tmp_path / "ck2")
    ckpt_lib.save(state, 3, d)
    abstract = jax.eval_shape(lambda: state)
    sh = jax.tree.map(lambda leaf: NamedSharding(mesh, P()), abstract)
    restored, _ = ckpt_lib.restore(d, abstract, shardings=sh)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf.sharding, NamedSharding)


def test_checkpoint_detects_tree_mismatch(tmp_path):
    d = str(tmp_path / "ckm")
    ckpt_lib.save({"x": jnp.int32(1)}, 1, d)
    with pytest.raises(ValueError, match="mismatch"):
        ckpt_lib.restore(d, jax.eval_shape(lambda: {"y": jnp.int32(0)}))


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ck3")
    for s in (1, 2, 3, 4):
        ckpt_lib.save({"x": jnp.int32(s)}, s, d)
    removed = ckpt_lib.gc(d, keep=2)
    assert len(removed) == 2
    assert ckpt_lib.latest_step(d) == 4


def test_checkpoint_tmp_dir_ignored(tmp_path):
    d = str(tmp_path / "ck4")
    ckpt_lib.save({"x": jnp.int32(1)}, 1, d)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated dead writer
    assert ckpt_lib.latest_step(d) == 1


# --------------------------------------------------------------- train loop
def test_train_loop_with_resume(tiny, tmp_path):
    cfg, _ = tiny
    opt = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def batch_fn(step):
        return demo_batch(cfg, batch=2, seq=16, kind="train", seed=step)

    def fresh_state():
        p = build_params(cfg, seed=0)
        return {"params": p, "opt": init_state(p, opt),
                "step": jnp.zeros((), jnp.int32)}

    lc = LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path / "loop"),
                    log_every=100)
    loop = TrainLoop(step_fn, fresh_state(), batch_fn, lc,
                     install_signals=False)
    stats = loop.run(log=lambda *_: None)
    assert stats.steps_run == 6
    assert ckpt_lib.latest_step(lc.ckpt_dir) == 6

    # crash + restart: a new loop resumes from the committed checkpoint
    lc2 = LoopConfig(total_steps=9, ckpt_every=3, ckpt_dir=lc.ckpt_dir,
                     log_every=100)
    abstract = jax.eval_shape(fresh_state)
    loop2 = TrainLoop(step_fn, fresh_state(), batch_fn, lc2,
                      abstract_state=abstract, install_signals=False)
    stats2 = loop2.run(log=lambda *_: None)
    assert stats2.resumed_from == 6
    assert stats2.steps_run == 3  # only the remaining steps


def test_preemption_saves_and_exits(tiny, tmp_path):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    lc = LoopConfig(total_steps=50, ckpt_every=100,
                    ckpt_dir=str(tmp_path / "pre"), log_every=1000)
    loop = TrainLoop(step_fn, state,
                     lambda s: demo_batch(cfg, 2, 16, "train", s),
                     lc, install_signals=False)
    orig = loop.train_step

    def step_then_preempt(st, b):
        out = orig(st, b)
        if int(np.asarray(out[0]["step"])) >= 2:
            loop._on_preempt(None, None)  # simulated SIGTERM
        return out

    loop.train_step = step_then_preempt
    stats = loop.run(log=lambda *_: None)
    assert stats.preempted
    assert stats.steps_run < 50
    assert ckpt_lib.latest_step(lc.ckpt_dir) is not None


def test_straggler_watchdog_counts(tiny, tmp_path):
    cfg, params = tiny
    opt = AdamWConfig(lr=1e-3)
    base = jax.jit(make_train_step(cfg, opt))
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    import time as _t
    calls = {"n": 0}

    def slow_every_5(st, b):
        calls["n"] += 1
        if calls["n"] == 8:
            _t.sleep(1.0)  # injected straggler
        return base(st, b)

    lc = LoopConfig(total_steps=10, ckpt_every=1000,
                    ckpt_dir=str(tmp_path / "wd"), log_every=1000,
                    straggler_factor=3.0)
    loop = TrainLoop(slow_every_5, state,
                     lambda s: demo_batch(cfg, 2, 16, "train", s % 3),
                     lc, install_signals=False)
    stats = loop.run(log=lambda *_: None)
    assert stats.straggler_steps >= 1


# ------------------------------------------------------------ grad compress
def test_compressed_pmean_single_axis():
    from repro.distributed.compress import (compressed_pmean,
                                            init_error_feedback)
    from repro.distributed.sharding import use_mesh
    mesh = jax.make_mesh((1,), ("pod",))
    tree = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                             jnp.float32)}
    ef = init_error_feedback(tree)
    with use_mesh(mesh):
        mean, new_ef = compressed_pmean(tree, ef, mesh, axis="pod")
    err = np.abs(np.asarray(mean["a"]) - np.asarray(tree["a"])).max()
    scale = float(jnp.abs(tree["a"]).max()) / 127.0
    assert err <= scale * 1.01  # quantisation bound
    np.testing.assert_allclose(np.asarray(new_ef["a"]),
                               np.asarray(tree["a"] - mean["a"]), atol=1e-6)


# ------------------------------------------------------------- data plane
def test_corpus_store_and_pipeline_resume():
    strings = load_dataset("news_headlines", 1 << 18)
    store = CompressedCorpusStore.build(strings, sample_bytes=1 << 18)
    assert store.compression_ratio > 2.0
    spec = BatchSpec(global_batch=4, seq_len=64, seed=9)
    pipe = TokenPipeline(store, spec)
    b5 = pipe.batch(5)
    pipe2 = TokenPipeline(store, spec)  # fresh process after restart
    np.testing.assert_array_equal(b5["tokens"], pipe2.batch(5)["tokens"])


def test_microbatched_train_step_matches_single(tiny):
    cfg, params = tiny
    opt = AdamWConfig(lr=0.0, weight_decay=0.0)
    s1 = make_train_step(cfg, opt, microbatches=1)
    s2 = make_train_step(cfg, opt, microbatches=2)
    state = {"params": params, "opt": init_state(params, opt),
             "step": jnp.zeros((), jnp.int32)}
    batch = demo_batch(cfg, batch=4, seq=16, kind="train")
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)


# ------------------------------------------------------ sharding unit rules
def test_param_specs_shapes_match():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_specs_tree
    cfg = REGISTRY["yi-9b"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.transformer import abstract_params
    ap = abstract_params(cfg)
    specs = param_specs_tree(ap, mesh, cfg, fsdp=True)
    flat_p = jax.tree_util.tree_leaves(ap)
    flat_s = jax.tree_util.tree_leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape)
