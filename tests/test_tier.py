"""Tiered storage: the RLZ cold tier and temperature-driven movement.

Covers the RLZ codec itself (round-trips, literals-only pathologies), the
byte-identity contract — a majority-demoted store must answer every read
API identically to its all-hot twin, across save→open, demote→promote and
compact() — the memory win that justifies the tier, the per-segment
read-rate EWMA, off-thread demotion + read-burst promotion, the OP_TIER
RPC through a real server, the sharded/client fan-out, the loadgen
cold-skew knob's determinism guard, and the async tail-seal satellite.
Everything runs on a numpy-only host."""

import os
import threading

import numpy as np
import pytest

from repro.core import registry
from repro.core.codec import Encoder
from repro.core.rlz import RLZCodec, decode_ids, decode_range, rlz_nbytes
from repro.data.synth import load_dataset
from repro.distributed import ShardedStringStore, save_sharded
from repro.loadgen import WorkloadSpec, build_schedule
from repro.net import RemoteShardClient, ShardServer
from repro.store import (CompressedStringStore, DriftMonitor,
                         MutableStringStore, tier_op)

SAMPLE = 1 << 18
SPS = 128  # small segments so a corpus spans many demotion candidates
COLD = {"promote_above": 1e9}  # keep segments cold under test read loops


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""
    strings[7] = b"\x00\xff" * 9
    return strings


@pytest.fixture(scope="module")
def artifact(titles):
    return registry.train("onpair16", titles, sample_bytes=SAMPLE)


def _store(titles, n=1000, **kw):
    kw.setdefault("strings_per_segment", SPS)
    kw.setdefault("sample_bytes", SAMPLE)
    return CompressedStringStore.build(titles[:n], **kw)


def _demote_all(store, **params):
    tier = store.enable_tiering(**{**COLD, **params})
    for seg in store.segments.segments:
        tier.demote(seg.index)
    return tier


def _assert_reads_identical(store, titles, n):
    rng = np.random.default_rng(7)
    ids = rng.integers(0, n, 200).tolist()
    assert store.multiget(ids) == [titles[i] for i in ids]
    for i in (0, 3, 7, n // 2, n - 1):
        assert store.get(i) == titles[i]
    assert store.scan(0, n) == titles[:n]
    assert store.scan(SPS - 3, SPS + 3) == titles[SPS - 3:SPS + 3]


# ------------------------------------------------------------- RLZ codec
def test_rlz_roundtrip_against_reference(titles):
    ref = b"".join(titles[:50])
    codec = RLZCodec(ref)
    strings = titles[50:250] + [b"", b"\x00" * 3, titles[60], titles[60]]
    arrays = codec.factorize(strings)
    assert decode_ids(ref, arrays, range(len(strings))) == strings
    # random access: any subset, any order
    assert decode_ids(ref, arrays, [203, 0, 17]) == [
        strings[203], strings[0], strings[17]]
    assert decode_range(ref, arrays, 5, 9) == strings[5:9]
    assert arrays["starts"].shape == (len(strings) + 1,)


def test_rlz_literals_only_when_nothing_matches():
    codec = RLZCodec(b"aaaaaaaaaaaaaaaa", min_match=8)
    rng = np.random.default_rng(0)
    strings = [rng.integers(0, 256, 40, dtype=np.uint8).tobytes()
               for _ in range(20)]
    arrays = codec.factorize(strings)
    assert decode_ids(b"aaaaaaaaaaaaaaaa", arrays, range(20)) == strings
    # incompressible input: the literals blob carries ~everything
    assert arrays["literals"].size >= sum(map(len, strings)) * 0.9


def test_rlz_compresses_redundant_strings(titles):
    ref = b"".join(titles[:200])
    arrays = RLZCodec(ref).factorize(titles[:200])  # self-referential corpus
    assert rlz_nbytes(arrays) < sum(map(len, titles[:200]))


def test_rlz_empty_input():
    arrays = RLZCodec(b"abcdefgh" * 4).factorize([])
    assert decode_ids(b"abcdefgh" * 4, arrays, []) == []
    assert rlz_nbytes(arrays) >= 0


# ---------------------------------------------- byte-identity hot vs cold
def test_demoted_store_reads_byte_identical(titles):
    n = 1000
    store = _store(titles, n)
    tier = _demote_all(store)
    assert len(tier.cold) == store.segments.n_segments
    _assert_reads_identical(store, titles, n)
    assert store.stats.cold_lookups > 0  # misses decoded from RLZ
    # cached entries short-circuit before the tier split
    hits0 = store.cache.hits
    cold0 = store.stats.cold_lookups
    store.multiget([0, 1, 2])
    store.multiget([0, 1, 2])
    assert store.cache.hits > hits0
    assert store.stats.cold_lookups <= cold0 + 3


def test_locate_and_scan_prefix_on_cold_segments(titles):
    n = 600
    store = _store(titles, n)
    hot_locate = [store.locate(titles[i]) for i in range(0, n, 13)]
    prefix = titles[5][:4]
    hot_prefix = store.scan_prefix(prefix, limit=None)
    _demote_all(store)
    assert [store.locate(titles[i]) for i in range(0, n, 13)] == hot_locate
    assert store.locate(b"@@definitely-absent@@") is None
    assert store.scan_prefix(prefix, limit=None) == hot_prefix


def test_memory_drops_at_least_40pct_when_majority_cold(titles):
    # payload-dominated corpus: enough strings that segment bytes dwarf the
    # dictionary's fixed resident cost, as the acceptance criterion requires
    corpus = (titles * 6)[:24_000]
    n = len(corpus)
    store = _store(corpus, n, cache_bytes=0)
    before = store.memory_bytes
    tier = _demote_all(store)
    assert len(tier.cold) >= store.segments.n_segments // 2  # majority cold
    after = store.memory_bytes
    assert after <= before * 0.6, (before, after)
    _assert_reads_identical(store, corpus, n)


def test_save_open_preserves_cold_tier(titles, tmp_path):
    n = 800
    store = _store(titles, n)
    _demote_all(store)
    d = str(tmp_path / "cold")
    store.save(d)
    names = os.listdir(d)
    assert any(f.startswith("cold-") and f.endswith(".rlz") for f in names)

    re = CompressedStringStore.open(d)
    assert re.tier is not None and len(re.tier.cold) > 0
    assert re.tier.promote_above == pytest.approx(COLD["promote_above"])
    _assert_reads_identical(re, titles, n)
    re.cache.clear()
    re.multiget(list(range(0, n, 5)))
    assert re.stats.cold_lookups > 0


def test_save_without_tier_writes_no_cold_files(titles, tmp_path):
    store = _store(titles, 300)
    d = str(tmp_path / "plain")
    store.save(d)
    assert not any(f.startswith("cold-") for f in os.listdir(d))
    re = CompressedStringStore.open(d)
    assert re.tier is None
    assert tier_op(re, "stats") == {"enabled": False}


def test_promote_restores_heap_arrays(titles):
    n = 500
    store = _store(titles, n)
    tier = _demote_all(store)
    seg0 = store.segments.segments[0]
    assert isinstance(seg0.payload, np.memmap)
    assert tier.promote(0) and not tier.promote(0)  # second is a no-op
    assert 0 not in tier.cold
    assert not isinstance(store.segments.segments[0].payload, np.memmap)
    assert tier.promotions == 1
    _assert_reads_identical(store, titles, n)
    snap = store.stats_snapshot()["tier"]
    assert snap["n_cold"] == len(tier.cold)
    assert snap["demotions"] == tier.demotions and snap["promotions"] == 1


def test_read_burst_promotes_cold_segment(titles):
    store = _store(titles, 500)
    tier = store.enable_tiering(promote_above=0.001, halflife_s=30.0)
    assert tier.demote(0) is not None
    for _ in range(3):
        store.multiget(list(range(0, SPS)))
    assert 0 not in tier.cold and tier.promotions >= 1


def test_tick_demotes_idle_segments_off_thread(titles):
    store = _store(titles, 500)
    tier = store.enable_tiering(demote_below=0.05, **COLD)
    scheduled = tier.tick()
    tier.join()
    assert scheduled and len(tier.cold) == len(scheduled)
    worker = tier._worker
    assert worker is not None and worker.daemon
    _assert_reads_identical(store, titles, 500)


def test_compact_folds_cold_tier_back_hot(titles, artifact):
    corpus = Encoder(artifact).encode(titles[:400])
    store = MutableStringStore(artifact, corpus, strings_per_segment=SPS)
    _demote_all(store)
    assert len(store.tier.cold) > 0
    store.compact()
    assert store.tier.cold == {}  # rewrite folded everything back in
    assert store.scan(0, 400) == titles[:400]
    assert not isinstance(store.segments.segments[0].payload, np.memmap)


def test_mutable_save_open_roundtrip_with_cold_tail(titles, artifact,
                                                    tmp_path):
    corpus = Encoder(artifact).encode(titles[:300])
    store = MutableStringStore(artifact, corpus, strings_per_segment=SPS)
    store.extend(titles[300:350])                 # unsealed tail stays hot
    _demote_all(store)
    d = str(tmp_path / "mcold")
    store.save(d)
    re = MutableStringStore.open(d)
    assert re.tier is not None and len(re.tier.cold) > 0
    assert re.scan(0, 350) == titles[:350]
    ids = re.extend(titles[350:400])              # still writable
    assert ids == list(range(350, 400))
    assert re.get(399) == titles[399]


# ---------------------------------------------------- temperature (EWMA)
def test_read_rate_ewma_decays_with_halflife():
    m = DriftMonitor(read_halflife_s=10.0)
    m.note_reads({0: 100}, now=0.0)
    r0 = m.read_rate(0, now=0.0)
    assert r0 > 0
    # one halflife later the decayed mass (and rate) halves
    m.note_reads({0: 0}, now=10.0)
    assert m.read_rate(0, now=10.0) == pytest.approx(r0 / 2)
    # unknown segment reads as stone cold
    assert m.read_rate(99, now=10.0) == 0.0
    assert set(m.read_rates(now=10.0)) == {0}
    m.reset()
    assert m.read_rates() == {}


def test_read_rate_accumulates_sustained_traffic():
    m = DriftMonitor(read_halflife_s=5.0)
    for t in range(10):
        m.note_reads({0: 50, 1: 1}, now=float(t))
    assert m.read_rate(0, now=9.0) > m.read_rate(1, now=9.0) > 0


# ----------------------------------------------------------- tier_op API
def test_tier_op_demote_promote_all(titles):
    store = _store(titles, 500)
    r = tier_op(store, "demote", params=COLD)
    assert r["enabled"] and r["n_cold"] == len(r["demoted"]) > 0
    again = tier_op(store, "demote", params=COLD)
    assert again["demoted"] == []                 # idempotent
    stats = tier_op(store, "stats")
    assert stats["enabled"] and stats["n_cold"] == r["n_cold"]
    assert stats["rlz_bytes"] > 0
    p = tier_op(store, "promote")
    assert sorted(p["promoted"]) == sorted(r["demoted"])
    assert p["n_cold"] == 0
    with pytest.raises(ValueError):
        tier_op(store, "defrost")


def test_tier_op_single_segment(titles):
    store = _store(titles, 500)
    r = tier_op(store, "demote", segment=1, params=COLD)
    assert r["demoted"] == [1] and r["n_cold"] == 1
    assert tier_op(store, "promote", segment=1)["promoted"] == [1]


# ------------------------------------------------------------ OP_TIER RPC
def test_tier_rpc_through_shard_server(titles, tmp_path):
    d = str(tmp_path / "served")
    _store(titles, 600).save(d)
    with ShardServer.from_dir(d).start() as server:
        client = RemoteShardClient(server.address)
        try:
            assert client.supports_tier
            assert client.tier() == {"enabled": False}
            r = client.tier("demote", params=COLD)
            assert r["n_cold"] > 0
            ids = list(range(0, 600, 11))
            assert client.multiget(ids) == [titles[i] for i in ids]
            stats = client.tier("stats")
            assert stats["enabled"] and stats["n_cold"] == r["n_cold"]
            assert client.tier("promote")["n_cold"] == 0
        finally:
            client.close()


def test_sharded_store_tier_fanout(titles, tmp_path):
    store = _store(titles, 600)
    d = str(tmp_path / "sharded")
    save_sharded(store, d, 2)
    sharded = ShardedStringStore.open(d)
    rows = sharded.tier_stats()
    assert len(rows) == 2 and all(not r["enabled"] for r in rows)
    demoted = sharded.demote(**COLD)
    assert all(r["n_cold"] > 0 for r in demoted)
    ids = list(range(0, 600, 9))
    assert sharded.multiget(ids) == [titles[i] for i in ids]
    one = sharded.demote(shard=0, segment=0, **COLD)
    assert len(one) == 1
    with pytest.raises(ValueError):
        sharded.tier(segment=0)                   # segment needs a shard
    assert all(r["n_cold"] == 0 for r in sharded.promote())


# --------------------------------------------------- loadgen cold-skew
def test_cold_fraction_zero_keeps_schedules_identical(titles):
    base = WorkloadSpec(mix={"get": 1.0}, seed=3)
    knob = WorkloadSpec(mix={"get": 1.0}, seed=3, cold_fraction=0.0,
                        cold_band=0.25)
    assert build_schedule(base, 5000, 400) == build_schedule(knob, 5000, 400)


def test_cold_fraction_redirects_reads_into_band(titles):
    spec = WorkloadSpec(mix={"get": 1.0}, seed=3, cold_fraction=0.5,
                        cold_band=0.25)
    n = 10_000
    sched = build_schedule(spec, n, 2000)
    band0 = int(n * 0.75)
    frac = np.mean([op.ids[0] >= band0 for op in sched])
    # zipf alone lands <10% of reads in the top quartile; the knob forces
    # roughly half the draws there
    assert 0.35 < frac < 0.7
    # determinism: same spec, same schedule
    assert build_schedule(spec, n, 2000) == sched
    with pytest.raises(ValueError):
        WorkloadSpec(cold_fraction=1.5)
    with pytest.raises(ValueError):
        WorkloadSpec(cold_band=0.0)


# ----------------------------------------------------- async tail seals
def test_async_seal_commits_off_thread(titles, artifact):
    corpus = Encoder(artifact).encode(titles[:SPS])
    store = MutableStringStore(artifact, corpus, strings_per_segment=SPS)
    assert store.async_seal
    store.extend(titles[SPS:SPS * 3 + 10])
    store.seal_barrier()
    assert store.segments.n_segments == 3
    assert store.stats_snapshot()["n_tail_strings"] == 10
    assert store.scan(0, SPS * 3 + 10) == titles[:SPS * 3 + 10]


def test_sync_seal_mode_still_available(titles, artifact):
    store = MutableStringStore(artifact, None, strings_per_segment=SPS,
                               async_seal=False)
    store.extend(titles[:SPS * 2 + 5])
    # no barrier needed: seals happened inline during extend
    assert store.segments.n_segments == 2
    assert store.scan(0, SPS * 2 + 5) == titles[:SPS * 2 + 5]


def test_async_seal_flag_survives_save_open(titles, artifact, tmp_path):
    store = MutableStringStore(artifact, None, strings_per_segment=SPS,
                               async_seal=False)
    store.extend(titles[:100])
    d = str(tmp_path / "sync")
    store.save(d)
    assert MutableStringStore.open(d).async_seal is False


def test_save_during_pending_seal_waits_for_commit(titles, artifact,
                                                   tmp_path):
    store = MutableStringStore(artifact, None, strings_per_segment=SPS)
    store.extend(titles[:SPS * 2])
    d = str(tmp_path / "pend")
    store.save(d)                                 # joins the pending seal
    re = MutableStringStore.open(d)
    assert re.scan(0, SPS * 2) == titles[:SPS * 2]


def test_concurrent_readers_during_async_seals(titles, artifact):
    store = MutableStringStore(artifact, None, strings_per_segment=SPS)
    store.extend(titles[:50])
    errors = []

    def reader():
        try:
            for _ in range(200):
                n = store.n_strings
                got = store.multiget([0, n - 1])
                assert got[0] == titles[0]
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    for lo in range(50, SPS * 4, 50):
        store.extend(titles[lo:lo + 50])
    t.join()
    store.seal_barrier()
    assert not errors
    assert store.scan(0, SPS * 4) == titles[:SPS * 4]
