"""Per-kernel validation: Pallas (interpret) vs ref.py oracle vs Python
reference, swept over shapes/dtypes/corpora, plus hypothesis property tests
on the packing/compare primitives."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep: property tests skip, the rest run
    from _hypothesis_fallback import given, settings, st

from repro.core import make_onpair16
from repro.core.packed import hash_key as np_hash_key, split_u64
from repro.core.packing import pack_u64, shared_prefix_size
from repro.data.synth import load_dataset
from repro.kernels.ops import OnPairDevice
from repro.kernels.ref import ctz32, hash_key, shared_prefix_bytes


@pytest.fixture(scope="module")
def trained():
    strings = load_dataset("book_titles", 1 << 19)
    comp = make_onpair16(sample_bytes=1 << 19, seed=7)
    comp.train(strings)
    return strings, comp


@pytest.fixture(scope="module")
def device(trained):
    _, comp = trained
    return OnPairDevice(comp.dictionary)


# ------------------------------------------------------------- primitives
@given(st.integers(0, 2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_ctz32_matches_python(x):
    expected = 32 if x == 0 else (x & -x).bit_length() - 1
    assert int(ctz32(jnp.uint32(x))) == expected


@given(st.binary(min_size=0, max_size=8), st.binary(min_size=0, max_size=8))
@settings(max_examples=200, deadline=None)
def test_shared_prefix_jax_vs_python(a, b):
    va, vb = pack_u64(a, 0, len(a)), pack_u64(b, 0, len(b))
    expect = min(shared_prefix_size(va, vb), 8)
    lo_a, hi_a = split_u64(va)
    lo_b, hi_b = split_u64(vb)
    got = int(shared_prefix_bytes(jnp.uint32(lo_a), jnp.uint32(hi_a),
                                  jnp.uint32(lo_b), jnp.uint32(hi_b)))
    assert got == expect


@given(st.integers(0, 2**64 - 1), st.integers(1, 16))
@settings(max_examples=200, deadline=None)
def test_hash_jax_matches_numpy(v, length):
    lo, hi = split_u64(v)
    assert int(hash_key(jnp.uint32(lo), jnp.uint32(hi), jnp.int32(length))) \
        == np_hash_key(lo, hi, length)


# ------------------------------------------------------------ encode kernel
@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("batch_size", [1, 7, 32])
def test_encode_matches_python_lpm(trained, device, use_pallas, batch_size):
    strings, comp = trained
    batch = strings[:batch_size]
    enc = device.encode_to_bytes(batch, use_pallas=use_pallas)
    for s, e in zip(batch, enc):
        assert e == comp.compress_string(s)


def test_encode_pallas_equals_ref_on_edge_strings(device):
    edge = [b"", b"a", b"ab", b"abcdefgh", b"abcdefghi", b"x" * 100,
            bytes(range(256)), b"\x00" * 20, b"abracadabra abracadabra"]
    # empty strings can't be packed (0 tokens) — encoder emits n=0
    toks_p, n_p = device.encode_batch(edge, use_pallas=True)
    toks_r, n_r = device.encode_batch(edge, use_pallas=False)
    np.testing.assert_array_equal(n_p, n_r)
    for i in range(len(edge)):
        np.testing.assert_array_equal(toks_p[i, : n_p[i]], toks_r[i, : n_r[i]])


# ------------------------------------------------------------ decode kernels
@pytest.mark.parametrize("use_pallas", [True, False])
def test_decode_roundtrip(trained, device, use_pallas):
    strings, _ = trained
    batch = strings[10:60]
    assert device.roundtrip(batch, use_pallas=use_pallas) == batch


@pytest.mark.parametrize("tile", [256, 1024])
def test_decode_stream_vs_python(trained, device, tile):
    strings, comp = trained
    batch = strings[:200]
    corpus = comp.compress(batch)
    tokens = np.asarray(corpus.payload.view("<u2"), dtype=np.int32)
    got = device.decode_stream(tokens, use_pallas=True, tile=tile)
    assert got == b"".join(batch)


def test_decode_gather_rows_match_dictionary(trained, device):
    _, comp = trained
    d = comp.dictionary
    rng = np.random.default_rng(0)
    toks = rng.integers(0, d.num_entries, size=2048).astype(np.int32)
    from repro.kernels.onpair_decode import decode_gather
    rows, lens = decode_gather(jnp.asarray(toks), device.dd.mat16,
                               device.dd.lens, tile=512)
    rows, lens = np.asarray(rows), np.asarray(lens)
    np.testing.assert_array_equal(rows, d.mat16[toks].astype(np.int32))
    np.testing.assert_array_equal(lens, d.lens[toks].astype(np.int32))


# ------------------------------------------------- property: full roundtrip
@given(st.lists(st.binary(min_size=0, max_size=64), min_size=1, max_size=8))
@settings(max_examples=30, deadline=None)
def test_property_roundtrip_arbitrary_bytes(trained, a_batch):
    """compress . decompress == identity for ARBITRARY byte strings, even
    ones unlike the training distribution (single-byte seeds guarantee it)."""
    _, comp = trained
    dev = OnPairDevice(comp.dictionary)
    batch = [s for s in a_batch]
    toks, n = dev.encode_batch(batch, use_pallas=False,
                               max_tokens=max(1, max(map(len, batch), default=1)))
    out = dev.decode_batch(toks, n, max_out=max(1, max(map(len, batch), default=1)),
                           use_pallas=False)
    assert out == batch


# --------------------------------------------------------- dtype/shape sweep
@pytest.mark.parametrize("length", [1, 8, 9, 16, 17, 63, 128])
def test_encode_shape_sweep(device, trained, length):
    _, comp = trained
    rng = np.random.default_rng(length)
    s = bytes(rng.integers(32, 127, size=length).astype(np.uint8))
    enc = device.encode_to_bytes([s], use_pallas=True)[0]
    assert enc == comp.compress_string(s)
    out = device.roundtrip([s], use_pallas=True)
    assert out == [s]
