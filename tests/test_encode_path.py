"""Write-path encode tests.

Covers the batched frozen-dictionary parser (vectorised table walk vs the
per-string DynamicLPM oracle), pallas-vs-numpy byte identity through the
full mutable lifecycle (extend -> seal -> save -> open -> multiget), the
bounded compact-race retry, the non-token-stream refusal, client-side
group-commit, and the jit-retrace bound on the device encode path.

Importable without jax: device-path tests skip when OnPairDevice is None
(REPRO_NO_JAX or no jax install), everything else runs on numpy alone.
"""

import os

import numpy as np
import pytest

from repro.client import connect, wrap
from repro.client.session import _ExtendBatcher
from repro.core import registry
from repro.core.api import RawCompressor
from repro.core.codec import Encoder
from repro.core.lpm import parse_batch
from repro.data.synth import load_dataset
from repro.net import ShardServer
from repro.store.mutable import MutableStringStore, OnPairDevice

SAMPLE = 1 << 18

#: the shapes the paper's bound makes interesting: empty, single byte,
#: exactly one max-length entry, longer than any entry, every byte value
EDGE = [b"", b"a", b"x" * 16, b"y" * 40, bytes(range(256))]

needs_jax = pytest.mark.skipif(OnPairDevice is None,
                               reason="jax unavailable (or REPRO_NO_JAX)")


@pytest.fixture(scope="module")
def titles():
    return load_dataset("book_titles", SAMPLE)


@pytest.fixture(scope="module")
def artifact(titles):
    return registry.train("onpair16", titles, sample_bytes=SAMPLE, seed=3)


# --------------------------------------------------- vectorised batch parse
@pytest.mark.parametrize("codec", ["onpair16", "onpair"])
def test_parse_batch_matches_per_string_lpm(titles, codec):
    """The shared table walk is byte-identical to the greedy per-string
    parse — same tokens, same tie-breaks — for bounded AND unbounded
    dictionaries, on real data plus the edge shapes."""
    comp = registry.create(codec, sample_bytes=SAMPLE // 2)
    comp.train(titles)
    batch = titles[:512] + EDGE
    ref = [np.asarray(comp._parser().parse(s), dtype="<u2") for s in batch]
    payload, counts = parse_batch(comp.dictionary, batch)
    off = np.concatenate(([0], np.cumsum(counts)))
    for i in range(len(batch)):
        assert np.array_equal(payload[off[i]:off[i + 1]], ref[i]), \
            f"{codec}: mismatch at string {i}: {batch[i][:40]!r}"


def test_encoder_batch_equals_encode_one(artifact, titles):
    enc = Encoder(artifact)
    batch = titles[:64] + EDGE
    corpus = enc.encode(batch)
    assert corpus.n_strings == len(batch)
    for i, s in enumerate(batch):
        assert corpus.string_payload(i) == enc.encode_one(s)


# ----------------------------------------------------- constructor refusals
def test_mutable_refuses_non_token_stream():
    raw = RawCompressor()
    raw.train([b"abc"])
    with pytest.raises(ValueError, match="token-stream"):
        MutableStringStore(raw)


def test_mutable_refuses_unknown_encode_backend(artifact):
    with pytest.raises(ValueError, match="encode_backend"):
        MutableStringStore(artifact, encode_backend="cuda")


# ------------------------------------------------------ bounded retry loop
def test_extend_retry_is_bounded(artifact, titles):
    """A compact() landing between parse and ingest forces a re-parse; when
    every optimistic attempt loses, the final attempt encodes under the
    store lock — extend() terminates instead of livelocking."""
    store = MutableStringStore(artifact)
    real = store._encoder
    calls = {"n": 0}

    class Flapping:
        def encode(self, strings):
            calls["n"] += 1
            store.version_id += 1  # a compact swaps the generation mid-parse
            return real.encode(strings)

    store._encoder = Flapping()
    batch = titles[:8]
    ids = store.extend(batch)
    assert ids == list(range(8))
    assert calls["n"] == store._MAX_ENCODE_RETRIES + 1
    store._encoder = real
    assert store.multiget(ids) == batch


# -------------------------------------------------- client-side group-commit
def test_extend_batcher_fuses_pending_writes():
    """Writes submitted while one RPC is in flight drain as ONE
    backend.extend; the id block splits back per caller."""
    import threading
    from concurrent.futures import ThreadPoolExecutor

    gate = threading.Event()
    entered = threading.Event()

    class SlowBackend:
        def __init__(self):
            self.calls = []
            self.n = 0

        def extend(self, strings):
            self.calls.append(len(strings))
            if len(self.calls) == 1:
                entered.set()
                assert gate.wait(5.0)
            ids = list(range(self.n, self.n + len(strings)))
            self.n += len(strings)
            return ids

    backend = SlowBackend()
    pool = ThreadPoolExecutor(max_workers=1)
    batcher = _ExtendBatcher(backend, pool.submit)
    first = batcher.submit_extend([b"a"])
    assert entered.wait(5.0)  # first drain is on the wire, holding the gate
    pending = [batcher.submit_extend([b"b", b"c"]),
               batcher.submit_extend([b"d"])]
    gate.set()
    assert first.result(5.0) == [0]
    assert pending[0].result(5.0) == [1, 2]
    assert pending[1].result(5.0) == [3]
    pool.shutdown(wait=True)
    assert backend.calls == [1, 3]  # second drain fused both pending writes
    assert batcher.batches == 2 and batcher.coalesced == 2


def test_client_async_appends_group_commit(artifact, titles, tmp_path):
    """Pipelined append_async/extend_async through a tcp:// client fold into
    bulk extends server-side (service append_batches < appends)."""
    src = str(tmp_path / "src")
    MutableStringStore(artifact).save(src)
    with ShardServer.from_dir(src) as server:
        server.start()
        with connect(f"tcp://127.0.0.1:{server.port}") as client:
            futs = [client.append_async(s) for s in titles[:48]]
            futs.append(client.extend_async(titles[48:64]))
            ids = [f.result(10.0) for f in futs]
            flat = ids[:48] + list(ids[48])
            assert sorted(flat) == list(range(64))
            got = client.multiget(flat)
            assert got == titles[:64]
            stats = client.stats()
            assert stats["extend_batches"] >= 1
            svc = server.service.stats()
            assert svc["appends"] == 64
            assert svc["append_batches"] <= 49


# ------------------------------------------- pallas/numpy lifecycle identity
@needs_jax
@pytest.mark.parametrize("transport", ["inproc", "tcp"])
def test_pallas_numpy_lifecycle_identity(artifact, titles, tmp_path,
                                         transport):
    """encode_backend='pallas' and 'numpy' stores produce byte-identical
    corpora through extend -> seal -> save -> open -> multiget, through the
    in-process client and over tcp://."""
    batch = titles[:300] + EDGE
    results = {}
    for backend in ("numpy", "pallas"):
        d = str(tmp_path / backend)
        store = MutableStringStore(artifact, encode_backend=backend,
                                   strings_per_segment=128)
        if transport == "inproc":
            with wrap(store) as client:
                ids = client.extend(batch)
        else:
            stage = str(tmp_path / f"{backend}-srv")
            store.save(stage)
            with ShardServer.from_dir(
                    stage, encode_backend=backend) as server:
                server.start()
                with connect(f"tcp://127.0.0.1:{server.port}") as client:
                    ids = client.extend(batch)
                server.store.save(stage)
            store = MutableStringStore.open(stage)
        store.seal()
        store.save(d)
        reopened = MutableStringStore.open(d)
        assert reopened.encode_backend == backend
        assert reopened.multiget(ids) == batch
        # byte-level identity of the stored token streams, not just decodes
        results[backend] = [reopened.corpus.string_payload(i)
                            for i in range(reopened.corpus.n_strings)]
    assert results["numpy"] == results["pallas"]


@needs_jax
def test_device_encode_matches_numpy_corpus(artifact, titles):
    batch = titles[:200] + EDGE
    assert Encoder(artifact, backend="pallas").encode(batch).payload.tobytes() \
        == Encoder(artifact).encode(batch).payload.tobytes()


# ------------------------------------------------------- jit retrace bound
@needs_jax
def test_encode_trace_count_bounded(artifact, titles):
    """Mixed batch sizes and string lengths must not compile a trace per
    (B, L) pair: encode_bucketed pins every launch to a static bucket
    shape, so compiled-trace growth is bounded by the bucket set."""
    from repro.kernels.ref import encode_batch_ref_jit

    device = OnPairDevice(registry.codec_from_artifact(artifact).dictionary)
    before = encode_batch_ref_jit._cache_size()
    rng = np.random.default_rng(0)
    for trial in range(12):
        n = int(rng.integers(1, 90))
        batch = [titles[int(rng.integers(len(titles)))][: int(rng.integers(1, 300))]
                 for _ in range(n)]
        device.encode_bucketed(batch, use_pallas=False)
    added = encode_batch_ref_jit._cache_size() - before
    assert added <= len(device.encode_len_caps), \
        f"{added} traces for {len(device.encode_len_caps)} buckets"
    pb = device.encode_pad_batch
    allowed = {(pb, cap + 16) for cap in device.encode_len_caps}
    assert device.encode_shapes <= allowed, \
        f"unexpected launch shapes {device.encode_shapes - allowed}"


@needs_jax
def test_warm_encode_precompiles_buckets(artifact):
    from repro.kernels.ref import encode_batch_ref_jit

    device = OnPairDevice(registry.codec_from_artifact(artifact).dictionary)
    device.warm_encode(use_pallas=False)
    before = encode_batch_ref_jit._cache_size()
    device.encode_bucketed([b"abc", b"x" * 100, b"y" * 500],
                           use_pallas=False)
    assert encode_batch_ref_jit._cache_size() == before  # all warm


if __name__ == "__main__":
    raise SystemExit(os.system(f"pytest -x -q {__file__}"))
