"""repro.loadgen acceptance: deterministic schedules, drivers over real
backends, the server-histogram SLO gate (both verdicts), hedged-read
cancellation (proved by server-side op counters), replica autodiscovery
from the manifest, and the Prometheus scrape round-trip the open-loop
collector relies on."""

import os

import pytest

from repro.client import connect, format_tcp_url
from repro.data.synth import load_dataset
from repro.distributed import save_sharded
from repro.distributed.shard_store import manifest_replicas, record_replicas
from repro.loadgen import (
    SLO,
    WorkloadSpec,
    build_report,
    build_schedule,
    fraction_under,
    run_workload,
    snapshot_server_states,
)
from repro.net import ShardServer
from repro.obs import (
    Histogram,
    MetricsRegistry,
    hist_state_from_rows,
    parse_prometheus,
    render_prometheus,
)
from repro.store import CompressedStringStore

SAMPLE = 1 << 18


@pytest.fixture(scope="module")
def titles():
    return load_dataset("book_titles", SAMPLE)


@pytest.fixture(scope="module")
def corpus(titles, tmp_path_factory):
    """One flat store dir + one 2-shard sharded dir."""
    store = CompressedStringStore.build(
        titles, sample_bytes=SAMPLE, strings_per_segment=256
    )
    base = tmp_path_factory.mktemp("loadgen")
    flat = str(base / "flat")
    store.save(flat)
    sharded = str(base / "shards")
    save_sharded(store, sharded, 2)
    return {"flat": flat, "sharded": sharded}


# ------------------------------------------------------------------ schedule
class TestSchedule:
    def test_same_seed_same_spec_identical_schedule(self):
        spec = WorkloadSpec(
            mix={"get": 0.5, "multiget": 0.3, "scan": 0.2},
            loop="open",
            rate=500.0,
            seed=42,
        )
        a = build_schedule(spec, 10_000, 3000)
        b = build_schedule(spec, 10_000, 3000)
        assert a == b
        assert len(a) == 3000

    def test_different_seed_different_schedule(self):
        base = dict(mix={"get": 1.0}, seed=1)
        a = build_schedule(WorkloadSpec(**base), 10_000, 500)
        b = build_schedule(WorkloadSpec(**{**base, "seed": 2}), 10_000, 500)
        assert a != b

    def test_shapes_and_arrivals(self):
        spec = WorkloadSpec(
            mix={"get": 0.6, "multiget": 0.4},
            multiget_fanout=8,
            loop="open",
            rate=1000.0,
            seed=0,
        )
        sched = build_schedule(spec, 5000, 2000)
        kinds = {op.kind for op in sched}
        assert kinds == {"get", "multiget"}
        arrivals = [op.at_s for op in sched]
        assert arrivals == sorted(arrivals)  # Poisson schedule is cumulative
        for op in sched:
            if op.kind == "multiget":
                assert len(op.ids) == 8
            assert all(0 <= i < 5000 for i in op.ids)

    def test_closed_loop_arrivals_all_zero(self):
        sched = build_schedule(WorkloadSpec(mix={"get": 1.0}), 100, 64)
        assert all(op.at_s == 0.0 for op in sched)

    def test_spec_json_roundtrip(self):
        spec = WorkloadSpec(
            mix={"get": 1.0},
            loop="open",
            rate=250.0,
            seed=9,
            slo=SLO(p99_ms=5.0, min_goodput=0.9),
        )
        again = WorkloadSpec.from_json(spec.to_json())
        assert again == spec
        assert build_schedule(again, 1000, 100) == build_schedule(
            spec, 1000, 100
        )

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            WorkloadSpec(mix={"teleport": 1.0})
        with pytest.raises(ValueError):
            WorkloadSpec(loop="möbius")
        with pytest.raises(ValueError):
            WorkloadSpec(mix={"get": 0.0})


# ------------------------------------------------------------------- drivers
class TestDrivers:
    def test_closed_loop_over_sharded_backend(self, corpus, titles):
        spec = WorkloadSpec(
            mix={"get": 0.7, "multiget": 0.3}, concurrency=16, seed=3
        )
        with connect(f"shard://{corpus['sharded']}") as client:
            result = run_workload(client, spec, duration_s=0.5)
        assert result.loop == "closed"
        assert result.ops_ok > 0
        assert result.ops_failed == 0
        assert result.per_kind.get("get", 0) > 0
        assert sum(result.latency_state["counts"]) == result.ops_ok
        assert result.bytes_read > 0

    def test_open_loop_paces_to_rate(self, corpus):
        spec = WorkloadSpec(
            mix={"get": 1.0}, loop="open", rate=200.0, seed=5
        )
        with connect(f"shard://{corpus['sharded']}") as client:
            result = run_workload(client, spec, duration_s=1.0)
        assert result.loop == "open"
        assert result.ops_ok > 0
        # paced, not saturating: issue count tracks rate x duration, far
        # below what a closed loop would push through in a second
        assert result.ops_issued <= 2 * 200

    def test_writes_in_mix(self, corpus, tmp_path):
        spec = WorkloadSpec(
            mix={"get": 0.5, "append": 0.25, "extend": 0.25},
            concurrency=4,
            extend_batch=8,
            seed=11,
        )
        with connect(f"shard://{corpus['sharded']}", writable=True) as client:
            n0 = client.n_strings
            result = run_workload(client, spec, duration_s=0.3)
            assert result.ops_failed == 0
            assert client.n_strings > n0


# ------------------------------------------------------------------ SLO gate
class TestSLOGate:
    def _run(self, corpus, slo: SLO):
        spec = WorkloadSpec(mix={"get": 1.0}, concurrency=8, seed=2, slo=slo)
        # file:// runs the local micro-batching service, so the *server*
        # histogram (repro_service_request_latency_us) lives in-process
        with connect(f"file://{corpus['flat']}") as client:
            before = snapshot_server_states(client)
            result = run_workload(client, spec, duration_s=0.3)
            after = snapshot_server_states(client)
            return build_report(spec, result, before, after, client=client)

    def test_gate_passes_under_generous_slo(self, corpus):
        report = self._run(corpus, SLO(p99_ms=10_000.0))
        assert report["passed"] is True
        assert report["violations"] == []
        assert report["server_latency"]["count"] > 0
        assert report["goodput"]["fraction_under_slo"] == 1.0

    def test_gate_fails_under_impossible_slo(self, corpus):
        report = self._run(
            corpus, SLO(p99_ms=0.0001, min_goodput=1.0)
        )
        assert report["passed"] is False
        names = {v["slo"] for v in report["violations"]}
        assert "p99_ms" in names
        assert "min_goodput" in names
        for v in report["violations"]:
            assert "trace_excerpt" in v  # attached even when empty

    def test_fraction_under(self):
        state = {"bounds": [10.0, 100.0], "counts": [5, 5, 0], "sum": 300.0}
        assert fraction_under(state, 10.0) == 0.5
        assert fraction_under(state, 1000.0) == 1.0
        assert fraction_under(state, 5.0) == pytest.approx(0.25)
        assert fraction_under(None, 10.0) == 0.0


# --------------------------------------------------------------- hedged reads
class TestHedgedReads:
    @pytest.fixture()
    def replicated(self, titles, tmp_path):
        """2-shard in-thread cluster + a read-only replica on shard 0."""
        store = CompressedStringStore.build(
            titles[:1500], sample_bytes=SAMPLE, strings_per_segment=256
        )
        d = str(tmp_path / "shards")
        save_sharded(store, d, 2)
        servers = [
            ShardServer.from_dir(os.path.join(d, f"shard-{k:04d}")).start()
            for k in range(2)
        ]
        replica = ShardServer.from_dir(
            os.path.join(d, "shard-0000"), read_only=True
        ).start()
        client = connect(format_tcp_url([s.address for s in servers]))
        client.register_replica(0, replica.address)
        yield client, servers, replica
        client.close()
        for s in [*servers, replica]:
            s.close()

    @staticmethod
    def _reads(server) -> int:
        return sum(
            server.op_counts.get(op, 0) for op in ("get", "multiget")
        )

    def test_unfired_hedge_is_cancelled(self, replicated):
        """Primary answers first -> the timer is cancelled and the replica
        never sees a single read (server-side op counters)."""
        client, _servers, replica = replicated
        r0 = self._reads(replica)
        for i in range(20):
            assert client.get_hedged(i, hedge_ms=2000.0) == client.get(i)
        assert self._reads(replica) == r0
        assert client.stats()["hedges"] == 0

    def test_fired_hedge_loser_cancelled(self, replicated):
        """hedge_ms=0 fires the second attempt on every read: both sides
        serve some traffic, every result is correct, and the op counters
        bound total server work at <= 2 per request — the losing attempt
        either completes or is cancelled, it is never retried/duplicated."""
        client, servers, replica = replicated
        n = 40
        p0 = self._reads(servers[0])
        r0 = self._reads(replica)
        expected = client.multiget(list(range(n)))
        base_stats = client.stats()
        for i in range(n):
            assert (
                client.get_hedged(i, hedge_ms=0.0, hedge_preference="replica")
                == expected[i]
            )
        stats = client.stats()
        assert stats["hedges"] - base_stats["hedges"] == n
        served_p = self._reads(servers[0]) - p0
        served_r = self._reads(replica) - r0
        # every request reached at least one server, no attempt duplicated
        # past the budget, and the hedge target actually saw traffic
        assert served_r >= 1
        assert n <= served_p + served_r <= 2 * n + len(expected)

    def test_hedge_budget_retries_failures(self, replicated):
        """budget > 1 also acts as a retry budget: an id out of range fails
        every attempt and surfaces the error (not a hang)."""
        client, _servers, _replica = replicated
        with pytest.raises(Exception):
            client.get_hedged(10**9, hedge_ms=0.0, budget=2, timeout=5.0)


# -------------------------------------------------------- replica discovery
class TestReplicaAutodiscovery:
    def test_connect_registers_manifest_replicas(self, titles, tmp_path):
        store = CompressedStringStore.build(
            titles[:1500], sample_bytes=SAMPLE, strings_per_segment=256
        )
        d = str(tmp_path / "shards")
        save_sharded(store, d, 2)
        servers = [
            ShardServer.from_dir(os.path.join(d, f"shard-{k:04d}")).start()
            for k in range(2)
        ]
        replica = ShardServer.from_dir(
            os.path.join(d, "shard-0001"), read_only=True
        ).start()
        # record one live replica and one dead address: discovery must
        # register the live one and shrug off the dead one
        record_replicas(d, {1: [replica.address, ("127.0.0.1", 1)]})
        assert manifest_replicas(d)[1][0] == replica.address
        client = None
        try:
            client = connect(
                format_tcp_url([s.address for s in servers]), dir_path=d
            )
            r0 = replica.op_counts.get("multiget", 0)
            # ids from shard 1's range — the shard the replica covers
            lo = client.backend.bounds[1][0]
            client.multiget([lo, lo + 1, lo + 2], read_preference="replica")
            assert replica.op_counts.get("multiget", 0) > r0
        finally:
            if client is not None:
                client.close()
            for s in [*servers, replica]:
                s.close()

    def test_auto_replicas_off_by_flag(self, titles, tmp_path):
        store = CompressedStringStore.build(
            titles[:800], sample_bytes=SAMPLE, strings_per_segment=256
        )
        d = str(tmp_path / "shards")
        save_sharded(store, d, 1)
        server = ShardServer.from_dir(os.path.join(d, "shard-0000")).start()
        replica = ShardServer.from_dir(
            os.path.join(d, "shard-0000"), read_only=True
        ).start()
        record_replicas(d, {0: [replica.address]})
        try:
            with connect(
                format_tcp_url([server.address]),
                dir_path=d,
                auto_replicas=False,
            ) as client:
                r0 = replica.op_counts.get("multiget", 0)
                client.multiget([1, 2], read_preference="any")
                client.multiget([1, 2], read_preference="any")
                assert replica.op_counts.get("multiget", 0) == r0
        finally:
            server.close()
            replica.close()


# ------------------------------------------------------------- get batching
class TestGetBatcher:
    def test_concurrent_gets_coalesce_into_multiget(self, corpus, titles):
        servers = [
            ShardServer.from_dir(
                os.path.join(corpus["sharded"], f"shard-{k:04d}")
            ).start()
            for k in range(2)
        ]
        try:
            with connect(
                format_tcp_url([s.address for s in servers])
            ) as client:
                gets_before = sum(
                    s.op_counts.get("get", 0) for s in servers
                )
                futs = [client.get_async(i) for i in range(200)]
                vals = [f.result(timeout=30) for f in futs]
                assert vals == titles[:200]
                stats = client.stats()
                assert stats["coalesced_gets"] > 0
                assert stats["get_batches"] < 200
                # point reads traveled as multiget RPCs, not per-get calls
                gets_after = sum(s.op_counts.get("get", 0) for s in servers)
                assert gets_after == gets_before
        finally:
            for s in servers:
                s.close()


# ------------------------------------------------------- scrape round-trip
class TestScrapeRoundTrip:
    def test_prometheus_text_rebuilds_exact_hist_state(self):
        reg = MetricsRegistry()
        hist = reg.register(Histogram("rt_latency_us", {"shard": "0"}))
        for v in (3.0, 42.0, 9001.0, 1e7):
            hist.record(v)
        reg.register(Histogram("rt_latency_us", {"shard": "1"})).record(5.0)
        rows = parse_prometheus(render_prometheus(reg))
        state = hist_state_from_rows(rows, "rt_latency_us", {"shard": "0"})
        assert state == hist.state()
        other = hist_state_from_rows(rows, "rt_latency_us", {"shard": "1"})
        assert sum(other["counts"]) == 1
