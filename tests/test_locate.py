"""Reverse lookup (`locate`) and prefix enumeration (`scan_prefix`).

The queryable-dictionary surface end to end: locate as the inverse of get
(property-based where hypothesis is installed), miss/None semantics,
prefix-scan ordering + limit + pagination across segment boundaries,
mutable-tail visibility before/after seal and through a live compact(),
index persistence through save/open, byte-identity of the sharded and tcp
deployments against the in-process answers, and capability fallback
against servers that predate OP_LOCATE/OP_SCAN_PREFIX.

Everything here is stdlib + numpy (the RPC tier stays covered on jax-less
hosts); spawned servers run in-process threads via ShardServer.start().
"""

import os

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dep: property tests skip, the rest run
    from _hypothesis_fallback import given, settings, st
    HAVE_HYPOTHESIS = False

from repro.client import connect, wrap
from repro.data.synth import load_dataset
from repro.distributed import ShardedStringStore, save_sharded
from repro.net import DistributedStringStore, ShardServer
from repro.net import protocol as P
from repro.store import CompressedStringStore, MutableStringStore

SAMPLE = 1 << 16


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)[:1200]
    strings[3] = b""
    strings[7] = b"\x00\xff" * 9
    strings[11] = strings[5]  # a duplicate: locate must return id 5
    return strings


@pytest.fixture(scope="module")
def store(titles):
    # small segments so queries cross many segment boundaries
    return CompressedStringStore.build(
        titles, sample_bytes=SAMPLE, strings_per_segment=128)


@pytest.fixture(scope="module")
def first_index(titles):
    first: dict[bytes, int] = {}
    for i, s in enumerate(titles):
        first.setdefault(s, i)
    return first


# ----------------------------------------------------------- exact semantics
def test_locate_is_inverse_of_get(store, titles, first_index):
    for i in (0, 3, 7, 5, 11, 127, 128, 600, len(titles) - 1):
        assert store.locate(titles[i]) == first_index[titles[i]]


def test_locate_miss_returns_none(store, titles):
    assert store.locate(b"@@definitely-absent@@") is None
    assert store.locate(titles[0] + b"\x00") is None
    assert store.locate(titles[42][:-1] + b"\xfe") is None


def test_locate_batch_mixed_hits_and_misses(store, titles, first_index):
    queries = [titles[9], b"@@absent@@", titles[400], titles[11]]
    assert store.locate_batch(queries) == [
        first_index[titles[9]], None, first_index[titles[400]], 5]


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_locate_inverse_property(store, titles, first_index, data):
    i = data.draw(st.integers(0, len(titles) - 1))
    assert store.locate(titles[i]) == first_index[titles[i]]


@settings(max_examples=30, deadline=None)
@given(st.binary(max_size=40))
def test_locate_arbitrary_bytes_never_wrong(store, first_index, s):
    got = store.locate(s)
    if s in first_index:
        assert got == first_index[s]
    else:
        assert got is None


# --------------------------------------------------------------- prefix scan
def _expected_prefix(titles, prefix):
    return sorted((s, i) for i, s in enumerate(titles) if s.startswith(prefix))


def test_scan_prefix_ordering_across_segments(store, titles):
    prefix = b"The "  # common: hits in many 128-string segments
    expected = _expected_prefix(titles, prefix)
    assert len(expected) > 10
    hits = store.scan_prefix(prefix, limit=None)
    assert [(s, g) for g, s in hits] == expected


def test_scan_prefix_limit_and_pagination(store, titles):
    prefix = b"The "
    expected = _expected_prefix(titles, prefix)
    page1 = store.scan_prefix(prefix, limit=7)
    assert [(s, g) for g, s in page1] == expected[:7]
    g_last, s_last = page1[-1]
    page2 = store.scan_prefix(prefix, limit=7, after=(s_last, g_last))
    assert [(s, g) for g, s in page2] == expected[7:14]


def test_scan_prefix_no_match(store):
    assert store.scan_prefix(b"\xfe\xfd\xfc", limit=10) == []


# ------------------------------------------------------ mutable tail + compact
def test_mutable_tail_locate_before_and_after_seal(store, titles):
    m = MutableStringStore(store.artifact, store.corpus,
                           strings_per_segment=128)
    n0 = len(m)
    new = [b"tail-string-%d" % k for k in range(20)]
    ids = m.extend(new)
    # visible the moment extend returns (still in the unsealed tail)
    for s, i in zip(new, ids):
        assert m.locate(s) == i
        assert m.get(i) == s
    # force the tail through a seal and re-check
    filler = [b"filler-%d" % k for k in range(150)]
    m.extend(filler)
    assert m.locate(new[0]) == ids[0]
    assert m.locate(filler[-1]) == n0 + 20 + len(filler) - 1
    hits = m.scan_prefix(b"tail-string-1", limit=None)
    assert [s for _g, s in hits] == sorted(
        s for s in new if s.startswith(b"tail-string-1"))


def test_locate_through_live_compact(store, titles, first_index):
    m = MutableStringStore(store.artifact, store.corpus,
                           strings_per_segment=128)
    appended = [b"compact-me-%d" % k for k in range(40)]
    ids = m.extend(appended)
    m.compact()  # new dictionary generation: indexes must rebuild
    for i in (0, 5, 11, 700):
        assert m.locate(titles[i]) == first_index[titles[i]]
    for s, i in zip(appended, ids):
        assert m.locate(s) == i
    # post-compact appends are locatable against the new dictionary
    j = m.append(b"born-after-compact")
    assert m.locate(b"born-after-compact") == j
    assert m.locate(b"@@still-absent@@") is None


# ----------------------------------------------------------- index persistence
def test_index_persists_through_save_open(store, titles, first_index,
                                          tmp_path):
    d = str(tmp_path / "flat")
    store.locate(titles[0])  # force index construction so save persists it
    store.save(d)
    assert os.path.exists(os.path.join(d, "index.npz"))
    reopened = CompressedStringStore.open(d)
    assert reopened._seg_indexes, "persisted index should preload on open"
    assert reopened.locate(titles[321]) == first_index[titles[321]]
    assert reopened.locate(b"@@absent@@") is None


def test_missing_index_file_rebuilds_lazily(store, titles, first_index,
                                            tmp_path):
    d = str(tmp_path / "flat2")
    store.save(d)
    idx_path = os.path.join(d, "index.npz")
    if os.path.exists(idx_path):
        os.remove(idx_path)
    reopened = CompressedStringStore.open(d)
    assert reopened.locate(titles[100]) == first_index[titles[100]]


def test_mutable_save_open_roundtrip(store, titles, first_index, tmp_path):
    d = str(tmp_path / "mut")
    m = MutableStringStore(store.artifact, store.corpus,
                           strings_per_segment=128)
    m.extend([b"persist-me-%d" % k for k in range(10)])
    m.locate(b"persist-me-0")  # build indexes so save writes the sidecar
    m.save(d)
    reopened = MutableStringStore.open(d)
    assert reopened.locate(b"persist-me-7") == len(titles) + 7
    assert reopened.locate(titles[50]) == first_index[titles[50]]


# ------------------------------------------------- sharded + tcp byte-identity
@pytest.fixture(scope="module")
def sharded_dir(store, tmp_path_factory):
    d = str(tmp_path_factory.mktemp("locate") / "shards")
    save_sharded(store, d, 3)
    return d


@pytest.fixture(scope="module")
def probe(titles):
    return [titles[0], titles[11], titles[500], titles[1199], b"@@absent@@"]


def test_sharded_matches_flat(store, sharded_dir, titles, probe):
    sharded = ShardedStringStore.open(sharded_dir)
    assert sharded.locate_batch(probe) == store.locate_batch(probe)
    prefix = b"The "
    assert (sharded.scan_prefix(prefix, limit=None)
            == store.scan_prefix(prefix, limit=None))
    assert sharded.scan_prefix(prefix, limit=5) == store.scan_prefix(
        prefix, limit=5)


def test_tcp_matches_in_process(store, sharded_dir, probe):
    servers = [
        ShardServer.from_dir(
            os.path.join(sharded_dir, f"shard-{k:04d}")).start()
        for k in range(3)
    ]
    try:
        dist = DistributedStringStore.connect(
            [s.address for s in servers], dir_path=sharded_dir)
        try:
            assert all(c.supports_locate for c in dist.clients)
            assert dist.locate_batch(probe) == store.locate_batch(probe)
            prefix = b"The "
            assert (dist.scan_prefix(prefix, limit=None)
                    == store.scan_prefix(prefix, limit=None))
            page1 = dist.scan_prefix(prefix, limit=4)
            assert page1 == store.scan_prefix(prefix, limit=4)
            g, s = page1[-1]
            assert (dist.scan_prefix(prefix, limit=4, after=(s, g))
                    == store.scan_prefix(prefix, limit=4, after=(s, g)))
        finally:
            dist.close()
    finally:
        for srv in servers:
            srv.close()


class _PreLocateServer(ShardServer):
    """A server image predating OP_LOCATE: echoes the capability probe and
    rejects the new ops, like any old peer would."""

    def dispatch(self, kind, payload):
        if kind == P.OP_PING and payload == P.CAPS_PROBE:
            return payload
        if kind in (P.OP_LOCATE, P.OP_SCAN_PREFIX):
            raise P.ProtocolError(f"unknown op 0x{kind:02X}")
        return super().dispatch(kind, payload)


def test_old_server_capability_fallback(store, sharded_dir, probe):
    servers = [
        _PreLocateServer.from_dir(
            os.path.join(sharded_dir, f"shard-{k:04d}")).start()
        for k in range(3)
    ]
    try:
        dist = DistributedStringStore.connect(
            [s.address for s in servers], dir_path=sharded_dir)
        try:
            assert not any(c.supports_locate for c in dist.clients)
            # scan-side fallback: identical answers, no new ops on the wire
            assert dist.locate_batch(probe) == store.locate_batch(probe)
            prefix = b"The "
            assert (dist.scan_prefix(prefix, limit=6)
                    == store.scan_prefix(prefix, limit=6))
        finally:
            dist.close()
    finally:
        for srv in servers:
            srv.close()


# -------------------------------------------------------------- client surface
def test_client_locate_over_every_backend(store, sharded_dir, titles,
                                          first_index, probe, tmp_path):
    flat = str(tmp_path / "client-flat")
    store.save(flat)
    want = store.locate_batch(probe)
    prefix_hits = store.scan_prefix(b"The ", limit=9)

    def check(client):
        with client:
            assert client.locate(titles[11]) == 5
            assert client.locate(b"@@absent@@") is None
            assert client.locate_batch(probe) == want
            assert client.locate_batch(probe, timeout=30.0) == want
            assert client.locate_async(titles[500]).result(30) == \
                first_index[titles[500]]
            assert client.scan_prefix(b"The ", limit=9) == prefix_hits
            assert list(client.scan_prefix_iter(b"The ", chunk=4))[:9] == \
                prefix_hits
            ops = client.stats()["ops"]
            assert ops.get("locate", 0) >= 3

    check(connect(f"file://{flat}"))
    check(connect(f"shard://{sharded_dir}"))
    servers = [
        ShardServer.from_dir(
            os.path.join(sharded_dir, f"shard-{k:04d}")).start()
        for k in range(3)
    ]
    try:
        dist = DistributedStringStore.connect(
            [s.address for s in servers], dir_path=sharded_dir)
        check(wrap(dist))
        dist.close()
    finally:
        for srv in servers:
            srv.close()


def test_locate_stats_counters(store, titles):
    before = store.stats_snapshot()
    store.locate_batch([titles[1], b"@@absent@@"])
    store.scan_prefix(b"The ", limit=3)
    after = store.stats_snapshot()
    assert after["locates"] - before["locates"] == 2
    assert after["locate_hits"] - before["locate_hits"] == 1
    assert after["prefix_scans"] - before["prefix_scans"] == 1
