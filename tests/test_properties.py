"""Property-based round-trip suite for every registered codec.

Arbitrary byte strings — empty, 1-byte, >16-byte, high-byte, UTF-8
fragments — must round-trip through each codec's train→encode→decode and
through the stateless ``Encoder``/``Decoder`` API; numpy and pallas
backends must agree wherever the registry says ``device_decodable``; and
the writable store must return appended strings byte-identically.

Runs under hypothesis when installed; without it the ``@given`` tests skip
(via ``_hypothesis_fallback``) while the concrete edge-case tests below
still execute, so the numpy-only minimal-deps CI job keeps covering the
same codecs with a fixed adversarial corpus.
"""

from functools import lru_cache

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dep: property tests skip, the rest run
    from _hypothesis_fallback import given, settings, st
    HAVE_HYPOTHESIS = False

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except Exception:
    HAVE_JAX = False

from repro.core import registry
from repro.core.codec import Decoder, Encoder
from repro.data.synth import load_dataset
from repro.store import MutableStringStore

SAMPLE = 1 << 16  # small training corpus keeps per-example rebuilds cheap

#: fixed adversarial strings: empty, 1-byte, >16-byte (longer than any
#: bounded dictionary entry), high bytes, UTF-8 + truncated UTF-8 fragments
EDGE_CASES = [
    b"",
    b"\x00",
    b"\xff",
    b"a",
    bytes(range(256)),
    "héllo wörld".encode("utf-8"),
    "日本語のテキスト".encode("utf-8"),
    "héllo".encode("utf-8")[:3],      # truncated multi-byte sequence
    b"\xf0\x9f\x92",                   # dangling emoji prefix
    b"x" * 17,
    b"ab" * 100,
    b"\x00" * 33,
    b"\xfe\xff" * 21,
]

if HAVE_HYPOTHESIS:
    ARBITRARY = st.one_of(
        st.binary(min_size=0, max_size=48),
        st.binary(min_size=17, max_size=160),            # > 16-byte entries
        st.text(max_size=40).map(lambda t: t.encode()),  # valid UTF-8
        st.sampled_from(EDGE_CASES),
    )
    BATCH = st.lists(ARBITRARY, min_size=0, max_size=8)
else:  # fallback: strategies are never drawn, placeholders suffice
    ARBITRARY = BATCH = None


@lru_cache(maxsize=None)
def _artifact(name: str):
    corpus = load_dataset("book_titles", SAMPLE)
    if registry.capabilities(name).trainable:
        return registry.train(name, corpus, sample_bytes=SAMPLE)
    return registry.create(name).to_artifact()


@lru_cache(maxsize=None)
def _coders(name: str):
    art = _artifact(name)
    return Encoder(art), Decoder(art)


@lru_cache(maxsize=None)
def _pallas_decoder(name: str):
    return Decoder(_artifact(name), backend="pallas")


def _check_roundtrip(name: str, strings: list) -> None:
    enc, dec = _coders(name)
    corpus = enc.encode(strings)
    if "str_block" not in corpus.meta:  # block layouts index blocks, not strings
        assert corpus.n_strings == len(strings)
    assert dec.decode_all(corpus) == b"".join(strings), name
    for i, s in enumerate(strings):
        assert dec.access(corpus, i) == s, (name, i)


# ---------------------------------------------------------------- properties
@given(strings=BATCH)
@settings(max_examples=25, deadline=None)
def test_roundtrip_every_codec(strings):
    for name in registry.names():
        _check_roundtrip(name, strings)


@given(s=ARBITRARY)
@settings(max_examples=50, deadline=None)
def test_encode_one_and_access(s):
    """Encoder.encode_one emits exactly the per-string payload, and that
    payload decodes alone through the frozen dictionary (token codecs)."""
    for name in registry.names():
        enc, dec = _coders(name)
        corpus = enc.encode([b"padding", s, b"more padding"])
        assert dec.access(corpus, 1) == s, name
        if registry.capabilities(name).token_stream:
            payload = enc.encode_one(s)
            assert payload == corpus.string_payload(1), name
            toks = np.frombuffer(payload, dtype="<u2").astype(np.int64)
            assert dec.dictionary.decode_tokens(toks) == s, name


@pytest.mark.skipif(not HAVE_JAX, reason="jax unavailable")
@given(strings=BATCH)
@settings(max_examples=10, deadline=None)
def test_numpy_pallas_backend_equivalence(strings):
    for name in registry.names():
        if not registry.capabilities(name).device_decodable:
            continue
        enc, host = _coders(name)
        dev = _pallas_decoder(name)
        corpus = enc.encode(strings)
        ids = list(range(len(strings)))
        assert dev.multiget(corpus, ids) == host.multiget(corpus, ids), name
        assert dev.decode_all(corpus) == host.decode_all(corpus), name


@given(strings=BATCH)
@settings(max_examples=10, deadline=None)
def test_mutable_store_append_roundtrip(strings):
    """Appending arbitrary strings against a frozen dictionary and reading
    them back through every store path is the identity."""
    store = MutableStringStore(_artifact("onpair16"),
                               strings_per_segment=4, cache_bytes=0,
                               backend="numpy")
    ids = store.extend(strings)
    assert ids == list(range(len(strings)))
    assert store.multiget(ids) == strings
    assert store.scan(0, len(strings)) == strings


# ------------------------------------------- concrete edge-case regressions
# (run everywhere, including the numpy-only job without hypothesis)
@pytest.mark.parametrize("name", registry.names())
def test_edge_cases_roundtrip(name):
    _check_roundtrip(name, EDGE_CASES)


@pytest.mark.parametrize("name", registry.names())
def test_empty_corpus_roundtrip(name):
    _check_roundtrip(name, [])
    _check_roundtrip(name, [b"", b"", b""])


def test_edge_cases_through_mutable_store():
    store = MutableStringStore(_artifact("onpair16"),
                               strings_per_segment=4, cache_bytes=0)
    ids = store.extend(EDGE_CASES)
    assert store.multiget(ids) == EDGE_CASES
    assert store.scan(0, len(EDGE_CASES)) == EDGE_CASES


@pytest.mark.skipif(not HAVE_JAX, reason="jax unavailable")
def test_edge_cases_backend_equivalence():
    for name in registry.names():
        if not registry.capabilities(name).device_decodable:
            continue
        enc, host = _coders(name)
        dev = _pallas_decoder(name)
        corpus = enc.encode(EDGE_CASES)
        ids = list(range(len(EDGE_CASES)))
        assert dev.multiget(corpus, ids) == host.multiget(corpus, ids), name
