"""Paper-semantics tests for the core OnPair/OnPair16 implementation:
invariants from §3 (dictionary bounds, threshold law, LPM behaviour,
decode layouts) + roundtrip properties for every compressor."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dev dep: property tests skip, the rest run
    from _hypothesis_fallback import given, settings, st

from repro.core import (BPECompressor, FSSTCompressor, OnPairConfig,
                        PackedDictionary, auto_threshold, make_onpair,
                        make_onpair16, registry, train_dictionary)
from repro.core.lpm import DynamicLPM
from repro.core.packing import (is_prefix_packed, pack_u64,
                                shared_prefix_size, unpack_u64)
from repro.data.synth import load_dataset


@pytest.fixture(scope="module")
def titles():
    return load_dataset("book_titles", 1 << 19)


# ------------------------------------------------------------------ packing
@given(st.binary(min_size=1, max_size=8))
@settings(max_examples=200, deadline=None)
def test_pack_unpack_roundtrip(b):
    assert unpack_u64(pack_u64(b, 0, len(b)), len(b)) == b


@given(st.binary(min_size=0, max_size=8), st.binary(min_size=0, max_size=8))
@settings(max_examples=200, deadline=None)
def test_shared_prefix_matches_string_compare(a, b):
    va, vb = pack_u64(a, 0, len(a)), pack_u64(b, 0, len(b))
    got = shared_prefix_size(va, vb)
    true_shared = 0
    for x, y in zip(a, b):
        if x != y:
            break
        true_shared += 1
    # packed compare can only over-report past the shorter string's end
    # (zero padding); Algorithm 2's length check covers that.
    assert got >= min(true_shared, 8)
    if true_shared < min(len(a), len(b)):
        assert got == true_shared


@given(st.binary(min_size=1, max_size=8), st.binary(min_size=1, max_size=8))
@settings(max_examples=200, deadline=None)
def test_is_prefix_packed_semantics(s, p):
    got = is_prefix_packed(pack_u64(s, 0, len(s)), len(s),
                           pack_u64(p, 0, len(p)), len(p))
    assert got == s.startswith(p)


# ---------------------------------------------------------------- threshold
def test_auto_threshold_law():
    # threshold = max(2, floor(log2(S_MiB)))  (§3.2.1)
    assert auto_threshold(1 << 19) == 2          # 0.5 MiB
    assert auto_threshold(4 << 20) == 2          # 4 MiB
    assert auto_threshold(220 << 20) == 7        # Book Titles, 220 MiB
    assert auto_threshold(1846 << 20) == 10      # URLs, 1.8 GiB


# ------------------------------------------------------------------ LPM
def test_lpm_greedy_longest_match(titles):
    lpm = DynamicLPM()
    for tid, e in enumerate([bytes([b]) for b in range(256)]):
        lpm.insert(e, tid)
    lpm.insert(b"abcd", 300)
    lpm.insert(b"abcdefghij", 301)   # long pattern (> 8 bytes)
    lpm.insert(b"abcdefgh", 302)
    tid, L = lpm.search(b"abcdefghijklm", 0)
    assert (tid, L) == (301, 10)     # longest wins (long tier)
    tid, L = lpm.search(b"abcdefgX", 0)
    assert (tid, L) == (300, 4)      # falls back through short tier
    tid, L = lpm.search(b"zzz", 0)
    assert (tid, L) == (ord("z"), 1)  # single byte guaranteed


def test_bucket_descending_order(titles):
    lpm = DynamicLPM()
    lpm.insert(b"prefix12" + b"a" * 3, 1)
    lpm.insert(b"prefix12" + b"a" * 6, 2)
    lpm.insert(b"prefix12" + b"a" * 1, 3)
    bucket = lpm.long_buckets[pack_u64(b"prefix12", 0, 8)]
    lens = [len(s) for s, _ in bucket]
    assert lens == sorted(lens, reverse=True)


# ----------------------------------------------------------- training phase
def test_dictionary_bounds_onpair16(titles):
    cfg = OnPairConfig.onpair16(sample_bytes=1 << 19)
    res = train_dictionary(titles, cfg)
    assert len(res.entries) <= 65536
    assert all(len(e) <= 16 for e in res.entries)          # 16-byte bound
    d = PackedDictionary.build(res.entries)
    assert d.max_bucket_size <= 128                         # bucket bound
    assert d.total_bytes <= (1 << 20) + (1 << 18)           # <= 1.25 MiB
    assert res.entries[:256] == [bytes([b]) for b in range(256)]


def test_dict_grows_more_with_lower_threshold(titles):
    low = train_dictionary(titles, OnPairConfig.onpair16(
        threshold=2, sample_bytes=1 << 18))
    high = train_dictionary(titles, OnPairConfig.onpair16(
        threshold=12, sample_bytes=1 << 18))
    assert len(low.entries) > len(high.entries)             # Fig. 2 behaviour


def test_training_deterministic(titles):
    a = train_dictionary(titles, OnPairConfig.onpair16(seed=5, sample_bytes=1 << 18))
    b = train_dictionary(titles, OnPairConfig.onpair16(seed=5, sample_bytes=1 << 18))
    assert a.entries == b.entries


# ------------------------------------------------------------ roundtrips
@pytest.mark.parametrize("name", ["raw", "zlib-block", "zstd-block", "fsst",
                                  "onpair", "onpair16"])
def test_roundtrip_all_compressors(titles, name):
    if name == "zstd-block":
        pytest.importorskip("zstandard")
    strings = titles[:4000]
    c = registry.create(name)
    c.train(strings, sum(map(len, strings)))
    corpus = c.compress(strings)
    assert c.decompress_all(corpus) == b"".join(strings)
    rng = np.random.default_rng(0)
    for i in rng.integers(0, len(strings), 25):
        assert c.access(corpus, int(i)) == strings[int(i)]


def test_bpe_roundtrip_small(titles):
    strings = titles[:1500]
    c = BPECompressor(sample_bytes=1 << 17)
    c.train(strings)
    corpus = c.compress(strings)
    assert c.decompress_all(corpus) == b"".join(strings)
    assert c.access(corpus, 3) == strings[3]


@given(st.lists(st.binary(min_size=0, max_size=100), min_size=1, max_size=20))
@settings(max_examples=25, deadline=None)
def test_property_onpair16_roundtrip_arbitrary(strings):
    c = make_onpair16(sample_bytes=1 << 16)
    c.train(strings or [b"x"])
    corpus = c.compress(strings)
    assert c.decompress_all(corpus) == b"".join(strings)
    for i in range(len(strings)):
        assert c.access(corpus, i) == strings[i]


@given(st.lists(st.binary(min_size=0, max_size=80), min_size=1, max_size=10))
@settings(max_examples=20, deadline=None)
def test_property_fsst_roundtrip_arbitrary(strings):
    c = FSSTCompressor(sample_bytes=1 << 14)
    c.train(strings)
    corpus = c.compress(strings)
    assert c.decompress_all(corpus) == b"".join(strings)


# ----------------------------------------------------------- decode layout
def test_decode_tokens_matches_entries(titles):
    c = make_onpair16(sample_bytes=1 << 18)
    c.train(titles)
    d = c.dictionary
    rng = np.random.default_rng(1)
    toks = rng.integers(0, d.num_entries, 500)
    expect = b"".join(d.entries[t] for t in toks)
    assert d.decode_tokens(toks) == expect


def test_offsets_encode_lengths(titles):
    c = make_onpair(sample_bytes=1 << 18)
    c.train(titles)
    d = c.dictionary
    # Figure 7: entry i lives at blob[offsets[i]:offsets[i+1]]
    for tid in [0, 17, 256, d.num_entries - 1]:
        o0, o1 = int(d.offsets[tid]), int(d.offsets[tid + 1])
        assert bytes(d.blob[o0:o1]) == d.entries[tid]


def test_paper_claim_ratio_ordering(titles):
    """Core claim (Table 3): OnPair ratio > OnPair16 ratio >> FSST ratio."""
    strings = titles
    rs = {}
    for name in ("onpair", "onpair16", "fsst"):
        c = registry.create(name)
        c.train(strings, sum(map(len, strings)))
        rs[name] = c.compress(strings[:3000]).ratio
    assert rs["onpair"] >= rs["onpair16"] * 0.98
    assert rs["onpair16"] > rs["fsst"] * 1.1


# ------------------------------------------------------- deprecated shim
def test_back_compat_shim_warns_and_still_works():
    """ALL_COMPRESSORS / StringCompressor survive as a deprecated facade
    over the registry: accessing them warns, using them still works (the
    removal horizon is documented in README 'Deprecations')."""
    import repro.core as core

    with pytest.warns(DeprecationWarning, match="registry"):
        all_compressors = core.ALL_COMPRESSORS
    assert set(all_compressors) == {
        "raw", "zlib-block", "zstd-block", "lz-block", "bpe", "fsst",
        "onpair", "onpair16"}
    c = all_compressors["onpair16"]()
    c.train([b"shim", b"still", b"works"])
    assert c.access(c.compress([b"shim"]), 0) == b"shim"

    with pytest.warns(DeprecationWarning, match="repro.core.api"):
        from repro.core import StringCompressor
    from repro.core.api import StringCompressor as canonical
    assert StringCompressor is canonical  # the shim aliases, not forks
