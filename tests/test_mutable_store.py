"""Writable-store tests: frozen-dictionary append (tail + sealing), tail-aware
scan/stats, save→open round-trips of unsealed tails, drift-triggered
compaction byte-identity, cache invalidation, service read/append
interleaving, and sharded append/compact routing. Everything runs on a
numpy-only host; the jax path is exercised implicitly when available."""

import os
import threading

import numpy as np
import pytest

from repro.core import registry
from repro.core.codec import Encoder
from repro.data.synth import load_dataset
from repro.distributed import ShardedStringStore, save_sharded
from repro.store import (CompressedStringStore, DriftMonitor,
                         MutableStringStore, StoreService)
from repro.store.drift import segment_ratio, segment_report

SAMPLE = 1 << 18
SPS = 256  # small segments so appends cross seal boundaries quickly


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""
    strings[7] = b"\x00\xff" * 9
    return strings


@pytest.fixture(scope="module")
def artifact(titles):
    return registry.train("onpair16", titles, sample_bytes=SAMPLE)


def _junk(n: int, length: int = 48, seed: int = 0) -> list:
    """Incompressible strings — a drifted distribution for any dictionary."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, length, dtype=np.uint8).tobytes()
            for _ in range(n)]


def _mutable(artifact, strings, **kw):
    corpus = Encoder(artifact).encode(strings) if strings else None
    kw.setdefault("strings_per_segment", SPS)
    kw.setdefault("cache_bytes", 1 << 20)
    return MutableStringStore(artifact, corpus, **kw)


# ------------------------------------------------- append == from-scratch
def test_append_matches_from_scratch_build(titles, artifact):
    base, extra = titles[:700], titles[700:1300]
    store = _mutable(artifact, base)
    ids = store.extend(extra)
    assert ids == list(range(700, 1300))
    assert store.n_strings == 1300

    # ground truth: the same 1300 strings encoded in one immutable pass
    scratch = CompressedStringStore(
        artifact, Encoder(artifact).encode(base + extra),
        strings_per_segment=SPS)
    rng = np.random.default_rng(0)
    some = rng.integers(0, 1300, 500).tolist()
    assert store.multiget(some) == scratch.multiget(some)
    for i in (0, 3, 7, 699, 700, 1299):
        assert store.get(i) == scratch.get(i)
    assert store.scan(0, 1300) == scratch.scan(0, 1300)


def test_appended_ids_are_contiguous_and_empty_ok(artifact, titles):
    store = _mutable(artifact, titles[:10])
    assert store.extend([]) == []
    a = store.append(b"")
    b = store.append(b"x" * 100)
    assert (a, b) == (10, 11)
    assert store.get(a) == b"" and store.get(b) == b"x" * 100


def test_store_can_start_empty(artifact, titles):
    store = _mutable(artifact, [])
    assert store.n_strings == 0
    assert store.scan(0, 0) == []
    ids = store.extend(titles[:SPS + 5])
    assert ids[0] == 0 and store.n_strings == SPS + 5
    assert store.scan(0, SPS + 5) == titles[:SPS + 5]
    store.seal_barrier()                   # let the background seal land
    assert store.segments.n_segments == 1  # one sealed + 5 in tail


# --------------------------------------------------------- seal boundaries
def test_seal_boundary_exactly_full_tail(artifact, titles):
    base = titles[:SPS]  # base corpus = exactly one full segment
    store = _mutable(artifact, base)
    n_seg0 = store.segments.n_segments
    store.extend(titles[SPS : 2 * SPS])           # exactly fills one tail
    store.seal_barrier()
    snap = store.stats_snapshot()
    assert snap["n_tail_strings"] == 0            # sealed, nothing left over
    assert store.segments.n_segments == n_seg0 + 1
    assert snap["n_sealed_strings"] == 2 * SPS
    assert store.scan(0, 2 * SPS) == titles[: 2 * SPS]


def test_seal_boundary_empty_tail_seal_is_noop(artifact, titles):
    store = _mutable(artifact, titles[:20])
    n_seg = store.segments.n_segments
    store.seal()                                   # empty tail: nothing to do
    assert store.segments.n_segments == n_seg
    store.append(b"tailed")
    store.seal()                                   # force-seal a short tail
    assert store.segments.n_segments == n_seg + 1
    assert store.stats_snapshot()["n_tail_strings"] == 0
    assert store.get(20) == b"tailed"


def test_seal_with_partial_base_segment(artifact, titles):
    # base corpus ends mid-segment: appended seals land behind a short
    # segment, so routing must bisect, not divide
    base = titles[: SPS + 37]
    store = _mutable(artifact, base)
    store.extend(titles[SPS + 37 : 3 * SPS])
    assert store.scan(0, 3 * SPS) == titles[: 3 * SPS]
    for gid in (SPS + 36, SPS + 37, 2 * SPS, 3 * SPS - 1):
        assert store.get(gid) == titles[gid]


# -------------------------------------- satellite: tail-aware scan + stats
def test_scan_straddles_sealed_tail_boundary(artifact, titles):
    store = _mutable(artifact, titles[:300])      # seg of 256 + 44 sealed? no:
    # 300 base strings => segments [256, 44]; appends go to the tail
    store.extend(titles[300:350])                 # 50 unsealed tail strings
    snap = store.stats_snapshot()
    assert snap["n_sealed_strings"] == 300 and snap["n_tail_strings"] == 50
    assert snap["n_strings"] == 350
    # ranges fully sealed / straddling / fully tail
    assert store.scan(250, 300) == titles[250:300]
    assert store.scan(280, 340) == titles[280:340]
    assert store.scan(300, 350) == titles[300:350]
    assert store.scan(349, 350) == titles[349:350]
    assert store.scan(350, 350) == []
    with pytest.raises(IndexError):
        store.scan(0, 351)
    # multiget across the boundary, same decode answers
    ids = [0, 299, 300, 349]
    assert store.multiget(ids) == [titles[i] for i in ids]


def test_stats_snapshot_tail_aware(artifact, titles):
    store = _mutable(artifact, titles[:100])
    store.extend(titles[100:120])
    snap = store.stats_snapshot()
    for key in ("n_sealed_strings", "n_tail_strings", "drift", "compactions",
                "version"):
        assert key in snap
    assert snap["n_strings"] == 120
    assert snap["memory_bytes"] >= store._tail_payload_bytes() > 0


# ------------------------------------------------------- save/open roundtrip
def test_save_open_roundtrip_with_unsealed_tail(artifact, titles, tmp_path):
    store = _mutable(artifact, titles[:400])
    store.extend(titles[400:500])                 # leaves an unsealed tail
    assert store.stats_snapshot()["n_tail_strings"] > 0
    d = str(tmp_path / "wstore")
    store.save(d)
    assert os.path.exists(os.path.join(d, "current.json"))
    assert os.path.isdir(os.path.join(d, "v0000"))

    re = MutableStringStore.open(d)
    assert re.n_strings == 500
    assert re.stats_snapshot()["n_tail_strings"] == \
        store.stats_snapshot()["n_tail_strings"]
    assert re.scan(0, 500) == titles[:500]
    # drift window survives the round-trip
    assert re.drift.raw_bytes == store.drift.raw_bytes
    assert re.drift.baseline_ratio == pytest.approx(store.drift.baseline_ratio)
    # and the reopened store keeps appending / sealing on the same boundaries
    ids = re.extend(titles[500:600])
    assert ids == list(range(500, 600))
    assert re.scan(450, 600) == titles[450:600]


def test_open_plain_readonly_store_dir_as_writable(titles, tmp_path):
    flat = CompressedStringStore.build(titles[:300], sample_bytes=SAMPLE,
                                       strings_per_segment=SPS)
    d = str(tmp_path / "flat")
    flat.save(d)
    store = MutableStringStore.open(d)
    assert store.n_strings == 300
    store.append(b"appended onto a read-only layout")
    assert store.get(300) == b"appended onto a read-only layout"


# --------------------------------------------------------------- compaction
def test_compact_byte_identity_and_versioned_swap(artifact, titles, tmp_path):
    store = _mutable(artifact, titles[:600])
    store.extend(titles[600:700])
    store.extend(_junk(400))                      # inject drift
    assert store.drift.should_compact()
    live_before = store.scan(0, store.n_strings)

    d = str(tmp_path / "cstore")
    store.save(d)
    report = store.compact()
    assert report["version"] == "v0001"
    assert report["ratio_after"] >= report["ratio_before"]
    assert store.compactions == 1
    # all live strings byte-identical through every read path
    n = store.n_strings
    assert store.scan(0, n) == live_before
    rng = np.random.default_rng(1)
    ids = rng.integers(0, n, 300).tolist()
    assert store.multiget(ids) == [live_before[i] for i in ids]
    # drift window restarted against the new dictionary
    assert store.drift.observations == 0 and store.drift.drift == 0.0
    # versioned directory swapped atomically, old generation pruned
    assert sorted(os.listdir(d)) == ["current.json", "v0001"]
    re = MutableStringStore.open(d)
    assert re.version_id == 1
    assert re.scan(0, n) == live_before


def test_compact_drops_cached_entries_for_rewritten_segments(artifact, titles):
    store = _mutable(artifact, titles[:300], cache_bytes=1 << 20)
    store.multiget(list(range(50)))
    store.get(0)
    assert store.cache.hits >= 1 and len(store.cache) > 0
    store.compact()
    assert len(store.cache) == 0                  # rewritten segments dropped
    assert store.cache.current_bytes == 0
    assert store.get(0) == titles[0]              # decoded fresh, still right


def test_compact_on_empty_store_is_noop(artifact):
    store = _mutable(artifact, [])
    report = store.compact()
    assert report["n_strings"] == 0 and store.n_strings == 0


def test_auto_compact_triggers_on_drift(artifact, titles):
    store = _mutable(artifact, titles[:300], auto_compact=True,
                     drift_threshold=0.5)
    store.extend(_junk(600))
    assert store.compactions >= 1                 # tripped during extend
    assert store.drift.observations == 0          # window restarted
    assert store.get(300 + 599) == store.scan(0, store.n_strings)[-1]


# ------------------------------------------------------------ drift monitor
def test_drift_monitor_math():
    m = DriftMonitor(threshold=0.2, baseline_ratio=2.0, min_bytes=100)
    assert m.drift == 0.0 and not m.should_compact()
    m.observe(200, 100)                           # ratio 2.0: no drift
    assert m.drift == pytest.approx(0.0)
    m.observe(200, 300)                           # now 400/400 = 1.0
    assert m.drift == pytest.approx(0.5)
    assert m.should_compact()
    m.reset(3.0)
    assert m.observations == 0 and m.baseline_ratio == 3.0
    assert m.drift == 0.0


def test_drift_monitor_min_bytes_floor_and_validation():
    m = DriftMonitor(threshold=0.2, baseline_ratio=4.0, min_bytes=1 << 20)
    m.observe(100, 100)                           # terrible ratio, tiny data
    assert m.drift > 0.2 and not m.should_compact()
    with pytest.raises(ValueError):
        DriftMonitor(threshold=1.5)
    m2 = DriftMonitor(threshold=0.2)              # no baseline: never drifts
    m2.observe(10, 1000)
    assert m2.drift == 0.0 and not m2.should_compact()


def test_empty_started_store_seeds_baseline_and_detects_drift(artifact,
                                                              titles):
    # a store populated purely by appends has no train-time ratio: the first
    # observation window seeds the baseline so drift detection still works
    store = _mutable(artifact, [], drift_threshold=0.3)
    store.extend(titles[:800])                    # compressible seed window
    assert store.drift.baseline_ratio is not None
    assert not store.drift.should_compact()
    store.extend(_junk(600))                      # distribution shift
    assert store.drift.should_compact()


def test_segment_ratio_report(artifact, titles):
    store = _mutable(artifact, titles[:600])
    rows = segment_report(store)
    assert len(rows) == store.segments.n_segments
    for seg, row in zip(store.segments.segments, rows):
        r = segment_ratio(store.dictionary, seg)
        assert r == pytest.approx(row["ratio"], abs=1e-3)
        assert r > 1.0                            # trained data compresses
        assert row["n_strings"] == seg.n_strings


# ------------------------------------------- service: reads + appends mixed
def test_service_interleaved_reads_and_appends(artifact, titles):
    base = titles[:400]
    store = _mutable(artifact, base)
    appended = titles[400:600]
    seen_n = []
    errs: list = []

    with StoreService(store, max_batch=64, max_wait_s=0.002) as svc:
        def writer():
            try:
                futs = [svc.submit_append(s) for s in appended]
                ids = [f.result(30) for f in futs]
                # service folds appends into ordered extend() batches: ids
                # come back contiguous from 400
                assert sorted(ids) == list(range(400, 600))
                assert ids == sorted(ids)
            except Exception as e:
                errs.append(e)

        def reader(seed):
            try:
                rng = np.random.default_rng(seed)
                last_n = 0
                for _ in range(150):
                    n = store.n_strings
                    assert n >= last_n            # monotonic growth
                    last_n = n
                    seen_n.append(n)
                    i = int(rng.integers(0, 400))  # stable prefix
                    assert svc.get(i, timeout=30) == base[i]
            except Exception as e:
                errs.append(e)

        threads = [threading.Thread(target=writer)] + \
                  [threading.Thread(target=reader, args=(s,)) for s in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs, errs[0]
        st = svc.stats()
        assert st["appends"] == 200
        assert st["append_batches"] <= st["appends"]

    # after the dust settles: every appended string is byte-identical
    assert store.n_strings == 600
    assert store.scan(0, 600) == titles[:600]


def test_service_append_to_readonly_store_fails(titles):
    store = CompressedStringStore.build(titles[:50], sample_bytes=SAMPLE)
    with StoreService(store) as svc:
        with pytest.raises(TypeError):
            svc.submit_append(b"nope").result(5)


# ------------------------------------------------------- sharded write path
def test_sharded_append_and_compact_route_to_owning_shard(titles, tmp_path):
    store = CompressedStringStore.build(titles[:512], sample_bytes=SAMPLE,
                                        strings_per_segment=128)
    d = str(tmp_path / "shards")
    save_sharded(store, d, 2)
    sharded = ShardedStringStore.open(d, writable=True)
    n0 = sharded.n_strings
    gid = sharded.append(b"routed to the last shard")
    assert gid == n0
    assert sharded.get(gid) == b"routed to the last shard"
    assert sharded.bounds[-1][1] == n0 + 1
    # only the owning (last) shard grew
    assert sharded.stores[-1].n_strings == n0 - sharded.bounds[-1][0] + 1
    ids = sharded.extend(_junk(300))
    assert ids == list(range(n0 + 1, n0 + 301))
    live = [sharded.get(i) for i in range(sharded.n_strings)]
    reports = sharded.compact(shard=len(sharded.stores) - 1)
    assert len(reports) == 1
    assert [sharded.get(i) for i in range(sharded.n_strings)] == live


def test_sharded_concurrent_extends_stay_monotonic(titles, tmp_path):
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE,
                                        strings_per_segment=128)
    d = str(tmp_path / "race-shards")
    save_sharded(store, d, 2)
    sharded = ShardedStringStore.open(d, writable=True)
    results: dict[int, list[int]] = {}
    errs: list = []

    def writer(k):
        try:
            results[k] = sharded.extend(
                [b"w%d-%d" % (k, i) for i in range(50)])
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs[0]
    assert sharded.n_strings == 256 + 200         # no lost updates
    for k, ids in results.items():                # every acknowledged id reads
        assert sharded.multiget(ids) == [b"w%d-%d" % (k, i)
                                         for i in range(50)]


def test_sharded_readonly_append_raises(titles, tmp_path):
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE,
                                        strings_per_segment=128)
    d = str(tmp_path / "ro-shards")
    save_sharded(store, d, 2)
    sharded = ShardedStringStore.open(d)
    with pytest.raises(TypeError):
        sharded.append(b"x")
    with pytest.raises(TypeError):
        sharded.compact()


# ------------------------------------------------ review-fix regressions
def test_memory_bytes_stable_across_seal(artifact, titles):
    # sealed-from-tail segments must stay in the resident accounting
    store = _mutable(artifact, titles[:100], cache_bytes=0)
    store.append(titles[100])
    before = store.memory_bytes
    assert store.stats_snapshot()["n_tail_strings"] == 1
    store.seal()                                  # tail -> segment
    assert store.memory_bytes >= before           # nothing vanished

    store2 = _mutable(artifact, titles[:SPS], cache_bytes=0)
    store2.extend(titles[SPS : 2 * SPS])          # seals a full segment
    store2.seal_barrier()
    seg_bytes = sum(s.payload_bytes + s.offsets.nbytes
                    for s in store2.segments.segments)
    assert store2.memory_bytes >= seg_bytes


def test_drift_threshold_survives_save_open(artifact, titles, tmp_path):
    store = _mutable(artifact, titles[:50], drift_threshold=0.05)
    d = str(tmp_path / "thresh")
    store.save(d)
    re = MutableStringStore.open(d)
    assert re.drift.threshold == pytest.approx(0.05)
    # explicit overrides beat the saved params (and must not TypeError)
    re2 = MutableStringStore.open(d, drift_threshold=0.4, train_ratio=9.0)
    assert re2.drift.threshold == pytest.approx(0.4)
    assert re2.drift.baseline_ratio == pytest.approx(9.0)


def test_readonly_open_follows_versioned_layout(artifact, titles, tmp_path):
    store = _mutable(artifact, titles[:300])
    store.extend(titles[300:320])
    d = str(tmp_path / "verdir")
    store.save(d)
    ro = CompressedStringStore.open(d)            # read-only, same generation
    assert ro.n_strings == 320
    assert ro.scan(0, 320) == titles[:320]


def test_flat_dir_upgrade_leaves_no_stale_generation(titles, tmp_path):
    flat = CompressedStringStore.build(titles[:100], sample_bytes=SAMPLE,
                                       strings_per_segment=SPS)
    d = str(tmp_path / "upgrade")
    flat.save(d)
    m = MutableStringStore.open(d)
    m.append(b"appended then compacted")
    m.compact()                                   # upgrades d to versioned
    assert not os.path.exists(os.path.join(d, "corpus.rpc"))
    assert not os.path.exists(os.path.join(d, "dictionary.rpa"))
    # BOTH open paths now agree on the same generation
    assert CompressedStringStore.open(d).n_strings == 101
    assert MutableStringStore.open(d).get(100) == b"appended then compacted"


def test_sharded_appends_persist_across_save_open(titles, tmp_path):
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE,
                                        strings_per_segment=128)
    d = str(tmp_path / "durable-shards")
    save_sharded(store, d, 2)
    sharded = ShardedStringStore.open(d, writable=True)
    ids = sharded.extend([b"persisted-one", b"persisted-two"])
    sharded.save()
    # only the dirty (appended-to) shard was rewritten to a versioned
    # layout; the untouched shard keeps the shared flat layout
    assert not os.path.exists(os.path.join(d, "shard-0000", "current.json"))
    assert os.path.exists(os.path.join(d, "shard-0001", "current.json"))
    re = ShardedStringStore.open(d, writable=True)
    assert re.n_strings == 258
    assert [re.get(i) for i in ids] == [b"persisted-one", b"persisted-two"]
    assert re.multiget(list(range(256))) == titles[:256]
    # a read-only reopen of the same layout serves the saved appends but
    # rejects writes — writable=False must hold for versioned shards too
    ro = ShardedStringStore.open(d)
    assert [ro.get(i) for i in ids] == [b"persisted-one", b"persisted-two"]
    with pytest.raises(TypeError):
        ro.extend([b"nope"])
    # save() is in-place only: a router not opened from disk has no target
    with pytest.raises(ValueError):
        ShardedStringStore(re.stores, re.bounds).save()


def test_sharded_open_rejects_out_of_band_nontail_growth(titles, tmp_path):
    from repro.distributed.shard_store import open_shard
    store = CompressedStringStore.build(titles[:256], sample_bytes=SAMPLE,
                                        strings_per_segment=128)
    d = str(tmp_path / "oob-shards")
    save_sharded(store, d, 2)
    # grow a NON-tail shard behind the router's back and persist it
    shard0 = open_shard(d, 0, writable=True)
    shard0.append(b"smuggled in")
    shard0.save(os.path.join(d, "shard-0000"))
    with pytest.raises(ValueError, match="only the last shard may grow"):
        ShardedStringStore.open(d)
    # the tail shard growing out of band is fine: its bound extends
    d2 = str(tmp_path / "tail-shards")
    save_sharded(store, d2, 2)
    tail = open_shard(d2, 1, writable=True)
    tail.append(b"tail growth ok")
    tail.save(os.path.join(d2, "shard-0001"))
    re = ShardedStringStore.open(d2)
    assert re.n_strings == 257
    assert re.get(256) == b"tail growth ok"


def test_save_sharded_covers_appended_strings(artifact, titles, tmp_path):
    # sharding a writable store must snapshot sealed-tail segments + tail,
    # not the stale construction-time corpus
    store = _mutable(artifact, titles[:300])
    store.extend(titles[300:500])                 # seals one segment + tail
    d = str(tmp_path / "append-shards")
    bounds = save_sharded(store, d, 2)
    assert bounds[-1][1] == 500
    sharded = ShardedStringStore.open(d)
    assert sharded.n_strings == 500
    assert sharded.multiget(list(range(500))) == titles[:500]


def test_swap_state_never_unpublishes_ids(artifact, titles):
    # lock-free n_strings readers rely on the published count never dipping,
    # even while compact() swaps in a corpus that excludes the delta
    store = _mutable(artifact, titles[:100])
    new_comp = registry.codec_from_artifact(store.artifact)
    new_comp.train(titles[:100])
    partial = new_comp.compress(titles[:80])      # 20 ids still "in flight"
    with store._lock:
        store._swap_state_locked(new_comp, partial)
        assert store.n_strings == 100             # acknowledged ids stay


def test_extend_reparses_when_compact_swaps_mid_encode(artifact, titles):
    # simulate a compact() landing between extend()'s encode and ingest by
    # bumping version_id after the first encode call
    store = _mutable(artifact, titles[:100])
    real_encode = store._encoder.encode
    tripped = {}

    class Tripwire:
        def encode(self, strings):
            if not tripped:
                tripped["hit"] = True
                corpus = real_encode(strings)
                store.compact()          # swaps dictionary + version_id
                return corpus            # now-stale payloads
            return store._encoder.encode(strings)  # post-swap encoder

    store._encoder = Tripwire()
    ids = store.extend([b"raced string", titles[5]])
    assert tripped and store.multiget(ids) == [b"raced string", titles[5]]


# ------------------------------------------------- acceptance criterion
def test_acceptance_full_lifecycle(titles, tmp_path):
    """N build + M frozen-dict appends + injected drift + compact: every
    read path returns byte-identical strings, before and after save→open."""
    N, M = 500, 300
    base = titles[:N]
    appended = titles[N : N + M - 150] + _junk(150, length=160, seed=7)
    art = registry.train("onpair16", base, sample_bytes=SAMPLE)
    store = MutableStringStore(art, Encoder(art).encode(base),
                               strings_per_segment=SPS)
    store.extend(appended)
    expect = base + appended
    assert store.drift.should_compact()           # injected drift visible
    store.compact()

    def check(s):
        n = s.n_strings
        assert n == N + M
        assert s.scan(0, n) == expect
        rng = np.random.default_rng(2)
        ids = rng.integers(0, n, 400).tolist()
        assert s.multiget(ids) == [expect[i] for i in ids]
        for i in (0, N - 1, N, n - 1):
            assert s.get(i) == expect[i]

    check(store)
    d = str(tmp_path / "acceptance")
    store.save(d)
    check(MutableStringStore.open(d))
