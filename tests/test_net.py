"""Tests for the repro.net multi-process serving tier.

Protocol framing (round-trips, truncation, oversize refusal), server/router
loopback equivalence against the in-process ShardedStringStore on the same
directories, request-order preservation under concurrent fan-out, retry
across a shard process kill/restart, replica-backed compaction hand-off,
and the StoreService no-busy-wait contract.

Everything here is stdlib + numpy (the point of the RPC tier: serving hosts
without jax stay covered); spawned child processes run with REPRO_NO_JAX=1
so startup stays fast on jax-equipped containers too.
"""

import os
import re
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.data.synth import load_dataset
from repro.distributed import ShardedStringStore, save_sharded
from repro.net import (
    DistributedStringStore,
    FrameTooLargeError,
    ProtocolError,
    RemoteError,
    RemoteShardClient,
    ShardServer,
    TruncatedFrameError,
)
from repro.net import protocol as P
from repro.store import CompressedStringStore, StoreService

SAMPLE = 1 << 18
# .../src/repro/net/protocol.py -> .../src (repro may be a namespace package)
SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(P.__file__))))
CHILD_ENV = {**os.environ, "PYTHONPATH": SRC_DIR, "REPRO_NO_JAX": "1"}


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""
    strings[7] = b"\x00\xff" * 9
    return strings


@pytest.fixture(scope="module")
def sharded_dir(titles, tmp_path_factory):
    store = CompressedStringStore.build(
        titles, sample_bytes=SAMPLE, strings_per_segment=256
    )
    d = str(tmp_path_factory.mktemp("net") / "shards")
    save_sharded(store, d, 3)
    return d


@pytest.fixture()
def cluster(sharded_dir):
    servers = [
        ShardServer.from_dir(os.path.join(sharded_dir, f"shard-{k:04d}")).start()
        for k in range(3)
    ]
    dist = DistributedStringStore.connect(
        [s.address for s in servers], dir_path=sharded_dir
    )
    yield dist, servers
    dist.close()
    for s in servers:
        s.close()


def _spawn_server(args, via_launcher=False):
    """Start a shard server child process; returns (proc, (host, port))."""
    mod = ["-m", "repro.launch.serve", "--shard-server"] if via_launcher else [
        "-m",
        "repro.net",
    ]
    proc = subprocess.Popen(
        [sys.executable, *mod, *args],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
        env=CHILD_ENV,
    )
    line = proc.stdout.readline()
    m = re.search(r"SHARD_SERVER_READY port=(\d+)", line)
    if not m:
        proc.terminate()
        raise AssertionError(
            f"server never became ready: {line!r}\n{proc.stderr.read()}"
        )
    return proc, ("127.0.0.1", int(m.group(1)))


# ------------------------------------------------------------------- protocol
def test_frame_roundtrip_all_ops():
    for kind in list(P.OP_NAMES) + [P.ST_OK, P.ST_ERR]:
        payload = os.urandom(kind)  # varied sizes, including empty
        buf = P.encode_frame(kind, payload)
        got_kind, got_payload, used = P.decode_frame(buf + b"trailing")
        assert (got_kind, got_payload, used) == (kind, payload, len(buf))


def test_frame_rejects_bad_magic_and_version():
    frame = bytearray(P.encode_frame(P.OP_PING, b"x"))
    frame[0] = ord("X")
    with pytest.raises(ProtocolError):
        P.decode_frame(bytes(frame))
    frame = bytearray(P.encode_frame(P.OP_PING, b"x"))
    frame[2] = 99  # version byte
    with pytest.raises(ProtocolError):
        P.decode_frame(bytes(frame))


def test_oversized_frame_refused_from_header_alone():
    frame = P.encode_frame(P.OP_EXTEND, b"a" * 1024)
    with pytest.raises(FrameTooLargeError):
        P.decode_frame(frame, max_frame=512)
    # the declared length alone triggers refusal — payload bytes not needed
    with pytest.raises(FrameTooLargeError):
        P.decode_header(frame[: P.HEADER_BYTES], max_frame=512)


def test_truncated_frame_detected_at_every_cut():
    frame = P.encode_frame(P.OP_MULTIGET, P.pack_ids([1, 2, 3]))
    for cut in range(len(frame)):
        with pytest.raises(TruncatedFrameError):
            P.decode_frame(frame[:cut])


def test_truncated_frame_over_socket():
    a, b = socket.socketpair()
    frame = P.encode_frame(P.OP_PING, b"payload")
    a.sendall(frame[: len(frame) - 3])
    a.close()
    with pytest.raises(TruncatedFrameError):
        P.recv_frame(b)
    b.close()
    # clean EOF at a frame boundary is None, not an error
    a, b = socket.socketpair()
    a.sendall(frame)
    a.close()
    assert P.recv_frame(b) == (P.OP_PING, b"payload")
    assert P.recv_frame(b) is None
    b.close()


def test_payload_helpers_roundtrip():
    ids = [0, 1, 2**40, 7]
    assert P.unpack_ids(P.pack_ids(ids)) == ids
    assert P.unpack_ids(b"") == []
    items = [b"", b"a", b"\x00\xff" * 100, b"", b"tail"]
    assert P.unpack_bytes_list(P.pack_bytes_list(items)) == items
    assert P.unpack_bytes_list(P.pack_bytes_list([])) == []
    with pytest.raises(ProtocolError):
        P.unpack_ids(b"odd")
    with pytest.raises(ProtocolError):
        P.unpack_bytes_list(b"\x01")


def test_remote_error_mapping():
    with pytest.raises(IndexError, match="out of range"):
        P.raise_remote(P.pack_error(IndexError("id 9 out of range")))
    with pytest.raises(RemoteError, match="OSError"):
        P.raise_remote(P.pack_error(OSError("disk on fire")))


# ------------------------------------------------- service: no-busy-wait fix
def test_service_idle_without_wakeups(titles):
    store = CompressedStringStore.build(titles[:64], sample_bytes=SAMPLE)
    with StoreService(store) as svc:
        time.sleep(0.3)  # several _POLL_S periods of the old polling drain
        assert svc.wakeups == 0, "idle service must not wake its worker"
        assert svc.batches == 0
        assert svc.get(5) == titles[5]
        assert svc.wakeups >= 1
        wakes = svc.wakeups
        time.sleep(0.2)
        assert svc.wakeups == wakes  # back to fully idle after traffic


def test_service_bulk_hooks(titles):
    store = CompressedStringStore.build(titles[:128], sample_bytes=SAMPLE)
    with StoreService(store) as svc:
        fut = svc.submit_multiget([5, 3, 5, 127])
        assert fut.result(30) == [titles[5], titles[3], titles[5], titles[127]]
        with pytest.raises(IndexError):
            svc.submit_multiget([0, 128]).result(30)
        with pytest.raises(TypeError):
            svc.submit_extend([b"x"]).result(30)  # read-only store
        # only the served batch counts: failed validations never enqueue
        assert svc.stats()["requests"] == 4


def test_service_close_during_inflight_batch_does_not_hang(titles):
    store = CompressedStringStore.build(titles[:64], sample_bytes=SAMPLE)
    svc = StoreService(store, max_wait_s=0.2)  # wide window to land close() in
    orig = store.multiget

    def slow_multiget(ids):
        time.sleep(0.3)
        return orig(ids)

    store.multiget = slow_multiget
    fut = svc.submit(5)
    time.sleep(0.05)  # worker is now inside the batch window / decode
    t0 = time.time()
    svc.close()
    assert time.time() - t0 < 3.0, "close() stalled on a lost sentinel"
    assert not svc._worker.is_alive()
    assert fut.result(1) == titles[5]


# --------------------------------------------------------- loopback equality
def test_router_matches_local_sharded_store(cluster, sharded_dir, titles):
    dist, _ = cluster
    local = ShardedStringStore.open(sharded_dir)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, len(titles), 800).tolist()
    assert dist.multiget(ids) == local.multiget(ids)
    assert dist.get(3) == titles[3] == local.get(3)
    lo, hi = len(titles) // 3 - 50, len(titles) // 3 + 50  # straddles shards
    assert dist.scan(lo, hi) == local.scan(lo, hi) == titles[lo:hi]
    assert dist.n_strings == local.n_strings == len(titles)
    snap = dist.stats_snapshot()
    assert snap["n_shards"] == 3
    assert snap["bounds"] == [list(b) for b in local.bounds]
    assert all(s["service"]["requests"] >= 0 for s in snap["shards"])
    with pytest.raises(IndexError):
        dist.get(len(titles))
    with pytest.raises(IndexError):
        dist.multiget([0, len(titles)])


def test_order_preserved_under_concurrent_fanout(cluster, titles):
    dist, _ = cluster
    errs = []

    def client(seed):
        try:
            rng = np.random.default_rng(seed)
            for _ in range(10):
                ids = rng.integers(0, len(titles), 200).tolist()
                assert dist.multiget(ids) == [titles[i] for i in ids]
        except Exception as exc:  # pragma: no cover - failure path
            errs.append(exc)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs[0]


def test_router_appends_route_to_tail_shard(cluster, titles):
    dist, servers = cluster
    n0 = dist.n_strings
    new = [b"net-append-%d" % i for i in range(300)]
    ids = dist.extend(new[:200])
    ids += [dist.append(s) for s in new[200:210]]
    futs = [dist.extend(new[210 + 3 * k : 213 + 3 * k]) for k in range(30)]
    ids += [i for chunk in futs for i in chunk]
    assert ids == list(range(n0, n0 + 300))
    assert dist.multiget(ids) == new
    assert dist.scan(n0 - 5, n0 + 300) == dist.multiget(
        range(n0 - 5, n0 + 300)
    )
    # every append landed on the tail shard's server, none elsewhere
    assert servers[-1].store.n_strings - (dist.bounds[-1][1] - dist.bounds[-1][0]) == 0
    assert servers[0].store.n_strings == dist.bounds[0][1]


def test_oversized_request_surfaces_instead_of_retrying(sharded_dir, titles):
    with ShardServer.from_dir(
        os.path.join(sharded_dir, "shard-0000"), max_frame=4096
    ).start() as server:
        client = RemoteShardClient(server.address)
        assert client.get(0) == titles[0]
        with pytest.raises(FrameTooLargeError, match="max_frame"):
            client.extend([b"x" * 16384])
        assert client.reconnects == 0  # refused once, not resent 17 times
        assert client.get(1) == titles[1]  # client reconnects cleanly after
        client.close()


def test_distributed_scan_chunks_below_max_frame(cluster, sharded_dir, titles):
    dist, _ = cluster
    dist.scan_chunk = 64  # force many small RPCs across shard boundaries
    lo, hi = 100, 1200
    assert dist.scan(lo, hi) == titles[lo:hi]


def test_server_refuses_writes_when_read_only(sharded_dir):
    with ShardServer.from_dir(
        os.path.join(sharded_dir, "shard-0000"), read_only=True
    ).start() as server:
        client = RemoteShardClient(server.address)
        assert client.get(0) == client.multiget([0])[0]
        with pytest.raises(TypeError):
            client.append(b"nope")
        with pytest.raises(TypeError):
            client.compact()
        assert client.stats()["writable"] is False
        client.close()


# ------------------------------------------------------- process lifecycles
def test_router_retries_across_server_restart(titles, tmp_path):
    store = CompressedStringStore.build(
        titles[:2000], sample_bytes=SAMPLE, strings_per_segment=256
    )
    d = str(tmp_path / "shards")
    save_sharded(store, d, 2)
    shard_dirs = [os.path.join(d, f"shard-{k:04d}") for k in range(2)]
    procs, addrs = [], []
    for k, sd in enumerate(shard_dirs):
        # shard 0 via the serve.py launcher (covers the --shard-server role),
        # shard 1 via python -m repro.net
        proc, addr = _spawn_server([sd], via_launcher=(k == 0))
        procs.append(proc)
        addrs.append(addr)
    dist = DistributedStringStore.connect(addrs, dir_path=d)
    try:
        assert dist.get(1) == titles[1]
        mid = dist.bounds[1][0] + 5
        assert dist.get(mid) == titles[mid]

        procs[1].terminate()
        procs[1].wait()
        with pytest.raises((ConnectionError, OSError)):
            # fast-failing client so the dead window is observed
            RemoteShardClient(addrs[1], reconnect_attempts=1).multiget([0])

        procs[1], _ = _spawn_server(
            [shard_dirs[1], "--port", str(addrs[1][1])]
        )
        assert dist.get(mid) == titles[mid]  # reconnects transparently
        assert dist.clients[1].reconnects >= 1
    finally:
        dist.close()
        for proc in procs:
            proc.terminate()


def test_replica_failover_during_live_compact(titles, tmp_path):
    store = CompressedStringStore.build(
        titles[:1500], sample_bytes=SAMPLE, strings_per_segment=256
    )
    d = str(tmp_path / "shards")
    save_sharded(store, d, 2)
    tail_dir = os.path.join(d, "shard-0001")
    servers = [
        ShardServer.from_dir(os.path.join(d, f"shard-{k:04d}")).start()
        for k in range(2)
    ]
    dist = DistributedStringStore.connect(
        [s.address for s in servers], dir_path=d
    )
    replica = None
    try:
        pre_ids = dist.extend([b"pre-compact-%d" % i for i in range(20)])
        dist.save()  # replica opens the saved (current) generation

        replica = ShardServer.from_dir(tail_dir, read_only=True).start()
        with pytest.raises(ValueError):  # a writable "replica" is refused
            dist.register_replica(1, servers[1].address)
        dist.register_replica(1, replica.address)

        # stretch the compaction window so the hand-off is observable
        primary_store = servers[1].store
        orig_compact = primary_store.compact

        def slow_compact(**kw):
            time.sleep(0.6)
            return orig_compact(**kw)

        primary_store.compact = slow_compact
        reports = {}

        def run_compact():
            reports["compact"] = dist.compact(1)

        compacter = threading.Thread(target=run_compact)
        compacter.start()
        deadline = time.time() + 5
        while not dist._draining.get(1) and time.time() < deadline:
            time.sleep(0.01)
        assert dist._draining.get(1), "compact never entered hand-off"

        # reads drain to the replica and never block on the rewrite
        t0 = time.time()
        assert dist.get(pre_ids[3]) == b"pre-compact-3"
        assert dist.multiget(pre_ids) == [b"pre-compact-%d" % i for i in range(20)]
        assert time.time() - t0 < 0.5
        replica_client, replica_n = dist._replicas[1][0]
        assert replica_client.n_strings >= pre_ids[-1] - dist.bounds[1][0]
        assert replica_n == replica_client.n_strings

        # appends park in the retry queue and are acknowledged post-swap
        mid_id = dist.append(b"appended-during-compact")
        compacter.join(timeout=30)
        assert reports["compact"][0]["n_strings"] > 0
        assert mid_id == pre_ids[-1] + 1
        assert dist.get(mid_id) == b"appended-during-compact"

        # durable: persisted and visible to a fresh in-process open
        dist.save()
        local = ShardedStringStore.open(d)
        assert local.get(mid_id) == b"appended-during-compact"
        assert local.get(pre_ids[0]) == b"pre-compact-0"
    finally:
        dist.close()
        for s in servers:
            s.close()
        if replica is not None:
            replica.close()
