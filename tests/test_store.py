"""Tests for the repro.store serving subsystem: byte-for-byte equivalence of
get/multiget/scan against RawCompressor ground truth (OnPair + OnPair16),
routing/bucketing invariants, cache accounting, and the micro-batch service."""

import threading

import numpy as np
import pytest

from repro.core import RawCompressor, make_onpair, make_onpair16
from repro.data.synth import load_dataset
from repro.store import CompressedStringStore, LRUCache, StoreService

SAMPLE = 1 << 19


@pytest.fixture(scope="module")
def titles():
    # a few hand-placed edge strings, including empties, inside a real corpus
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""
    strings[100] = b""
    strings[7] = b"\x00\xff" * 9
    return strings


@pytest.fixture(scope="module")
def raw_corpus(titles):
    return RawCompressor().compress(titles)


def _build(titles, variant16, **kw):
    comp = (make_onpair16 if variant16 else make_onpair)(sample_bytes=SAMPLE)
    comp.train(titles)
    return CompressedStringStore(comp, comp.compress(titles), **kw)


@pytest.fixture(scope="module")
def store16(titles):
    return _build(titles, True, strings_per_segment=1024)


@pytest.fixture(scope="module")
def store_unbounded(titles):
    return _build(titles, False, strings_per_segment=1024)


# -------------------------------------------------- ground-truth equivalence
@pytest.mark.parametrize("which", ["onpair16", "onpair"])
def test_multiget_matches_raw_ground_truth(titles, raw_corpus, store16,
                                           store_unbounded, which):
    store = store16 if which == "onpair16" else store_unbounded
    raw = RawCompressor()
    rng = np.random.default_rng(42)
    ids = rng.integers(0, len(titles), 1200).tolist()
    got = store.multiget(ids)
    assert got == [raw.access(raw_corpus, i) for i in ids]


@pytest.mark.parametrize("which", ["onpair16", "onpair"])
def test_get_and_scan_match_raw(titles, raw_corpus, store16, store_unbounded,
                                which):
    store = store16 if which == "onpair16" else store_unbounded
    raw = RawCompressor()
    for i in [0, 3, 7, 100, len(titles) - 1]:  # includes empties + binary
        assert store.get(i) == raw.access(raw_corpus, i)
    # scan crossing a segment boundary (segments are 1024 strings wide)
    lo, hi = 1000, 1100
    assert store.scan(lo, hi) == [raw.access(raw_corpus, i)
                                  for i in range(lo, hi)]
    assert store.scan(5, 5) == []


def test_multiget_duplicate_ids_decode_once(store16, titles):
    ids = [9, 9, 12, 9, 3, 12, 3]
    before = store16.stats.decoded_strings
    out = store16.multiget(ids)
    assert out == [titles[i] for i in ids]
    # 3 distinct uncached ids at most -> at most 3 new decoded strings
    assert store16.stats.decoded_strings - before <= 3


def test_out_of_range_ids_raise(store16):
    n = store16.n_strings
    with pytest.raises(IndexError):
        store16.get(n)
    with pytest.raises(IndexError):
        store16.multiget([0, 1, n + 5])
    with pytest.raises(IndexError):
        store16.multiget([-1])
    with pytest.raises(IndexError):
        store16.scan(0, n + 1)


def test_empty_strings_roundtrip_and_cache(titles):
    store = _build(titles, True, cache_bytes=1 << 20)
    assert store.get(3) == b""
    assert store.get(3) == b""          # second hit must come from cache
    assert store.cache.hits >= 1


# ----------------------------------------------------------- batch shaping
def test_bucketing_bounds_jit_shapes(titles):
    """>= 1000 random ids decode through at most 4 static (B, T) shapes."""
    store = _build(titles, True, cache_bytes=0)
    if store.backend != "jax":
        pytest.skip("jax backend unavailable")
    rng = np.random.default_rng(7)
    ids = rng.integers(0, len(titles), 1000).tolist()
    out = store.multiget(ids)
    assert out == [titles[i] for i in ids]
    assert 1 <= len(store.stats.jit_shapes) <= 4
    assert all(B == store.batch_size for B, _ in store.stats.jit_shapes)
    assert len(store.bucket_caps) <= 4
    # every string's token count is covered by the largest bucket
    assert int(store.segments.token_counts().max()) <= int(store.bucket_caps[-1])


def test_numpy_backend_matches_jax_backend(titles, store16):
    comp, corpus = store16.compressor, store16.corpus
    np_store = CompressedStringStore(comp, corpus, backend="numpy",
                                     cache_bytes=0)
    assert np_store.backend == "numpy"
    ids = list(range(0, 600, 3))
    assert np_store.multiget(ids) == store16.multiget(ids)


def test_unbounded_onpair_rejects_jax_backend(store_unbounded):
    if not store_unbounded.dictionary.variant16:
        with pytest.raises(ValueError):
            CompressedStringStore(store_unbounded.compressor,
                                  store_unbounded.corpus, backend="jax")


# ------------------------------------------------------------------ segments
def test_segment_routing(titles, store16):
    segs = store16.segments
    assert segs.n_segments == -(-len(titles) // 1024)
    for gid in [0, 1023, 1024, len(titles) - 1]:
        seg, local = segs.route(gid)
        assert seg.base_id + local == gid
        np.testing.assert_array_equal(
            seg.string_tokens(local), store16.corpus.string_tokens(gid))
    assert int(segs.token_counts().sum()) == store16.corpus.payload.size // 2
    with pytest.raises(IndexError):
        segs.route(len(titles))


# --------------------------------------------------------------------- cache
def test_lru_cache_eviction_and_accounting():
    c = LRUCache(capacity_bytes=10)
    c.put(1, b"aaaa")
    c.put(2, b"bbbb")
    assert c.get(1) == b"aaaa"          # 1 is now most-recent
    c.put(3, b"cccc")                   # 12 bytes > 10: evicts LRU (2)
    assert c.get(2) is None
    assert c.get(1) == b"aaaa"
    assert c.evictions == 1
    assert c.current_bytes <= 10
    c.put(1, b"x")                      # overwrite adjusts accounting
    assert c.current_bytes == len(b"x") + len(b"cccc")
    assert c.get(4) is None
    st = c.stats()
    assert st["hits"] == 2 and st["misses"] == 2

    disabled = LRUCache(capacity_bytes=0)
    disabled.put(1, b"zz")
    assert disabled.get(1) is None

    # an entry larger than the whole budget must be rejected, not admitted
    c2 = LRUCache(capacity_bytes=10)
    c2.put(1, b"aaaa")
    c2.put(2, b"x" * 100)
    assert c2.get(2) is None and c2.get(1) == b"aaaa"
    assert c2.current_bytes <= 10


def test_cache_stores_empty_strings():
    c = LRUCache(capacity_bytes=100)
    c.put(5, b"")
    assert c.get(5) == b""
    assert c.hits == 1 and c.misses == 0


# ------------------------------------------------------------------- service
def test_service_coalesces_and_matches(titles, store16):
    with StoreService(store16, max_batch=64, max_wait_s=0.002) as svc:
        rng = np.random.default_rng(3)
        ids = rng.integers(0, len(titles), 300).tolist()
        errs: list[Exception] = []

        def client(chunk):
            try:
                for i in chunk:
                    assert svc.get(int(i)) == titles[int(i)]
            except Exception as e:  # surfaced after join
                errs.append(e)

        threads = [threading.Thread(target=client, args=(ids[k::4],))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        st = svc.stats()
        assert st["requests"] == 300
        assert st["batches"] <= 300     # some coalescing happened is typical;
        bad = svc.submit(len(titles) + 1)
        with pytest.raises(IndexError):
            bad.result(timeout=5)
    with pytest.raises(RuntimeError):
        svc.get(0)                      # closed service fails fast


# ----------------------------------------------------- satellite: access()
@pytest.mark.parametrize("variant16", [True, False])
def test_access_equals_decompress_all_slice(titles, variant16):
    comp = (make_onpair16 if variant16 else make_onpair)(sample_bytes=SAMPLE)
    comp.train(titles)
    corpus = comp.compress(titles[:500])
    blob = comp.decompress_all(corpus)
    # per-string boundaries derived from the token streams alone
    lens = comp.dictionary.lens
    starts = np.zeros(corpus.n_strings + 1, dtype=np.int64)
    for i in range(corpus.n_strings):
        toks = np.asarray(corpus.string_tokens(i), dtype=np.int64)
        starts[i + 1] = starts[i] + int(lens[toks].sum())
    assert starts[-1] == len(blob)
    for i in range(corpus.n_strings):
        assert comp.access(corpus, i) == blob[starts[i] : starts[i + 1]]


def test_stats_snapshot_shape(store16):
    snap = store16.stats_snapshot()
    for key in ("lookups", "batches", "jit_shapes", "multiget_latency",
                "cache", "backend", "bucket_caps", "memory_bytes"):
        assert key in snap
    assert snap["multiget_latency"]["count"] >= 1
    assert 0.0 <= snap["cache"]["hit_rate"] <= 1.0
    # memory accounting includes the decode matrix + LPM tables
    assert store16.dictionary.resident_bytes > store16.dictionary.total_bytes
    assert snap["memory_bytes"] >= store16.dictionary.resident_bytes
