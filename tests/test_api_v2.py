"""API v2 tests: serializable dictionary artifacts, the codec registry and
its capability flags, Encoder/Decoder backends, and store/sharded-store
persistence. Everything here must run on a numpy-only host (no jax, no
hypothesis, no zstandard) — jax-dependent paths are skip-gated."""

import json
import os

import numpy as np
import pytest

from repro.core import (CompressedCorpus, DictArtifact, Decoder, Encoder,
                        registry)
from repro.data.synth import load_dataset
from repro.distributed import ShardedStringStore, plan_shards, save_sharded
from repro.store import CompressedStringStore

SAMPLE = 1 << 18


def _available_codecs():
    return registry.names()  # zstd-block drops out when zstandard is missing


@pytest.fixture(scope="module")
def titles():
    strings = load_dataset("book_titles", SAMPLE)
    strings[3] = b""                      # empties survive round-trips
    strings[7] = b"\x00\xff" * 9          # binary-safe
    return strings


@pytest.fixture(scope="module")
def artifacts(titles):
    """codec name -> (artifact, corpus) trained once per module."""
    out = {}
    for name in _available_codecs():
        art = registry.train(name, titles, sample_bytes=SAMPLE) \
            if registry.capabilities(name).trainable \
            else registry.create(name).to_artifact()
        corpus = Encoder(art).encode(titles)
        out[name] = (art, corpus)
    return out


# ----------------------------------------------------------------- registry
def test_all_codecs_constructible_by_name():
    # acceptance criterion: the paper's six rows all come from the registry
    for name in ("onpair", "onpair16", "bpe", "fsst", "lz-block", "raw"):
        codec = registry.create(name)
        assert hasattr(codec, "train") and hasattr(codec, "compress")


def test_registry_aliases_and_unknown():
    assert registry.resolve("zlib-block") == "lz-block"
    with pytest.raises(KeyError):
        registry.resolve("nope-codec")


def test_capability_flags_match_behavior(titles, artifacts):
    for name, (art, corpus) in artifacts.items():
        caps = registry.capabilities(name)
        dec = Decoder(art)

        # trainable <=> the artifact carries a real trained table
        assert caps.trainable == (art.num_entries > 0), name

        # token_stream <=> per-string payload slices are u16 token streams
        # decodable against the frozen dictionary
        if caps.token_stream:
            lens = np.diff(corpus.offsets)
            assert (lens % 2 == 0).all(), name
            d = dec.dictionary
            assert d is not None, name
            for i in (0, 3, 7, len(titles) - 1):
                toks = np.asarray(corpus.string_tokens(i), dtype=np.int64)
                assert d.decode_tokens(toks) == titles[i], name
        else:
            assert dec.dictionary is None or name == "fsst", name

        # bounded_entries <=> every table entry fits the 16-byte decode row
        if art.entries:
            assert caps.bounded_entries == all(
                len(e) <= 16 for e in art.entries), name

        # device_decodable implies the bounded token-stream layout; when jax
        # is importable the device codec must actually construct
        if caps.device_decodable:
            assert caps.token_stream and caps.bounded_entries, name
            jax = pytest.importorskip("jax")  # noqa: F841
            from repro.kernels.ops import OnPairDevice
            OnPairDevice.from_artifact(art)


# ----------------------------------------------------- artifact persistence
def test_artifact_save_load_decode_identical(titles, artifacts, tmp_path):
    # acceptance criterion: train -> save -> load -> decode, byte-identical,
    # for every registered codec
    expect = b"".join(titles)
    for name, (art, corpus) in artifacts.items():
        path = str(tmp_path / f"{name}.rpa")
        art.save(path)
        loaded = DictArtifact.load(path)
        assert registry.resolve(loaded.codec) == name
        assert loaded.entries == art.entries
        assert loaded.config == art.config
        dec = Decoder(loaded)
        assert dec.decode_all(corpus) == expect, name
        for i in (0, 3, 7, 42, len(titles) - 1):
            assert dec.access(corpus, i) == titles[i], name
        # and an encoder from the loaded artifact reproduces the corpus
        corpus2 = Encoder(loaded).encode(titles)
        assert corpus2.payload.tobytes() == corpus.payload.tobytes(), name
        np.testing.assert_array_equal(corpus2.offsets, corpus.offsets)


def test_artifact_bytes_roundtrip_and_bad_magic(artifacts):
    art, _ = artifacts["onpair16"]
    blob = art.to_bytes()
    again = DictArtifact.from_bytes(blob)
    assert again.entries == art.entries
    with pytest.raises(ValueError):
        DictArtifact.from_bytes(b"not an artifact container at all")


def test_artifact_mmap_load_is_lazy(artifacts, tmp_path):
    art, _ = artifacts["onpair16"]
    path = str(tmp_path / "d.rpa")
    art.save(path)
    loaded = DictArtifact.load(path, mmap=True)
    assert isinstance(loaded.arrays["blob"], np.memmap)
    assert loaded.entries == art.entries


def test_corpus_save_load(titles, artifacts, tmp_path):
    for name in ("onpair16", "lz-block", "raw"):
        art, corpus = artifacts[name]
        path = str(tmp_path / f"{name}.rpc")
        corpus.save(path)
        loaded = CompressedCorpus.load(path)
        assert loaded.raw_bytes == corpus.raw_bytes
        assert loaded.payload.tobytes() == corpus.payload.tobytes()
        np.testing.assert_array_equal(loaded.offsets, corpus.offsets)
        assert Decoder(art).decode_all(loaded) == b"".join(titles), name


def test_block_corpus_meta_arrays_survive(titles, artifacts, tmp_path):
    art, corpus = artifacts["lz-block"]
    codec = registry.codec_from_artifact(art)
    codec.access(corpus, 5)                      # populates "_cache" meta
    path = str(tmp_path / "b.rpc")
    corpus.save(path)
    loaded = CompressedCorpus.load(path)
    assert "_cache" not in loaded.meta           # transient state dropped
    for k in ("str_block", "str_off", "str_len"):
        np.testing.assert_array_equal(np.asarray(loaded.meta[k]),
                                      np.asarray(corpus.meta[k]))
    assert registry.codec_from_artifact(art).access(loaded, 5) == titles[5]


# ------------------------------------------------------- encoder / decoder
def test_backend_validation(artifacts):
    art16, _ = artifacts["onpair16"]
    art_raw, _ = artifacts["raw"]
    with pytest.raises(ValueError):
        Decoder(art16, backend="cuda")
    with pytest.raises(ValueError):
        Decoder(art_raw, backend="pallas")   # not device-decodable


def test_pallas_backend_matches_numpy(titles, artifacts):
    pytest.importorskip("jax")
    art, corpus = artifacts["onpair16"]
    ids = list(range(0, 200, 7))
    host = Decoder(art, backend="numpy")
    dev = Decoder(art, backend="pallas")
    assert dev.multiget(corpus, ids) == host.multiget(corpus, ids)
    assert dev.access(corpus, 3) == titles[3]


# ------------------------------------------------------- store persistence
def test_store_save_open_multiget_identical(titles, tmp_path):
    # acceptance criterion: a saved store reopened from disk serves identical
    # get/multiget/scan without retraining
    store = CompressedStringStore.build(titles, sample_bytes=SAMPLE,
                                        strings_per_segment=512)
    d = str(tmp_path / "store")
    store.save(d)
    reopened = CompressedStringStore.open(d)
    rng = np.random.default_rng(11)
    ids = rng.integers(0, len(titles), 800).tolist()
    assert reopened.multiget(ids) == store.multiget(ids)
    assert reopened.get(7) == store.get(7)
    assert reopened.scan(400, 700) == store.scan(400, 700)
    # saved construction params come back
    assert reopened.segments.strings_per_segment == 512
    with open(os.path.join(d, "store.json")) as f:
        meta = json.load(f)
    assert meta["codec"] == "onpair16" and meta["n_strings"] == len(titles)


def test_store_accepts_artifact_directly(titles, artifacts):
    art, corpus = artifacts["onpair16"]
    store = CompressedStringStore(art, corpus, cache_bytes=0)
    assert store.get(12) == titles[12]
    assert store.artifact is art


def test_store_rejects_non_token_codec(titles, artifacts):
    art, corpus = artifacts["lz-block"]
    with pytest.raises(ValueError):
        CompressedStringStore(art, corpus)


def test_store_build_by_codec_name(titles):
    store = CompressedStringStore.build(titles, codec="bpe",
                                        sample_bytes=1 << 16)
    assert store.compressor.name == "bpe"
    assert store.get(3) == titles[3]


# --------------------------------------------------------- sharded persistence
def test_plan_shards_covers_everything():
    assert plan_shards(10, 4, 3) == [(0, 4), (4, 8), (8, 10)]
    assert plan_shards(3, 10, 5) == [(0, 3)]       # never more shards than segs
    assert plan_shards(0, 4, 2) == [(0, 0)]
    with pytest.raises(ValueError):
        plan_shards(10, 4, 0)


def test_sharded_store_roundtrip(titles, tmp_path):
    store = CompressedStringStore.build(titles, sample_bytes=SAMPLE,
                                        strings_per_segment=256)
    d = str(tmp_path / "shards")
    bounds = save_sharded(store, d, 4)
    assert bounds[0][0] == 0 and bounds[-1][1] == len(titles)
    sharded = ShardedStringStore.open(d)
    rng = np.random.default_rng(5)
    ids = rng.integers(0, len(titles), 600).tolist()
    assert sharded.multiget(ids) == store.multiget(ids)
    assert sharded.get(0) == titles[0]
    with pytest.raises(IndexError):
        sharded.get(len(titles))


# -------------------------------------------------------------- pack_corpus
def test_pack_corpus_single_allocation_matches_join():
    from repro.core.api import pack_corpus
    parts = [b"", b"abc", b"\x00" * 40, b"z"]
    corpus = pack_corpus(parts, raw_bytes=44)
    assert corpus.payload.tobytes() == b"".join(parts)
    np.testing.assert_array_equal(corpus.offsets, [0, 0, 3, 43, 44])
    assert pack_corpus([], 0).payload.size == 0
