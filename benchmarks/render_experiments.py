"""Splice generated tables into EXPERIMENTS.md placeholders.

  PYTHONPATH=src python -m benchmarks.render_experiments
Replaces <!-- DRYRUN_TABLE -->, <!-- ROOFLINE_BASELINE -->,
<!-- ROOFLINE_FINAL --> with tables built from results/dryrun records.
"""

from __future__ import annotations

import os

from repro.launch.roofline import fmt_row, load_records

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def _records(mesh: str, tag: str) -> list[dict]:
    return [r for r in load_records(mesh) if r.get("tag", "") == tag]


def dryrun_table() -> str:
    lines = ["| arch | shape | 16x16 | 2x16x16 | per-dev args+temp (GiB, 16x16) | compile (s) |",
             "|---|---|---|---|---|---|"]
    single = {(r["arch"], r["shape"]): r for r in _records("16x16", "final")}
    multi = {(r["arch"], r["shape"]): r for r in _records("2x16x16", "final")}
    for key in sorted(single):
        s = single[key]
        m = multi.get(key)
        mem = s.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / (1 << 30)
        lines.append(
            f"| {key[0]} | {key[1]} | ok | {'ok' if m else 'pending'} | "
            f"{gib:.2f} | {s.get('compile_s', 0):.0f} |")
    lines.append(f"\n{len(single)}/34 single-pod and {len(multi)}/34 "
                 "multi-pod cells compiled (tag=final).")
    return "\n".join(lines)


def roofline_table(tag: str) -> str:
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "bottleneck | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in sorted(_records("16x16", tag),
                      key=lambda r: (r["arch"], r["shape"])):
        r = fmt_row(rec)
        lines.append(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']} | "
                     f"{r['t_memory_s']} | {r['t_collective_s']} | "
                     f"{r['bottleneck']} | {r['useful_ratio']} | "
                     f"{r['roofline_frac']} |")
    return "\n".join(lines)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_BASELINE -->", roofline_table(""))
    text = text.replace("<!-- ROOFLINE_FINAL -->", roofline_table("final"))
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
