"""RPC serving benchmark: loopback multi-process routing vs in-process.

What the socket hop costs: the same sharded directory is served once
through ``connect("shard://<dir>")`` (in-process router) and once through N
spawned ``repro.net`` shard-server processes behind ``connect("tcp://...")``
— the v3 client layer on both sides — and both run the same workloads — batched ``multiget`` (throughput +
per-batch tail latency), single ``get`` (request tail latency; the tcp
form runs pipelined ``get_async`` so the client batcher folds point reads
into bulk multiget RPCs), and Encoder-batched ``extend`` (append
throughput). Child processes run with
``REPRO_NO_JAX=1``: the RPC tier is the numpy-host serving story, and it
keeps spawn time out of the measurement window.

Emits the harness JSON schema (list of row dicts under results/bench).
"""

from __future__ import annotations

import os
import re
import shutil
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import dataset
from repro.client import connect, format_tcp_url
from repro.core.metrics import latency_summary
from repro.distributed import save_sharded
from repro.store import CompressedStringStore

_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)


def _spawn_servers(dir_path: str, n_shards: int):
    env = {**os.environ, "PYTHONPATH": _SRC, "REPRO_NO_JAX": "1"}
    procs, addrs = [], []
    for k in range(n_shards):
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.net",
             os.path.join(dir_path, f"shard-{k:04d}")],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True,
            env=env)
        line = proc.stdout.readline()
        m = re.search(r"SHARD_SERVER_READY port=(\d+)", line)
        if not m:
            for p in procs:
                p.terminate()
            proc.terminate()
            raise RuntimeError(f"shard server {k} never became ready: {line!r}")
        procs.append(proc)
        addrs.append(("127.0.0.1", int(m.group(1))))
    return procs, addrs


def _time_batches(fn, batches) -> list[float]:
    out = []
    for b in batches:
        t0 = time.perf_counter()
        fn(b)
        out.append(time.perf_counter() - t0)
    return out


def _time_pipelined(submit_async, items, window: int = 256):
    """Issue async ops with a bounded in-flight window; returns (per-op
    latencies, wall seconds). This is the path the client batcher
    coalesces: concurrent point gets fold into bulk multiget RPCs instead
    of paying one round-trip each."""
    sem = threading.Semaphore(window)
    done = [0.0] * len(items)
    futs = []

    def _cb(idx, t0):
        def _done(_f):
            done[idx] = time.perf_counter() - t0
            sem.release()
        return _done

    t_start = time.perf_counter()
    for idx, it in enumerate(items):
        sem.acquire()
        t0 = time.perf_counter()
        f = submit_async(it)
        f.add_done_callback(_cb(idx, t0))
        futs.append(f)
    for f in futs:
        f.result()
    return done, time.perf_counter() - t_start


def rpc_bench(size_mib: int, n_queries: int = 5000, batch: int = 256,
              n_singles: int = 1000, n_shards: int = 3, seed: int = 0,
              dataset_name: str = "book_titles") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    store = CompressedStringStore.build(
        strings, sample_bytes=min(size_mib, 4) << 20, seed=seed)
    dir_path = tempfile.mkdtemp(prefix="rpc_bench_")
    rows: list[dict] = []
    try:
        save_sharded(store, dir_path, n_shards)
        rng = np.random.default_rng(seed)
        ids = rng.integers(0, len(strings), n_queries).tolist()
        batches = [ids[k : k + batch] for k in range(0, len(ids), batch)]
        singles = ids[:n_singles]
        appends = [b"rpc-bench-append-%d " % i + strings[i % len(strings)]
                   for i in range(2048)]
        append_batches = [appends[k : k + 512]
                         for k in range(0, len(appends), 512)]

        def row(op: str, transport: str, lat_s: list[float], n: int,
                per: str, rate_key: str) -> dict:
            total = sum(lat_s)
            lat = latency_summary(lat_s)
            return {"dataset": dataset_name, "op": op, "transport": transport,
                    "n": n, "n_shards": n_shards, "latency_per": per,
                    "p50_us": round(lat["p50_us"], 2),
                    "p99_us": round(lat["p99_us"], 2),
                    rate_key: round(n / max(total, 1e-9), 1),
                    "total_s": round(total, 4)}

        # ------------------------------------- in-process form (shard:// url)
        local = connect(f"shard://{dir_path}")
        local.multiget(ids[:batch])  # warm caches/compiles identically
        lat = _time_batches(local.multiget, batches)
        rows.append(row("multiget", "inproc", lat, n_queries, "batch",
                        "lookups_per_s"))
        lat = _time_batches(local.get, singles)
        rows.append(row("get", "inproc", lat, n_singles, "lookup",
                        "lookups_per_s"))
        local.close()
        local_w = connect(f"shard://{dir_path}", writable=True)
        lat = _time_batches(local_w.extend, append_batches)
        rows.append(row("extend-512", "inproc", lat, len(appends), "batch",
                        "strings_per_s"))
        local_w.close()
        # appends stay in memory (no save): the directory the servers open
        # below is byte-identical to the one the in-process run measured

        # ---------------------------------- multi-process form (tcp:// url)
        procs, addrs = _spawn_servers(dir_path, n_shards)
        try:
            dist = connect(format_tcp_url(addrs))
            dist.multiget(ids[:batch])  # warm connections + caches
            lat = _time_batches(dist.multiget, batches)
            rows.append(row("multiget", "rpc", lat, n_queries, "batch",
                            "lookups_per_s"))
            # pipelined singles: get_async + the client-side batcher fold
            # point reads into bulk multiget RPCs — the fixed rpc/get path
            # (sequential blocking gets pay a full round-trip each and sat
            # at ~300 lookups/s)
            lat, wall = _time_pipelined(dist.get_async, singles)
            r = row("get", "rpc", lat, n_singles, "lookup", "lookups_per_s")
            r["lookups_per_s"] = round(n_singles / max(wall, 1e-9), 1)
            r["total_s"] = round(wall, 4)
            r["pipelined"] = True
            r["window"] = 256
            rows.append(r)
            lat = _time_batches(dist.extend, append_batches)
            rows.append(row("extend-512", "rpc", lat, len(appends), "batch",
                            "strings_per_s"))
            # pipelined singles on the WRITE path: append_async + the
            # client-side extend batcher group-commit pending appends into
            # bulk extend RPCs, and the server folds each drained batch
            # into one Encoder pass — the write-side mirror of rpc/get
            pipelined_appends = [b"rpc-bench-gc-%d " % i + appends[i]
                                 for i in range(1024)]
            lat, wall = _time_pipelined(dist.append_async, pipelined_appends)
            r = row("append-pipelined", "rpc", lat, len(pipelined_appends),
                    "append", "strings_per_s")
            r["strings_per_s"] = round(len(pipelined_appends)
                                       / max(wall, 1e-9), 1)
            r["total_s"] = round(wall, 4)
            r["pipelined"] = True
            r["window"] = 256
            rows.append(r)
            dist.close()
        finally:
            for p in procs:
                p.terminate()
    finally:
        shutil.rmtree(dir_path, ignore_errors=True)
    return rows
