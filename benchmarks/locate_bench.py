"""Reverse-lookup benchmark: locate + scan_prefix across deployment shapes.

Measures the queryable-dictionary surface on the same sharded directory
served three ways — directly (in-process :class:`CompressedStringStore`),
through ``connect("shard://<dir>")``, and through ``connect("tcp://...")``
against spawned shard-server processes:

* ``locate-hit``  — batched exact-match lookups of stored strings (encode
  the query once, probe the per-segment fingerprint tables);
* ``locate-miss`` — the same batches perturbed past any match (the miss
  path still pays the encode + per-segment probes);
* ``scan-prefix`` — short-prefix scans through the sorted sidecars,
  ``limit`` hits per query.

Child processes run with ``REPRO_NO_JAX=1``; the first locate on each
backend is a warmup so lazy index construction stays out of the window.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import dataset
from benchmarks.rpc_bench import _spawn_servers, _time_batches
from repro.client import connect, format_tcp_url
from repro.core.metrics import latency_summary
from repro.distributed import save_sharded
from repro.store import CompressedStringStore


def locate_bench(size_mib: int, n_queries: int = 3000, batch: int = 256,
                 n_shards: int = 3, prefix_len: int = 4, limit: int = 64,
                 seed: int = 0,
                 dataset_name: str = "book_titles") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    store = CompressedStringStore.build(
        strings, sample_bytes=min(size_mib, 4) << 20, seed=seed)
    dir_path = tempfile.mkdtemp(prefix="locate_bench_")
    rows: list[dict] = []
    try:
        save_sharded(store, dir_path, n_shards)
        rng = np.random.default_rng(seed)
        ids = rng.integers(0, len(strings), n_queries).tolist()
        hits = [strings[i] for i in ids]
        misses = [s + b"\x00@@miss@@" for s in hits]
        prefixes = [strings[i][:prefix_len] for i in ids[:n_queries // 4]]
        hit_batches = [hits[k:k + batch] for k in range(0, len(hits), batch)]
        miss_batches = [misses[k:k + batch]
                        for k in range(0, len(misses), batch)]

        def row(op: str, transport: str, n: int, lat_s: list[float],
                per: str) -> dict:
            lat = latency_summary(lat_s)
            total_s = sum(lat_s)
            return {"dataset": dataset_name, "op": op, "transport": transport,
                    "n": n, "n_shards": n_shards, "latency_per": per,
                    "p50_us": round(lat["p50_us"], 2),
                    "p99_us": round(lat["p99_us"], 2),
                    "lookups_per_s": round(n / max(total_s, 1e-9), 1),
                    "total_s": round(total_s, 4)}

        def measure(transport: str, locate_batch, scan_prefix) -> None:
            locate_batch(hits[:batch])  # warmup: builds the lazy indexes
            lat = _time_batches(locate_batch, hit_batches)
            rows.append(row("locate-hit", transport, n_queries, lat, "batch"))
            lat = _time_batches(locate_batch, miss_batches)
            rows.append(row("locate-miss", transport, n_queries, lat,
                            "batch"))
            lat = _time_batches(lambda p: scan_prefix(p, limit), prefixes)
            rows.append(row("scan-prefix", transport, len(prefixes), lat,
                            "query"))

        measure("store", store.locate_batch, store.scan_prefix)
        with connect(f"shard://{dir_path}") as client:
            measure("shard", client.locate_batch, client.scan_prefix)
        procs, addrs = _spawn_servers(dir_path, n_shards)
        try:
            with connect(format_tcp_url(addrs)) as client:
                measure("tcp", client.locate_batch, client.scan_prefix)
        finally:
            for p in procs:
                p.terminate()
    finally:
        shutil.rmtree(dir_path, ignore_errors=True)
    return rows
