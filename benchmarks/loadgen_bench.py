"""Loadgen benchmark: the SLO harness driving a spawned local cluster.

One sharded corpus, one 2-shard multi-process cluster, two short runs of
the same workload spec — closed loop (saturating throughput) and open
loop (paced arrivals) — reporting client-achieved rate plus the merged
*server-side* latency percentiles the SLO gate judges. This is the row
set that lets CI gate on server p99, not just throughput.

Emits the harness JSON schema (list of row dicts under results/bench).
"""

from __future__ import annotations

import shutil
import tempfile

from benchmarks.common import dataset
from repro.client import connect
from repro.distributed import save_sharded
from repro.loadgen import (
    LocalCluster,
    WorkloadSpec,
    build_report,
    run_workload,
    snapshot_server_states,
)
from repro.store import CompressedStringStore


def _row(loop: str, spec: WorkloadSpec, report: dict,
         n_shards: int, dataset_name: str) -> dict:
    run, server = report["run"], report["server_latency"]
    return {
        "dataset": dataset_name,
        "loop": loop,
        "transport": "rpc",
        "n_shards": n_shards,
        "concurrency": spec.concurrency,
        "rate_target": spec.rate if loop == "open" else None,
        "n": run["ops_issued"],
        "duration_s": run["duration_s"],
        "ops_s": run["achieved_rate"],
        "error_rate": run["error_rate"],
        "server_p50_us": server["p50_us"],
        "server_p99_us": server["p99_us"],
        "server_p999_us": server["p999_us"],
        "client_p99_us": round(run["client_latency"]["p99_us"], 1),
        "goodput_rps": report["goodput"]["rps_under_slo"],
        "goodput_fraction": report["goodput"]["fraction_under_slo"],
        "passed": report["passed"],
    }


def loadgen_bench(size_mib: int, duration_s: float = 4.0,
                  n_shards: int = 2, seed: int = 0,
                  dataset_name: str = "urls") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    store = CompressedStringStore.build(
        strings, sample_bytes=min(size_mib, 4) << 20, seed=seed)
    dir_path = tempfile.mkdtemp(prefix="loadgen_bench_")
    rows: list[dict] = []
    try:
        save_sharded(store, dir_path, n_shards)
        with LocalCluster.spawn(dir_path, n_shards=n_shards) as cluster:
            for loop in ("closed", "open"):
                spec = WorkloadSpec(
                    mix={"get": 0.7, "multiget": 0.3}, seed=seed,
                    loop=loop, concurrency=64, rate=2000.0)
                with connect(cluster.url, **cluster.connect_kw()) as client:
                    client.multiget(list(range(min(256, len(strings)))))
                    before = snapshot_server_states(client)
                    result = run_workload(client, spec, duration_s)
                    after = snapshot_server_states(client)
                    report = build_report(spec, result, before, after,
                                          client=client)
                rows.append(_row(loop, spec, report, n_shards, dataset_name))
    finally:
        shutil.rmtree(dir_path, ignore_errors=True)
    return rows
