"""Shared benchmark machinery: datasets, timing, measurement records."""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import registry
from repro.data.synth import DATASETS, load_dataset

MIB = float(1 << 20)


@dataclass
class Measurement:
    dataset: str
    compressor: str
    ratio: float
    comp_mib_s: float
    decomp_mib_s: float
    access_ns: float
    train_s: float
    dict_total_mib: float
    dict_data_mib: float
    parse_s: float


def measure(name: str, strings: list[bytes], n_queries: int = 20000,
            seed: int = 0, **kw) -> Measurement:
    raw = sum(len(s) for s in strings)
    comp = registry.create(name, **kw)
    stats = comp.train(strings, raw)
    t0 = time.perf_counter()
    corpus = comp.compress(strings)
    parse_s = time.perf_counter() - t0
    comp_total = stats.train_seconds + parse_s

    t0 = time.perf_counter()
    out = comp.decompress_all(corpus)
    dec_s = time.perf_counter() - t0
    assert out == b"".join(strings), f"{name}: roundtrip mismatch"

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(strings), n_queries)
    t0 = time.perf_counter()
    for i in idx:
        comp.access(corpus, int(i))
    access_ns = (time.perf_counter() - t0) / n_queries * 1e9

    return Measurement(
        dataset="?", compressor=name, ratio=corpus.ratio,
        comp_mib_s=raw / MIB / max(comp_total, 1e-9),
        decomp_mib_s=raw / MIB / max(dec_s, 1e-9),
        access_ns=access_ns, train_s=stats.train_seconds,
        dict_total_mib=stats.dict_total_bytes / MIB,
        dict_data_mib=stats.dict_data_bytes / MIB,
        parse_s=parse_s)


_CACHE: dict = {}


def dataset(name: str, target_bytes: int) -> list[bytes]:
    key = (name, target_bytes)
    if key not in _CACHE:
        _CACHE[key] = load_dataset(name, target_bytes)
    return _CACHE[key]


DATASET_NAMES = list(DATASETS)
