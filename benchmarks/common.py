"""Shared benchmark machinery: datasets, timing, measurement records, and
run-stamping helpers (commit sha, decode backend, UTC timestamp) used by
every emitter that writes the BENCH JSON schema."""

from __future__ import annotations

import importlib.util
import os
import subprocess
import time
from dataclasses import dataclass
from datetime import datetime, timezone

import numpy as np

from repro.core import registry
from repro.data.synth import DATASETS, load_dataset

MIB = float(1 << 20)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def commit_sha() -> str:
    """The sha BENCH rows are stamped with: $GITHUB_SHA in CI, ``git
    rev-parse HEAD`` locally, ``"unknown"`` outside a checkout."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    # outside a git checkout (sdist / extracted tree) every failure mode —
    # git missing, rev-parse rc=128, even a git that prints garbage — must
    # fall back to "unknown" rather than crash the caller
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, cwd=REPO, timeout=10)
        if out.returncode != 0:
            return "unknown"
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def decode_backend() -> str:
    """Which decode backend this run exercises: ``pallas`` when jax is
    importable and not opted out via REPRO_NO_JAX, else ``numpy``."""
    if os.environ.get("REPRO_NO_JAX"):
        return "numpy"
    return "pallas" if importlib.util.find_spec("jax") else "numpy"


def utc_timestamp() -> str:
    """ISO-8601 UTC second-resolution timestamp for BENCH rows."""
    return datetime.now(timezone.utc).isoformat(timespec="seconds")


@dataclass
class Measurement:
    dataset: str
    compressor: str
    ratio: float
    comp_mib_s: float
    decomp_mib_s: float
    access_ns: float
    train_s: float
    dict_total_mib: float
    dict_data_mib: float
    parse_s: float


def measure(name: str, strings: list[bytes], n_queries: int = 20000,
            seed: int = 0, **kw) -> Measurement:
    raw = sum(len(s) for s in strings)
    comp = registry.create(name, **kw)
    stats = comp.train(strings, raw)
    t0 = time.perf_counter()
    corpus = comp.compress(strings)
    parse_s = time.perf_counter() - t0
    comp_total = stats.train_seconds + parse_s

    t0 = time.perf_counter()
    out = comp.decompress_all(corpus)
    dec_s = time.perf_counter() - t0
    assert out == b"".join(strings), f"{name}: roundtrip mismatch"

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(strings), n_queries)
    t0 = time.perf_counter()
    for i in idx:
        comp.access(corpus, int(i))
    access_ns = (time.perf_counter() - t0) / n_queries * 1e9

    return Measurement(
        dataset="?", compressor=name, ratio=corpus.ratio,
        comp_mib_s=raw / MIB / max(comp_total, 1e-9),
        decomp_mib_s=raw / MIB / max(dec_s, 1e-9),
        access_ns=access_ns, train_s=stats.train_seconds,
        dict_total_mib=stats.dict_total_bytes / MIB,
        dict_data_mib=stats.dict_data_bytes / MIB,
        parse_s=parse_s)


_CACHE: dict = {}


def dataset(name: str, target_bytes: int) -> list[bytes]:
    key = (name, target_bytes)
    if key not in _CACHE:
        _CACHE[key] = load_dataset(name, target_bytes)
    return _CACHE[key]


DATASET_NAMES = list(DATASETS)
