"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per harness spec): us_per_call
is the per-string (or per-query) cost of the benchmark's primary operation;
`derived` carries the table's headline metric.

  PYTHONPATH=src python -m benchmarks.run            # standard (4 MiB/dataset)
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized (1 MiB)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale-ish (16 MiB)
  PYTHONPATH=src python -m benchmarks.run --only table3,kernels
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "results", "bench")


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.3f},{derived}", flush=True)


def _dump(name: str, obj) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def bench_table1(size_mib: int) -> None:
    from benchmarks.paper_tables import table1_dict_size_sweep
    rows = table1_dict_size_sweep(size_mib)
    _dump("table1", rows)
    for r in rows:
        _emit(f"table1/bits{r['bits']}", r["access_ns"] / 1e3,
              f"ratio={r['ratio']};decomp_mib_s={r['decomp_mib_s']};"
              f"dict_mib={r['dict_mib']};tok_len={r['token_len']}")


def bench_table3(size_mib: int) -> None:
    from benchmarks.paper_tables import table3_main_comparison
    rows = table3_main_comparison(size_mib)
    _dump("table3", [vars(m) for m in rows])
    for m in rows:
        _emit(f"table3/{m.dataset}/{m.compressor}", m.access_ns / 1e3,
              f"ratio={m.ratio:.3f};comp_mib_s={m.comp_mib_s:.2f};"
              f"decomp_mib_s={m.decomp_mib_s:.1f}")


def bench_table4(size_mib: int) -> None:
    from benchmarks.paper_tables import table4_dict_footprint
    rows = table4_dict_footprint(size_mib)
    _dump("table4", rows)
    for r in rows:
        _emit(f"table4/{r['dataset']}/{r['compressor']}", 0.0,
              f"total_mib={r['total_mib']};data_mib={r['data_mib']};"
              f"entries={r['entries']}")


def bench_table5(size_mib: int) -> None:
    from benchmarks.paper_tables import table5_train_parse_breakdown
    rows = table5_train_parse_breakdown(size_mib)
    _dump("table5", rows)
    for r in rows:
        _emit(f"table5/{r['dataset']}/{r['compressor']}", 0.0,
              f"training_s={r['training_s']};parsing_s={r['parsing_s']}")


def bench_figures(size_mib: int) -> None:
    from benchmarks import paper_figures as pf
    for name, fn in [("fig2", pf.fig2_threshold_sweep),
                     ("fig3", pf.fig3_gain_by_length),
                     ("fig6", pf.fig6_bucket_sizes),
                     ("fig8", pf.fig8_smoothed_gain),
                     ("fig9", pf.fig9_token_length_distribution),
                     ("fig10", pf.fig10_coverage)]:
        t0 = time.perf_counter()
        rows = fn(size_mib)
        _dump(name, rows)
        head = rows[0] if rows else {}
        tail = rows[-1] if rows else {}
        _emit(name, (time.perf_counter() - t0) * 1e6 / max(1, len(rows)),
              f"first={head};last={tail}".replace(",", ";"))


def bench_kernels(size_mib: int) -> None:
    """OnPair device-codec throughput (jit ref path; Pallas validated in
    interpret mode by tests — interpret timing is not meaningful)."""
    import numpy as np

    from benchmarks.common import dataset
    from repro.core import make_onpair16
    from repro.kernels.ops import OnPairDevice

    strings = dataset("book_titles", max(1, size_mib // 2) << 20)
    comp = make_onpair16(sample_bytes=2 << 20)
    comp.train(strings)
    dev = OnPairDevice(comp.dictionary)
    corpus = comp.compress(strings[:20000])
    tokens = np.asarray(corpus.payload.view("<u2"), dtype=np.int32)
    raw = sum(len(s) for s in strings[:20000])
    # warmup + timed decode
    dev.decode_stream(tokens, use_pallas=False)
    t0 = time.perf_counter()
    out = dev.decode_stream(tokens, use_pallas=False)
    dt = time.perf_counter() - t0
    assert out == b"".join(strings[:20000])
    _emit("kernels/decode_stream_jit", dt / max(1, len(tokens)) * 1e6,
          f"mib_s={raw / (1 << 20) / dt:.1f}")
    batch = strings[:256]
    dev.encode_to_bytes(batch, use_pallas=False)
    t0 = time.perf_counter()
    dev.encode_to_bytes(batch, use_pallas=False)
    dt = time.perf_counter() - t0
    bb = sum(len(s) for s in batch)
    _emit("kernels/encode_batch_jit", dt / len(batch) * 1e6,
          f"mib_s={bb / (1 << 20) / dt:.2f}")


def bench_store(size_mib: int) -> None:
    """repro.store serving path: batched multiget vs naive access loop."""
    from benchmarks.store_bench import store_multiget_bench
    rows = store_multiget_bench(size_mib)
    _dump("store", rows)
    for r in rows:
        us = r["total_s"] / max(1, r["n_queries"]) * 1e6
        _emit(f"store/{r['variant']}/{r['backend']}", us,
              f"lookups_s={r['lookups_per_s']};mib_s={r['mib_s']};"
              f"p50_us={r['p50_us']};p99_us={r['p99_us']};"
              f"per={r['latency_per']}")


def bench_ingest(size_mib: int) -> None:
    """Write path: frozen-dictionary appends + drift-triggered compaction."""
    from benchmarks.store_bench import store_ingest_bench
    rows = store_ingest_bench(size_mib)
    _dump("ingest", rows)
    for r in rows:
        us = r["total_s"] / max(1, r["n_strings"]) * 1e6
        derived = f"strings_s={r['strings_per_s']}"
        if "mib_s" in r:
            derived += f";mib_s={r['mib_s']}"
        if "ratio_after" in r:
            derived += (f";ratio_before={r['ratio_before']};"
                        f"ratio_after={r['ratio_after']};"
                        f"drift={r['drift_at_trigger']}")
        _emit(f"ingest/{r['dataset']}/{r['op']}", us, derived)


def bench_rpc(size_mib: int) -> None:
    """Multi-process shard serving: loopback RPC vs in-process routing."""
    from benchmarks.rpc_bench import rpc_bench
    rows = rpc_bench(size_mib)
    _dump("rpc", rows)
    for r in rows:
        us = r["total_s"] / max(1, r["n"]) * 1e6
        rate = ("lookups_s=" + str(r["lookups_per_s"])
                if "lookups_per_s" in r
                else "strings_s=" + str(r["strings_per_s"]))
        _emit(f"rpc/{r['op']}/{r['transport']}", us,
              f"{rate};p50_us={r['p50_us']};p99_us={r['p99_us']};"
              f"per={r['latency_per']}")


def bench_client(size_mib: int) -> None:
    """Client API v3: one session over shard:// (in-process) and tcp://
    (loopback RPC), sync vs pipelined-async multiget."""
    from benchmarks.client_bench import client_bench
    rows = client_bench(size_mib)
    _dump("client", rows)
    for r in rows:
        us = r["total_s"] / max(1, r["n"]) * 1e6
        _emit(f"client/{r['op']}/{r['transport']}", us,
              f"lookups_s={r['lookups_per_s']};p50_us={r['p50_us']};"
              f"p99_us={r['p99_us']};per={r['latency_per']}")


def bench_locate(size_mib: int) -> None:
    """Reverse lookup: locate hit/miss + scan_prefix over the store
    directly, shard:// and tcp://."""
    from benchmarks.locate_bench import locate_bench
    rows = locate_bench(size_mib)
    _dump("locate", rows)
    for r in rows:
        us = r["total_s"] / max(1, r["n"]) * 1e6
        _emit(f"locate/{r['op']}/{r['transport']}", us,
              f"lookups_s={r['lookups_per_s']};p50_us={r['p50_us']};"
              f"p99_us={r['p99_us']};per={r['latency_per']}")


def bench_loadgen(size_mib: int) -> None:
    """SLO-gated load harness: closed + open loop against a spawned
    2-shard cluster; derived carries the server-side percentiles."""
    from benchmarks.loadgen_bench import loadgen_bench
    rows = loadgen_bench(size_mib, duration_s=2.0 if size_mib <= 1 else 4.0)
    _dump("loadgen", rows)
    for r in rows:
        us = r["duration_s"] / max(1, r["n"]) * 1e6
        _emit(f"loadgen/{r['loop']}/{r['transport']}", us,
              f"ops_s={r['ops_s']};server_p50_us={r['server_p50_us']};"
              f"server_p99_us={r['server_p99_us']};"
              f"goodput_rps={r['goodput_rps']};"
              f"client_p99_us={r['client_p99_us']}")


def bench_tier(size_mib: int) -> None:
    """Tiered storage: memory shed by demotion, RLZ cold-tier ratio, and
    the hot-vs-cold batched read cost (byte-identity asserted inside)."""
    from benchmarks.tier_bench import tier_bench
    rows = tier_bench(size_mib)
    _dump("tier", rows)
    for r in rows:
        op = r["op"]
        if op.startswith("multiget"):
            us = r["total_s"] / max(1, r["n"]) * 1e6
            _emit(f"tier/{op}/store", us,
                  f"lookups_per_s={r['lookups_per_s']};p50_us={r['p50_us']};"
                  f"p99_us={r['p99_us']}")
        elif op == "memory-drop":
            _emit("tier/memory-drop/cold", r["total_s"] * 1e6,
                  f"memory_drop_pct={r['memory_drop_pct']};"
                  f"before_bytes={r['before_bytes']};"
                  f"after_bytes={r['after_bytes']};n_segments={r['n']}")
        else:  # rlz-ratio
            _emit("tier/rlz-ratio/cold", 0.0,
                  f"rlz_ratio={r['rlz_ratio']};raw_bytes={r['raw_bytes']};"
                  f"rlz_bytes={r['rlz_bytes']};"
                  f"segments_per_s={r['segments_per_s']}")


def bench_persist(size_mib: int) -> None:
    """Artifact save/load + store.open latency vs retrain-from-scratch."""
    from benchmarks.persist_bench import persist_bench
    rows = persist_bench(size_mib)
    _dump("persist", rows)
    for r in rows:
        _emit(f"persist/{r['dataset']}/{r['codec']}", r["open_s"] * 1e6,
              f"speedup_vs_retrain={r['speedup_vs_retrain']};"
              f"train_s={r['train_s']};save_s={r['save_s']};"
              f"disk_mib={r['disk_bytes'] / (1 << 20):.2f}")


def bench_roofline(_size_mib: int) -> None:
    """Surface the dry-run roofline summary as bench rows."""
    from repro.launch.roofline import fmt_row, load_records
    for mesh in ("16x16", "2x16x16"):
        for rec in load_records(mesh):
            if rec.get("tag") not in ("", "final"):
                continue
            r = fmt_row(rec)
            tag = rec.get("tag") or "baseline"
            _emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}/{tag}",
                  max(r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"]) * 1e6,
                  f"bottleneck={r['bottleneck']};frac={r['roofline_frac']}")


ALL = {
    "table1": bench_table1,
    "table3": bench_table3,
    "table4": bench_table4,
    "table5": bench_table5,
    "figures": bench_figures,
    "kernels": bench_kernels,
    "store": bench_store,
    "ingest": bench_ingest,
    "persist": bench_persist,
    "rpc": bench_rpc,
    "client": bench_client,
    "locate": bench_locate,
    "loadgen": bench_loadgen,
    "tier": bench_tier,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    size = 1 if args.quick else (16 if args.full else 4)
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(ALL)
    print("name,us_per_call,derived")
    for name in names:
        ALL[name](size)


if __name__ == "__main__":
    main()
