"""Measure the cross-pod collective saving of int8+EF gradient compression.

Lowers both a plain f32 pmean and `compressed_pmean` over the 'pod' axis of
the multi-pod production mesh (abstract inputs — no allocation) and compares
collective payload bytes from the compiled HLO.

  PYTHONPATH=src python -m benchmarks.grad_compress_bench
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compress import init_error_feedback
from repro.distributed.sharding import use_mesh
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

# per-pod-distinct gradients: leading dim 2 sharded over 'pod' so each pod
# holds its own 140M-value shard and the reduction is a real collective
GRADS = {
    "wq": jax.ShapeDtypeStruct((2, 64, 4096, 128), jnp.float32),
    "mlp": jax.ShapeDtypeStruct((2, 64, 4096, 344), jnp.float32),
    "embed": jax.ShapeDtypeStruct((2, 64000, 256), jnp.float32),
}


def main() -> None:
    mesh = make_production_mesh(multi_pod=True)
    ef = jax.eval_shape(partial(init_error_feedback), GRADS)

    with use_mesh(mesh):
        from jax.experimental.shard_map import shard_map
        specs = jax.tree.map(
            lambda x: P("pod", *([None] * (len(x.shape) - 1))), GRADS)

        @partial(shard_map, mesh=mesh, in_specs=(specs,), out_specs=specs,
                 check_rep=False)
        def plain(t):
            return jax.tree.map(lambda g: jax.lax.pmean(g, "pod"), t)

        from repro.distributed import compress as _c

        @partial(shard_map, mesh=mesh, in_specs=(specs, specs),
                 out_specs=(specs, specs), check_rep=False)
        def comp(t, e):
            flat_t, tdef = jax.tree_util.tree_flatten(t)
            flat_e = tdef.flatten_up_to(e)
            out = [_c._compressed_psum_leaf(g, ef_, "pod", 2)
                   for g, ef_ in zip(flat_t, flat_e)]
            return (tdef.unflatten([o[0] for o in out]),
                    tdef.unflatten([o[1] for o in out]))

        plain_c = jax.jit(plain).lower(GRADS).compile()
        comp_c = jax.jit(comp).lower(GRADS, ef).compile()

    a = analyze_hlo(plain_c.as_text())
    b = analyze_hlo(comp_c.as_text())
    total = sum(
        int(jnp.prod(jnp.array(v.shape))) * 4 for v in GRADS.values())
    print("name,us_per_call,derived")
    print(f"grad_compress/plain_pmean,0,collective_bytes={a['collective_bytes']:.3e}")
    print(f"grad_compress/int8_ef_pmean,0,collective_bytes={b['collective_bytes']:.3e}")
    ratio = a["collective_bytes"] / max(b["collective_bytes"], 1)
    print(f"grad_compress/saving,0,ratio={ratio:.2f}x;payload_f32={total:.3e}B")


if __name__ == "__main__":
    main()
