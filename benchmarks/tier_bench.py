"""Tiered-storage benchmark: what demotion buys and what cold reads cost.

Builds a payload-dominated store (small training sample so segment bytes
dwarf the dictionary's fixed resident cost — the regime tiering is for),
demotes every sealed segment to the RLZ cold tier, and measures:

* ``memory-drop`` — ``memory_bytes`` shed by majority demotion, as a
  percentage. This is the acceptance gate: a majority-demoted store must
  answer every read byte-identically while resident memory falls >= 40%.
* ``rlz-ratio`` — raw corpus bytes over the cold tier's factor-array
  bytes (how well RLZ-vs-dictionary compresses relative to raw).
* ``multiget-hot`` / ``multiget-cold`` — the same uniform batched read
  mix against the all-hot and the all-cold store, cache disabled, so the
  cold-read tax is visible rather than hidden behind the LRU.
* ``demote`` — segments/s for the re-encode + container write itself.

Byte-identity is asserted inside the bench — a run that answers wrong
bytes crashes instead of reporting a great number.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset
from repro.core.metrics import latency_summary
from repro.store import CompressedStringStore

#: training sample: deliberately small (256 KiB) so the dictionary stays a
#: minority of the resident footprint at every bench size
_SAMPLE = 1 << 18


def tier_bench(size_mib: int, n_queries: int = 4000, batch: int = 64,
               seed: int = 0,
               dataset_name: str = "book_titles") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    n = len(strings)
    store = CompressedStringStore.build(
        strings, sample_bytes=_SAMPLE, seed=seed, cache_bytes=0)
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n, n_queries).tolist()
    batches = [ids[k:k + batch] for k in range(0, n_queries, batch)]
    expected = [[strings[i] for i in b] for b in batches]

    def measure(op: str) -> dict:
        lat = []
        for b, want in zip(batches, expected):
            t0 = time.perf_counter()
            got = store.multiget(b)
            lat.append(time.perf_counter() - t0)
            assert got == want, f"{op}: wrong bytes for batch"
        s = latency_summary(lat)
        total = sum(lat)
        return {"dataset": dataset_name, "op": op, "n": n_queries,
                "p50_us": round(s["p50_us"], 2),
                "p99_us": round(s["p99_us"], 2),
                "lookups_per_s": round(n_queries / max(total, 1e-9), 1),
                "total_s": round(total, 4)}

    rows = [measure("multiget-hot")]

    before = store.memory_bytes
    tier = store.enable_tiering(promote_above=1e9)  # pin cold under load
    t0 = time.perf_counter()
    reports = [r for r in (tier.demote(s.index)
                           for s in store.segments.segments)
               if r is not None]
    demote_s = time.perf_counter() - t0
    after = store.memory_bytes
    assert len(tier.cold) > store.segments.n_segments // 2, "not majority cold"

    drop_pct = 100.0 * (before - after) / max(before, 1)
    raw_bytes = sum(r["raw_bytes"] for r in reports)
    rlz_bytes = sum(r["rlz_bytes"] for r in reports)
    rows.append({"dataset": dataset_name, "op": "memory-drop",
                 "n": len(reports), "before_bytes": before,
                 "after_bytes": after,
                 "memory_drop_pct": round(drop_pct, 2),
                 "total_s": round(demote_s, 4)})
    rows.append({"dataset": dataset_name, "op": "rlz-ratio",
                 "n": len(reports), "raw_bytes": raw_bytes,
                 "rlz_bytes": rlz_bytes,
                 "rlz_ratio": round(raw_bytes / max(rlz_bytes, 1), 3),
                 "segments_per_s": round(len(reports) / max(demote_s, 1e-9),
                                         1)})
    rows.append(measure("multiget-cold"))
    snap = store.stats_snapshot()["tier"]
    assert snap["n_cold"] == len(reports)
    return rows
