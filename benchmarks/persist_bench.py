"""Persistence benchmark: artifact save/load + store open vs retraining.

The point of the v2 artifact split is that a dictionary is trained once and
then *opened*, not retrained, on every serving host. This benchmark puts
numbers on that seam, per codec:

* ``train``      — train + compress + open from scratch (the only option
                   before artifacts existed);
* ``save``       — artifact.save + corpus.save + store.save wall time;
* ``open``       — CompressedStringStore.open(dir): mmap artifact + corpus,
                   rebuild derived decode tables, ready to serve;
* ``speedup``    — train_s / open_s (how much a restart stops costing).

Every opened store is checked byte-identical against the in-memory one on a
sample of ids before its row is emitted.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import dataset
from repro.core.artifact import DictArtifact
from repro.store import CompressedStringStore


def persist_bench(size_mib: int, codecs=("onpair16", "onpair", "bpe"),
                  dataset_name: str = "book_titles",
                  n_check: int = 500, seed: int = 0) -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    rng = np.random.default_rng(seed)
    check_ids = rng.integers(0, len(strings), n_check).tolist()
    rows: list[dict] = []
    for codec in codecs:
        t0 = time.perf_counter()
        store = CompressedStringStore.build(
            strings, codec=codec, sample_bytes=min(size_mib, 4) << 20,
            seed=seed)
        train_s = time.perf_counter() - t0
        expect = store.multiget(check_ids)

        tmp = tempfile.mkdtemp(prefix=f"persist-{codec}-")
        try:
            t0 = time.perf_counter()
            store.save(tmp)
            save_s = time.perf_counter() - t0
            disk = sum(os.path.getsize(os.path.join(tmp, f))
                       for f in os.listdir(tmp))

            t0 = time.perf_counter()
            art = DictArtifact.load(
                os.path.join(tmp, CompressedStringStore._DICT_FILE))
            art_load_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            reopened = CompressedStringStore.open(tmp)
            open_s = time.perf_counter() - t0
            assert reopened.multiget(check_ids) == expect, codec
            rows.append({
                "dataset": dataset_name, "codec": codec,
                "n_strings": len(strings),
                "dict_entries": art.num_entries,
                "disk_bytes": disk,
                "train_s": round(train_s, 4),
                "save_s": round(save_s, 4),
                "artifact_load_s": round(art_load_s, 5),
                "open_s": round(open_s, 4),
                "speedup_vs_retrain": round(train_s / max(open_s, 1e-9), 1),
            })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows
