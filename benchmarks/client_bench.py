"""Client API v3 benchmark: one session layer, every deployment shape.

The same sharded directory is served through ``connect("shard://<dir>")``
(in-process router) and ``connect("tcp://host:port,...")`` (spawned
shard-server processes), and both backends run identical workloads through
the identical :class:`~repro.client.session.StoreClient` surface:

* ``multiget``        — sequential batched lookups (sync path);
* ``multiget-async8`` — the same batches with 8 futures pipelined through
  the session's async path (local executor + router fan-out / socket pool),
  which is where the client layer earns its keep on the RPC transport.

Child processes run with ``REPRO_NO_JAX=1`` (numpy serving hosts; spawn
time stays out of the measurement window). Emits the harness JSON schema.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import dataset
from benchmarks.rpc_bench import _spawn_servers, _time_batches
from repro.client import connect, format_tcp_url
from repro.core.metrics import latency_summary
from repro.distributed import save_sharded
from repro.store import CompressedStringStore


def _pipeline_batches(client, batches, depth: int):
    """Keep ``depth`` multiget futures in flight; returns (per-future
    submit->result latencies, wall seconds)."""
    lats: list[float] = []
    pending: list[tuple[float, object]] = []

    def _drain_one() -> None:
        t0, fut = pending.pop(0)
        fut.result(60)
        lats.append(time.perf_counter() - t0)

    t_start = time.perf_counter()
    for b in batches:
        pending.append((time.perf_counter(), client.multiget_async(b)))
        if len(pending) >= depth:
            _drain_one()
    while pending:
        _drain_one()
    return lats, time.perf_counter() - t_start


def client_bench(size_mib: int, n_queries: int = 5000, batch: int = 256,
                 n_shards: int = 3, depth: int = 8, seed: int = 0,
                 dataset_name: str = "book_titles") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    store = CompressedStringStore.build(
        strings, sample_bytes=min(size_mib, 4) << 20, seed=seed)
    dir_path = tempfile.mkdtemp(prefix="client_bench_")
    rows: list[dict] = []
    try:
        save_sharded(store, dir_path, n_shards)
        rng = np.random.default_rng(seed)
        ids = rng.integers(0, len(strings), n_queries).tolist()
        batches = [ids[k : k + batch] for k in range(0, len(ids), batch)]

        def row(op: str, transport: str, lat_s: list[float],
                total_s: float) -> dict:
            lat = latency_summary(lat_s)
            return {"dataset": dataset_name, "op": op, "transport": transport,
                    "n": n_queries, "n_shards": n_shards,
                    "latency_per": "batch",
                    "p50_us": round(lat["p50_us"], 2),
                    "p99_us": round(lat["p99_us"], 2),
                    "lookups_per_s": round(n_queries / max(total_s, 1e-9), 1),
                    "total_s": round(total_s, 4)}

        def measure(transport: str, url: str) -> None:
            with connect(url) as client:
                client.multiget(ids[:batch])  # warm caches/connections
                lat = _time_batches(client.multiget, batches)
                rows.append(row("multiget", transport, lat, sum(lat)))
                lat, wall = _pipeline_batches(client, batches, depth)
                rows.append(row(f"multiget-async{depth}", transport, lat,
                                wall))

        measure("shard", f"shard://{dir_path}")
        procs, addrs = _spawn_servers(dir_path, n_shards)
        try:
            measure("tcp", format_tcp_url(addrs))
        finally:
            for p in procs:
                p.terminate()
    finally:
        shutil.rmtree(dir_path, ignore_errors=True)
    return rows
