"""One benchmark per paper table.

Table 1 — OnPair dictionary-size sweep (bits/token 9..17) on Book Titles.
Table 3 — main comparison: {raw, zlib, zstd, bpe, fsst, onpair, onpair16}
          x 5 datasets: ratio / comp / decomp / access.
Table 4 — dictionary memory footprint.
Table 5 — training vs parsing time breakdown.

Synthetic analogue datasets (repro.data.synth) stand in for the paper's
corpora; absolute MiB/s are Python-harness-scale but the *orderings and
ratios* are the reproduced claims (EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import DATASET_NAMES, MIB, dataset, measure
from repro.core import OnPairCompressor, OnPairConfig
from repro.core.metrics import avg_token_length


def table1_dict_size_sweep(size_mib: int = 4, bits_range=range(9, 18)):
    """name,us_per_call,derived CSV rows; derived = ratio@bits."""
    strings = dataset("book_titles", size_mib << 20)
    raw = sum(map(len, strings))
    rows = []
    for bits in bits_range:
        cfg = OnPairConfig.onpair(max_tokens=1 << bits, threshold=2,
                                  sample_bytes=8 << 20)
        comp = OnPairCompressor(cfg)
        st = comp.train(strings, raw)
        t0 = time.perf_counter()
        corpus = comp.compress(strings)
        comp_s = st.train_seconds + time.perf_counter() - t0
        t0 = time.perf_counter()
        out = comp.decompress_all(corpus)
        dec_s = time.perf_counter() - t0
        assert out == b"".join(strings)
        rng = np.random.default_rng(0)
        idx = rng.integers(0, len(strings), 5000)
        t0 = time.perf_counter()
        for i in idx:
            comp.access(corpus, int(i))
        acc_ns = (time.perf_counter() - t0) / 5000 * 1e9
        tokens = np.asarray(corpus.payload.view("<u2"))
        rows.append({
            "bits": bits, "ratio": round(corpus.ratio, 3),
            "comp_mib_s": round(raw / MIB / comp_s, 2),
            "decomp_mib_s": round(raw / MIB / dec_s, 1),
            "access_ns": round(acc_ns),
            "dict_mib": round(st.dict_total_bytes / MIB, 4),
            "token_len": round(avg_token_length(comp.dictionary, tokens), 2),
        })
    return rows


def table3_main_comparison(size_mib: int = 4,
                           compressors=("raw", "zlib-block", "zstd-block",
                                        "bpe", "fsst", "onpair", "onpair16"),
                           datasets=None):
    rows = []
    for ds in datasets or DATASET_NAMES:
        strings = dataset(ds, size_mib << 20)
        for name in compressors:
            m = measure(name, strings)
            m.dataset = ds
            rows.append(m)
    return rows


def table4_dict_footprint(size_mib: int = 4, datasets=None):
    rows = []
    for ds in datasets or DATASET_NAMES:
        strings = dataset(ds, size_mib << 20)
        raw = sum(map(len, strings))
        for name in ("onpair", "onpair16"):
            from repro.core import registry
            comp = registry.create(name)
            st = comp.train(strings, raw)
            rows.append({"dataset": ds, "compressor": name,
                         "total_mib": round(st.dict_total_bytes / MIB, 3),
                         "data_mib": round(st.dict_data_bytes / MIB, 3),
                         "entries": st.dict_entries})
    return rows


def table5_train_parse_breakdown(size_mib: int = 4, datasets=None):
    rows = []
    for ds in datasets or DATASET_NAMES:
        strings = dataset(ds, size_mib << 20)
        for name in ("onpair", "onpair16"):
            m = measure(name, strings, n_queries=100)
            rows.append({"dataset": ds, "compressor": name,
                         "training_s": round(m.train_s, 3),
                         "parsing_s": round(m.parse_s, 3)})
    return rows
