"""One benchmark per paper figure (data series, printed as CSV).

Fig 2  — compression ratio & training-data volume vs pair threshold (2..30).
Fig 3  — cumulative gain & frequency by token length.
Fig 6  — bucket-size distribution of OnPair16's long-pattern LPM.
Fig 8  — smoothed token gain by token id (moving average, 1% window).
Fig 9  — token length distribution: FSST vs OnPair16.
Fig 10 — cumulative token coverage vs dictionary memory footprint.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset
from repro.core import (FSSTCompressor, OnPairCompressor, OnPairConfig,
                        make_onpair, make_onpair16)
from repro.core.metrics import (bucket_size_histogram, cumulative_coverage,
                                gain_by_length, gain_by_token)


def fig2_threshold_sweep(size_mib: int = 4, thresholds=(2, 4, 8, 12, 16, 22, 30)):
    strings = dataset("book_titles", size_mib << 20)
    raw = sum(map(len, strings))
    rows = []
    for thr in thresholds:
        comp = OnPairCompressor(OnPairConfig.onpair(threshold=thr,
                                                    sample_bytes=64 << 20))
        comp.train(strings, raw)
        corpus = comp.compress(strings)
        rows.append({"threshold": thr, "ratio": round(corpus.ratio, 3),
                     "train_data_mib": round(
                         comp.train_result.scanned_bytes / (1 << 20), 3)})
    return rows


def _trained16(size_mib=4):
    strings = dataset("book_titles", size_mib << 20)
    comp = make_onpair16()
    comp.train(strings, sum(map(len, strings)))
    corpus = comp.compress(strings)
    tokens = np.asarray(corpus.payload.view("<u2"))
    return strings, comp, corpus, tokens


def fig3_gain_by_length(size_mib: int = 4):
    strings = dataset("book_titles", size_mib << 20)
    comp = make_onpair()
    comp.train(strings, sum(map(len, strings)))
    corpus = comp.compress(strings)
    tokens = np.asarray(corpus.payload.view("<u2"))
    table = gain_by_length(comp.dictionary, tokens)
    total_gain = sum(max(v["gain"], 0) for v in table.values()) or 1
    total_freq = sum(v["freq"] for v in table.values()) or 1
    rows, cg, cf = [], 0, 0
    for L in sorted(table):
        cg += max(table[L]["gain"], 0)
        cf += table[L]["freq"]
        rows.append({"token_len": L,
                     "cum_gain_frac": round(cg / total_gain, 4),
                     "cum_freq_frac": round(cf / total_freq, 4)})
    return rows


def fig6_bucket_sizes(size_mib: int = 4):
    _, comp, _, _ = _trained16(size_mib)
    hist = bucket_size_histogram(comp.dictionary)
    total = sum(hist.values()) or 1
    cum = 0
    rows = []
    for size in sorted(hist):
        cum += hist[size]
        rows.append({"bucket_size": size, "count": hist[size],
                     "cum_frac": round(cum / total, 4)})
    return rows


def fig8_smoothed_gain(size_mib: int = 4):
    strings = dataset("book_titles", size_mib << 20)
    comp = make_onpair()
    comp.train(strings, sum(map(len, strings)))
    corpus = comp.compress(strings)
    tokens = np.asarray(corpus.payload.view("<u2"))
    gains = gain_by_token(comp.dictionary, tokens).astype(np.float64)
    w = max(8, len(gains) // 100)
    kernel = np.ones(w) / w
    smooth = np.convolve(gains, kernel, mode="valid")
    step = max(1, len(smooth) // 64)
    return [{"token_id": int(i), "smoothed_gain": round(float(smooth[i]), 2)}
            for i in range(0, len(smooth), step)]


def fig9_token_length_distribution(size_mib: int = 4):
    strings, comp16, corpus16, tokens16 = _trained16(size_mib)
    lens16 = comp16.dictionary.lens[tokens16]
    f = FSSTCompressor()
    f.train(strings, sum(map(len, strings)))
    cf = f.compress(strings)
    # FSST decode lengths per code unit
    starts = np.ones(len(cf.payload), dtype=bool)
    from repro.core.fsst import _unit_starts
    starts = _unit_starts(cf.payload)
    toks = cf.payload[starts]
    import numpy as _np
    flens = _np.where(toks == 255, 1, f._lens[toks.astype(_np.int64)])
    rows = []
    for L in range(1, 17):
        rows.append({"token_len": L,
                     "onpair16_frac": round(float((lens16 == L).mean()), 4),
                     "fsst_frac": round(float((flens == L).mean()), 4)})
    avg16 = float(lens16.mean())
    avgf = float(flens.mean())
    rows.append({"token_len": "avg", "onpair16_frac": round(avg16, 3),
                 "fsst_frac": round(avgf, 3)})
    return rows


def fig10_coverage(size_mib: int = 4,
                   marks=(16 << 10, 32 << 10, 64 << 10, 128 << 10, 256 << 10)):
    _, comp, _, tokens = _trained16(size_mib)
    mem, cov = cumulative_coverage(comp.dictionary, tokens)
    rows = []
    for m in marks:
        i = int(np.searchsorted(mem, m))
        if i >= len(cov):
            i = len(cov) - 1
        rows.append({"dict_kib": m >> 10, "coverage": round(float(cov[i]), 4)})
    return rows
