"""Store serving benchmark: batched multiget vs the naive per-string loop.

Measures, over uniform random ids on one dataset:

* ``naive``      — per-string ``OnPairCompressor.access`` loop (the paper's
                   random-access microbenchmark, one string per call);
* ``store-*``    — ``CompressedStringStore.multiget`` in serving-sized
                   batches through each available backend (cache disabled so
                   the decode path is what's timed).

Emits the harness JSON schema (list of row dicts under results/bench) with
throughput (lookups/s, MiB/s) and p50/p99 latency per batch from
``repro.core.metrics.latency_summary``.
"""

from __future__ import annotations

import gc
import os
import time

import numpy as np

from benchmarks.common import dataset
from repro.client import wrap
from repro.core.metrics import latency_summary, throughput_mib_s
from repro.store import CompressedStringStore


def _time_batches(fn, batches) -> list[float]:
    out = []
    for b in batches:
        t0 = time.perf_counter()
        fn(b)
        out.append(time.perf_counter() - t0)
    return out


def store_multiget_bench(size_mib: int, n_queries: int = 20000,
                         batch: int = 1024, seed: int = 0,
                         dataset_name: str = "book_titles") -> list[dict]:
    strings = dataset(dataset_name, size_mib << 20)
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, len(strings), n_queries).tolist()
    raw_bytes = sum(len(strings[i]) for i in ids)
    batches = [ids[k : k + batch] for k in range(0, len(ids), batch)]
    rows: list[dict] = []

    def row(variant: str, backend: str, lat_s: list[float], per: str) -> dict:
        total = sum(lat_s)
        lat = latency_summary(lat_s)
        return {
            "dataset": dataset_name, "variant": variant, "backend": backend,
            "n_queries": n_queries, "batch": batch,
            "latency_per": per,
            "p50_us": round(lat["p50_us"], 2),
            "p99_us": round(lat["p99_us"], 2),
            "lookups_per_s": round(n_queries / total, 1),
            "mib_s": round(throughput_mib_s(raw_bytes, total), 2),
            "total_s": round(total, 4),
        }

    for variant16 in (True, False):
        variant = "onpair16" if variant16 else "onpair"
        store = CompressedStringStore.build(
            strings, variant16=variant16, sample_bytes=min(size_mib, 4) << 20,
            seed=seed, cache_bytes=0)
        comp, corpus = store.compressor, store.corpus

        # naive loop: one access() per id (per-call latency samples)
        lat = _time_batches(lambda b: [comp.access(corpus, i) for i in b],
                            [[i] for i in ids])
        rows.append(row(f"{variant}/naive-access", "numpy", lat, "lookup"))

        backends = ["numpy"] + (["jax"] if store.backend == "jax" else [])
        for backend in backends:
            s = CompressedStringStore(comp, corpus, cache_bytes=0,
                                      backend=backend)
            # measured through the v3 session layer (what a caller actually
            # holds); sync multigets ride the client's micro-batching service
            with wrap(s) as client:
                client.multiget(ids[:batch])  # warmup: trigger jit compiles
                lat = _time_batches(client.multiget, batches)
            r = row(f"{variant}/store-multiget", backend, lat, "batch")
            r["jit_shapes"] = [list(x) for x in sorted(s.stats.jit_shapes)]
            rows.append(r)
    return rows


def store_ingest_bench(size_mib: int, seed: int = 0,
                       dataset_name: str = "urls",
                       drift_dataset: str = "book_titles") -> list[dict]:
    """Write-path benchmark: frozen-dictionary append throughput (single and
    Encoder-batched), seal cost amortisation, and a full drift->compact
    cycle (append a different distribution until the monitor trips, then
    time the re-train + rewrite and report the ratio recovery)."""
    from repro.core import registry
    from repro.store.mutable import MutableStringStore

    strings = dataset(dataset_name, size_mib << 20)
    half = len(strings) // 2
    base, incoming = strings[:half], strings[half:]
    art = registry.train("onpair16", base,
                         sample_bytes=min(size_mib, 4) << 20, seed=seed)
    codec = registry.codec_from_artifact(art)  # tables built once, shared
    rows: list[dict] = []

    def build() -> MutableStringStore:
        return MutableStringStore((art, codec), codec.compress(base),
                                  strings_per_segment=4096, cache_bytes=0,
                                  drift_threshold=0.2)

    # single-string appends (per-call parse + tail update), measured through
    # the session layer's write path (client.append -> service -> store)
    store = build()
    one_by_one = incoming[: min(5000, len(incoming))]
    with wrap(store) as client:
        t0 = time.perf_counter()
        for s in one_by_one:
            client.append(s)
        dt = time.perf_counter() - t0
    raw = sum(len(s) for s in one_by_one)
    rows.append({"dataset": dataset_name, "op": "append",
                 "n_strings": len(one_by_one), "total_s": round(dt, 4),
                 "strings_per_s": round(len(one_by_one) / dt, 1),
                 "mib_s": round(throughput_mib_s(raw, dt), 2)})

    # batched appends (one Encoder pass per batch, seals amortised). The
    # collect isolates this phase from the append bench's allocator debris
    # (5000 per-call appends leave enough garbage to cost ~15% here).
    store = build()
    gc.collect()
    with wrap(store) as client:
        t0 = time.perf_counter()
        for k in range(0, len(incoming), 1024):
            client.extend(incoming[k : k + 1024])
        dt = time.perf_counter() - t0
    raw = sum(len(s) for s in incoming)
    rows.append({"dataset": dataset_name, "op": "extend-1024",
                 "n_strings": len(incoming), "total_s": round(dt, 4),
                 "strings_per_s": round(len(incoming) / dt, 1),
                 "mib_s": round(throughput_mib_s(raw, dt), 2),
                 "n_segments": store.segments.n_segments,
                 "tail": store.stats_snapshot()["n_tail_strings"]})

    # pallas-backend encode row, reported alongside the numpy rows but never
    # baseline-gated: it is absent on REPRO_NO_JAX hosts (the CI smoke), and
    # this container runs the kernel in interpret mode, so n stays small
    try:
        if os.environ.get("REPRO_NO_JAX"):
            raise ImportError("REPRO_NO_JAX is set")
        from repro.kernels.ops import OnPairDevice  # noqa: F401
        have_pallas = True
    except Exception:
        have_pallas = False
    if have_pallas:
        store = MutableStringStore((art, codec), codec.compress(base),
                                   strings_per_segment=4096, cache_bytes=0,
                                   encode_backend="pallas")
        small = incoming[:256]
        t0 = time.perf_counter()
        store.extend(small)
        dt = time.perf_counter() - t0
        raw = sum(len(s) for s in small)
        rows.append({"dataset": dataset_name, "op": "extend-pallas-256",
                     "n_strings": len(small), "total_s": round(dt, 4),
                     "strings_per_s": round(len(small) / dt, 1),
                     "mib_s": round(throughput_mib_s(raw, dt), 2)})

    # drift -> compact cycle: append a different distribution, then rewrite
    drifted = dataset(drift_dataset, min(size_mib, 2) << 20)
    store.extend(drifted)
    snap = store.drift.snapshot()
    report = store.compact()
    rows.append({"dataset": f"{dataset_name}+{drift_dataset}", "op": "compact",
                 "n_strings": report["n_strings"],
                 "total_s": report["total_s"], "train_s": report["train_s"],
                 "strings_per_s": round(report["n_strings"]
                                        / max(report["total_s"], 1e-9), 1),
                 "drift_at_trigger": snap["drift"],
                 "ratio_before": report["ratio_before"],
                 "ratio_after": report["ratio_after"]})
    return rows
