"""Benchmark smoke runner — the CI perf gate.

Runs ``python benchmarks/run.py`` on tiny configs for the serving-path
benchmarks (store, ingest, persist, rpc, client, loadgen), converts the emitted CSV rows to
the BENCH JSON schema (``{bench, metric, value, unit, commit}`` rows,
written to ``BENCH_smoke.json`` and uploaded as a CI artifact), and fails
on crash or on any metric regressing more than ``--factor`` (default 5x)
against the checked-in ``results/bench/baseline.json``.

Only metrics present in the baseline are gated — the baseline holds a
curated handful of robust throughput numbers (measured on a dev box, then
halved for hardware headroom; the 5x band absorbs CI-runner noise on top).

  PYTHONPATH=src python benchmarks/smoke.py                 # gate + write
  PYTHONPATH=src python benchmarks/smoke.py --update-baseline  # refresh floor
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # `python benchmarks/smoke.py` -> benchmarks pkg
sys.path.insert(0, os.path.join(REPO, "src"))  # repro importable sans PYTHONPATH

from benchmarks.common import (  # noqa: E402
    commit_sha,
    decode_backend,
    utc_timestamp,
)

BASELINE = os.path.join(REPO, "results", "bench", "baseline.json")
SMOKE_BENCHES = "store,ingest,persist,rpc,client,locate,loadgen,tier"

#: derived-CSV keys worth tracking, and their units ("1/s" and "MiB/s" are
#: rates — higher is better; "us" is a latency — lower is better)
RATE_KEYS = {
    "lookups_s": "1/s",
    "lookups_per_s": "1/s",
    "strings_s": "1/s",
    "strings_per_s": "1/s",
    "mib_s": "MiB/s",
    "speedup_vs_retrain": "x",
    "ops_s": "1/s",
    "goodput_rps": "1/s",
    # server-side latency from merged shard histogram states (repro.loadgen)
    # — the p99 gate; lower is better
    "server_p50_us": "us",
    "server_p99_us": "us",
    # tiering: resident-memory shed by majority demotion and the RLZ
    # cold-tier compression ratio — both higher is better
    "memory_drop_pct": "%",
    "rlz_ratio": "x",
}


def run_benchmarks(only: str, quick: bool = True) -> list[str]:
    """Invoke benchmarks/run.py in a child (a crash fails the job) and
    return its CSV lines."""
    env = {**os.environ}
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [sys.executable, os.path.join(REPO, "benchmarks", "run.py")]
    if quick:
        cmd.append("--quick")
    cmd += ["--only", only]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env, cwd=REPO)
    sys.stderr.write(proc.stderr)
    print(proc.stdout)
    if proc.returncode != 0:
        raise SystemExit(f"benchmarks/run.py crashed with rc={proc.returncode}")
    return [ln for ln in proc.stdout.splitlines() if "," in ln]


def rows_from_csv(lines: list[str], commit: str, backend: str = "numpy",
                  timestamp: str | None = None) -> list[dict]:
    """CSV ``name,us_per_call,derived`` -> BENCH schema rows, each stamped
    with the decode ``backend`` and an ISO-8601 UTC ``timestamp`` so runs
    from different hosts/configs stay attributable after aggregation."""
    if timestamp is None:
        timestamp = utc_timestamp()
    rows: list[dict] = []
    for line in lines:
        name, us, derived = line.split(",", 2)
        if name == "name":  # header
            continue
        bench = name.split("/", 1)[0]
        rows.append(
            {
                "bench": bench,
                "metric": f"{name}/us_per_call",
                "value": float(us),
                "unit": "us",
                "commit": commit,
                "backend": backend,
                "timestamp": timestamp,
            }
        )
        for pair in derived.split(";"):
            key, _, val = pair.partition("=")
            if key not in RATE_KEYS:
                continue
            try:
                value = float(val)
            except ValueError:
                continue
            rows.append(
                {
                    "bench": bench,
                    "metric": f"{name}/{key}",
                    "value": value,
                    "unit": RATE_KEYS[key],
                    "commit": commit,
                    "backend": backend,
                    "timestamp": timestamp,
                }
            )
    return rows


def check_regressions(
    rows: list[dict], baseline: list[dict], factor: float
) -> list[str]:
    """Compare against the checked-in floor; returns failure messages.

    A baseline row may carry its own ``factor`` (e.g. a wider band for a
    noisy tail-latency metric); otherwise the global ``--factor`` applies.
    """
    current = {r["metric"]: r for r in rows}
    failures = []
    for base in baseline:
        metric, base_value = base["metric"], float(base["value"])
        row = current.get(metric)
        if row is None:
            failures.append(f"baseline metric {metric!r} missing from this run")
            continue
        value = float(row["value"])
        band = float(base.get("factor", factor))
        if base.get("unit") == "us":  # latency: lower is better
            ok = value <= base_value * band
            verdict = f"{value:.3f}us vs baseline {base_value:.3f}us (allowed {band}x)"
        else:  # rate: higher is better
            ok = value >= base_value / band
            verdict = f"{value:.1f} vs baseline {base_value:.1f} (allowed /{band})"
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] {metric}: {verdict}")
        if not ok:
            failures.append(f"{metric}: {verdict}")
    return failures


#: metrics curated into a fresh baseline by --update-baseline, mapped to an
#: optional per-row regression factor (None = the global --factor). Mostly
#: robust throughput numbers; the loadgen server p99 gates tail latency —
#: with a wide band, since tiny-config tails are noisy on shared runners.
BASELINE_METRICS = {
    "store/onpair16/store-multiget/numpy/lookups_s": None,
    "ingest/urls/extend-1024/strings_s": None,
    "persist/book_titles/onpair16/speedup_vs_retrain": None,
    "rpc/multiget/rpc/lookups_s": None,
    "rpc/get/rpc/lookups_s": None,
    "rpc/extend-512/rpc/strings_s": None,
    "rpc/append-pipelined/rpc/strings_s": None,
    "client/multiget/shard/lookups_s": None,
    "locate/locate-hit/store/lookups_s": None,
    "loadgen/closed/rpc/ops_s": None,
    "loadgen/closed/rpc/server_p99_us": 10.0,
    # hard acceptance floor, not a halved throughput number: a majority-
    # demoted store must shed >= 40% of memory_bytes (factor 1.0 = no band)
    "tier/memory-drop/cold/memory_drop_pct": 1.0,
    "tier/multiget-cold/store/lookups_per_s": None,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=SMOKE_BENCHES)
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_smoke.json"))
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--factor", type=float, default=5.0)
    ap.add_argument("--full-size", action="store_true", help="not --quick")
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline floor from this run (values halved for "
        "hardware headroom) instead of gating against it",
    )
    args = ap.parse_args()

    rows = rows_from_csv(
        run_benchmarks(args.only, quick=not args.full_size),
        commit_sha(),
        backend=decode_backend(),
    )
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows to {args.out}")

    if args.update_baseline:
        current = {r["metric"]: r for r in rows}
        floor = []
        for metric, row_factor in BASELINE_METRICS.items():
            row = current[metric]
            value = row["value"] * 2 if row["unit"] == "us" else row["value"] / 2
            if metric == "tier/memory-drop/cold/memory_drop_pct":
                value = 40.0  # acceptance floor, not a measured number
            entry = {**row, "value": round(value, 3), "commit": "baseline"}
            if row_factor is not None:
                entry["factor"] = row_factor
            floor.append(entry)
        with open(args.baseline, "w") as f:
            json.dump(floor, f, indent=1)
        print(f"rewrote {args.baseline} with {len(floor)} metrics")
        return

    if not os.path.exists(args.baseline):
        raise SystemExit(f"no baseline at {args.baseline} (run --update-baseline)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check_regressions(rows, baseline, args.factor)
    if failures:
        raise SystemExit("bench-smoke regressions:\n  " + "\n  ".join(failures))
    print(f"bench-smoke: {len(baseline)} gated metrics within {args.factor}x")


if __name__ == "__main__":
    main()
